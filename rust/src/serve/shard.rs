//! One serving shard: a private writer [`Engine`] plus an epoch-published
//! read snapshot.
//!
//! The shard owns the paper's full per-engine round — outlier nomination on
//! the current set, ONE fused inc/dec update (eq. 15 / eq. 30), optional
//! snapshot rollback — over its J/K-sized slice of the stream, and after
//! every successful round publishes an immutable [`Arc<Engine>`] snapshot
//! through [`Epoch`]. Readers ([`SnapshotHandle`]) therefore never touch
//! the writer's state: an in-flight update delays nothing, it only delays
//! *freshness* by one epoch (see [`super::publish`] for the contrast with
//! the coordinator's `RwLock` read path).

use crate::coordinator::engine::{Engine, EnginePredictWork};
use crate::coordinator::{CoordinatorConfig, RoundOutcome};
use crate::ensure_shape;
use crate::error::Result;
use crate::linalg::Mat;
use crate::metrics::{Counters, Timer};
use crate::persist::store::ShardStore;
use crate::persist::wal::WalRecord;
use crate::streaming::outlier::detect_scored_multi;
use crate::streaming::StreamEvent;
use crate::telemetry::{FlightDump, FlightRecorder, HistId, MetricId, Registry, SpanKind};
use std::sync::Arc;

use super::publish::{Epoch, HealthCell, ShardStatus};
use super::query::{PredictRequest, PredictResponse, QueryKind};

/// Caller-owned workspace for [`SnapshotHandle::query_into`]: the engine
/// scratch plus the staging buffers the `D = 1` kinds need to bridge the
/// engines' `Vec<f64>` surface into the response's `(B, 1)` matrix.
/// Allocation-free once warm.
#[derive(Default)]
pub struct SnapshotQueryWork {
    engine: EnginePredictWork,
    mean: Vec<f64>,
    spare_var: Vec<f64>,
}

/// A cloneable, lock-free-for-readers handle onto one shard's published
/// model state.
#[derive(Clone)]
pub struct SnapshotHandle {
    cell: Arc<Epoch<Engine>>,
    health: Arc<HealthCell>,
    telemetry: Arc<Registry>,
}

impl SnapshotHandle {
    /// The shard's current serving status (one atomic load).
    pub fn status(&self) -> ShardStatus {
        self.health.get()
    }

    /// The shard's live metric slots — what the reader-side fleet view
    /// ([`super::router::RouterHandle::telemetry`], the `MKTL` stats
    /// frame) merges without touching the writer.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// True when the router may fan in over this shard (anything but
    /// quarantined).
    pub fn serving(&self) -> bool {
        self.health.serving()
    }

    /// The last published engine snapshot (readers compute against this
    /// without ever contending with the shard's writer).
    pub fn snapshot(&self) -> Arc<Engine> {
        self.cell.load()
    }

    /// Snapshot + its epoch number, read consistently.
    pub fn snapshot_with_epoch(&self) -> (Arc<Engine>, u64) {
        self.cell.load_with_epoch()
    }

    /// Current epoch number (0 = bootstrap state, +1 per published round).
    pub fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// Run one [`PredictRequest`] against the last published epoch,
    /// allocating a fresh response (serving loops should prefer
    /// [`SnapshotHandle::query_into`] with warm buffers).
    pub fn query(&self, req: &PredictRequest) -> Result<PredictResponse> {
        let mut resp = PredictResponse::default();
        let mut work = SnapshotQueryWork::default();
        self.query_inner(&req.x, req.want, &mut resp, &mut work)?;
        Ok(resp)
    }

    /// Run one [`PredictRequest`] through caller-owned buffers — the single
    /// entry point every legacy `predict*` shim delegates to.
    /// Allocation-free once `resp`/`work` are warm.
    pub fn query_into(
        &self,
        req: &PredictRequest,
        resp: &mut PredictResponse,
        work: &mut SnapshotQueryWork,
    ) -> Result<()> {
        self.query_inner(&req.x, req.want, resp, work)
    }

    /// Shared body of [`SnapshotHandle::query`] / [`SnapshotHandle::query_into`]
    /// (borrows `x` so the deprecated shims avoid copying the batch into a
    /// request). Each kind dispatches to the same engine kernel the legacy
    /// method used, so answers are bitwise-unchanged by the redesign.
    pub(crate) fn query_inner(
        &self,
        x: &Mat,
        want: QueryKind,
        resp: &mut PredictResponse,
        work: &mut SnapshotQueryWork,
    ) -> Result<()> {
        let snap = self.cell.load();
        match want {
            QueryKind::Mean => {
                snap.predict_into(x, &mut work.mean, &mut work.engine)?;
                resp.mean.resize_scratch(x.rows(), 1);
                resp.mean.as_mut_slice().copy_from_slice(&work.mean);
                resp.clear_into_spare(&mut work.spare_var);
            }
            QueryKind::MeanMulti => {
                snap.predict_multi_into(x, &mut resp.mean, &mut work.engine)?;
                resp.clear_into_spare(&mut work.spare_var);
            }
            QueryKind::MeanVar => {
                let mut var = resp.take_variance_buf(&mut work.spare_var);
                snap.predict_with_uncertainty_into(x, &mut work.mean, &mut var, &mut work.engine)?;
                resp.mean.resize_scratch(x.rows(), 1);
                resp.mean.as_mut_slice().copy_from_slice(&work.mean);
                resp.variance = Some(var);
            }
            QueryKind::MeanVarMulti => {
                let mut var = resp.take_variance_buf(&mut work.spare_var);
                snap.predict_with_uncertainty_multi_into(
                    x,
                    &mut resp.mean,
                    &mut var,
                    &mut work.engine,
                )?;
                resp.variance = Some(var);
            }
        }
        Ok(())
    }

    /// Predict through the last published epoch (`D = 1`).
    #[deprecated(since = "0.4.0", note = "use SnapshotHandle::query with QueryKind::Mean")]
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut resp = PredictResponse::default();
        let mut work = SnapshotQueryWork::default();
        self.query_inner(x, QueryKind::Mean, &mut resp, &mut work)?;
        Ok(resp.mean.as_slice().to_vec())
    }

    /// Predict all D output columns through the last published epoch.
    #[deprecated(since = "0.4.0", note = "use SnapshotHandle::query with QueryKind::MeanMulti")]
    pub fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        let mut resp = PredictResponse::default();
        let mut work = SnapshotQueryWork::default();
        self.query_inner(x, QueryKind::MeanMulti, &mut resp, &mut work)?;
        Ok(resp.mean)
    }

    /// Predictive mean + variance through the last published epoch
    /// (requires the shard's KBR twin, `D = 1`).
    #[deprecated(since = "0.4.0", note = "use SnapshotHandle::query with QueryKind::MeanVar")]
    pub fn predict_with_uncertainty(&self, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        let mut resp = PredictResponse::default();
        let mut work = SnapshotQueryWork::default();
        self.query_inner(x, QueryKind::MeanVar, &mut resp, &mut work)?;
        let var = resp.variance.take().unwrap_or_default();
        Ok((resp.mean.as_slice().to_vec(), var))
    }

    /// Multi-output predictive mean + shared per-query variance through
    /// the last published epoch (requires the shard's KBR twin).
    #[deprecated(
        since = "0.4.0",
        note = "use SnapshotHandle::query with QueryKind::MeanVarMulti"
    )]
    pub fn predict_with_uncertainty_multi(&self, x: &Mat) -> Result<(Mat, Vec<f64>)> {
        let mut resp = PredictResponse::default();
        let mut work = SnapshotQueryWork::default();
        self.query_inner(x, QueryKind::MeanVarMulti, &mut resp, &mut work)?;
        let var = resp.variance.take().unwrap_or_default();
        Ok((resp.mean, var))
    }

    /// Training-set size of the last published epoch.
    pub fn n_samples(&self) -> usize {
        self.cell.load().n_samples()
    }
}

/// One shard of the serving layer.
pub struct Shard {
    id: usize,
    /// The writer's private engine — never read by serving traffic.
    engine: Engine,
    /// Published read snapshots.
    cell: Arc<Epoch<Engine>>,
    /// Round policy, inherited from the coordinator config.
    cfg: CoordinatorConfig,
    /// Shared serving status (read by the router's fan-in loops).
    health: Arc<HealthCell>,
    /// Arrivals routed here but not yet folded into an update.
    pending: Vec<StreamEvent>,
    /// Size of the batch the most recent failed [`Shard::flush`] requeued
    /// (0 after a success) — the supervisor quarantines exactly this
    /// prefix once the retry budget is spent.
    last_attempt: usize,
    /// Chaos-injected failure window: while > 0, every flush fails with
    /// `Error::Numerical` (decrementing by 1 per round).
    #[cfg(feature = "chaos")]
    chaos_fail_rounds: u32,
    /// Durable-shard state ([`ShardStore`]): write-ahead log + checkpoint
    /// cadence. `None` = the pre-durability in-memory-only behaviour.
    store: Option<ShardStore>,
    /// Highest applied *event* sequence number — persisted in snapshots
    /// and used after recovery to re-feed exactly the events the crash
    /// lost (distinct from the epoch, which counts *rounds*).
    high_seq: u64,
    /// Reused insertion-block assembly buffers (`y_new` is (B, D)).
    x_new: Mat,
    y_new: Mat,
    y_row: Vec<f64>,
    /// Lock-free metric slots: rounds / added / removed / rollbacks /
    /// phase + round latency histograms. Shared (`Arc`) with this shard's
    /// [`SnapshotHandle`]s and attached [`ShardStore`], so readers merge a
    /// fleet view without touching the writer.
    telemetry: Arc<Registry>,
    /// Single-writer flight recorder for the shard's round phases — the
    /// supervisor dumps it at quarantine, recovery ships it per shard.
    recorder: FlightRecorder,
}

impl Shard {
    /// Fit a shard engine on its bootstrap slice and publish epoch 0
    /// (`D = 1`).
    pub fn bootstrap(
        id: usize,
        x: &Mat,
        y: &[f64],
        cfg: &CoordinatorConfig,
        space: crate::config::Space,
    ) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::bootstrap_multi(id, x, &ym, cfg, space)
    }

    /// Fit a shard engine on its `(N, D)` bootstrap slice and publish
    /// epoch 0.
    pub fn bootstrap_multi(
        id: usize,
        x: &Mat,
        y: &Mat,
        cfg: &CoordinatorConfig,
        space: crate::config::Space,
    ) -> Result<Self> {
        let mut engine =
            Engine::fit_multi(x, y, &cfg.kernel, cfg.ridge, space, cfg.with_uncertainty)?;
        engine.set_fold_eps(cfg.fold_eps);
        Ok(Self::from_engine(id, engine, cfg, 0, 0))
    }

    /// Assemble a shard around an existing engine, publishing it at a
    /// given epoch / event high-water mark — the recovery entry
    /// (`ShardRouter::recover`) republishes a rebuilt engine at the epoch
    /// its snapshot recorded so WAL replay stays sequence-idempotent.
    pub(crate) fn from_engine(
        id: usize,
        engine: Engine,
        cfg: &CoordinatorConfig,
        epoch: u64,
        high_seq: u64,
    ) -> Self {
        let cell = Arc::new(Epoch::new_at(engine.clone(), epoch));
        Self {
            id,
            engine,
            cell,
            cfg: cfg.clone(),
            health: Arc::new(HealthCell::new()),
            pending: Vec::new(),
            last_attempt: 0,
            #[cfg(feature = "chaos")]
            chaos_fail_rounds: 0,
            store: None,
            high_seq,
            x_new: Mat::default(),
            y_new: Mat::default(),
            y_row: Vec::new(),
            telemetry: Arc::new(Registry::new()),
            recorder: FlightRecorder::default(),
        }
    }

    /// Shard id (its index in the router).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Writer-side training-set size (the next epoch's size).
    pub fn n_samples(&self) -> usize {
        self.engine.n_samples()
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.engine.dim()
    }

    /// Events routed here but not yet applied.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// The shard's per-round batch cap (from the coordinator policy).
    pub fn max_batch(&self) -> usize {
        self.cfg.batch.max_batch
    }

    /// Queue one routed arrival for the next update round.
    pub fn push(&mut self, ev: StreamEvent) {
        self.pending.push(ev);
    }

    /// A read handle onto this shard's published epochs.
    pub fn handle(&self) -> SnapshotHandle {
        SnapshotHandle {
            cell: Arc::clone(&self.cell),
            health: Arc::clone(&self.health),
            telemetry: Arc::clone(&self.telemetry),
        }
    }

    /// This shard's live metric slots.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Replace the shard's registry (e.g. to share one registry across a
    /// tier). Counts recorded so far are folded into `reg`, and an
    /// attached store starts recording there too. Call before taking
    /// [`Shard::handle`]s — existing handles keep the old registry.
    pub fn set_telemetry(&mut self, reg: Arc<Registry>) {
        reg.absorb(&self.telemetry);
        if let Some(store) = self.store.as_mut() {
            store.set_telemetry(Arc::clone(&reg));
        }
        self.telemetry = reg;
    }

    /// String-keyed compatibility view over the shard's registry (the
    /// legacy `counters` field's rendering surface; names are unchanged).
    pub fn counters(&self) -> Counters {
        self.telemetry.counters()
    }

    /// Freeze the shard's flight-recorder window into a labeled dump —
    /// what the supervisor attaches the moment it quarantines this shard.
    pub fn flight_dump(&self, label: impl Into<String>) -> FlightDump {
        self.recorder.dump(label)
    }

    /// Stamp a span into this shard's recorder from its owner (the
    /// supervisor's retry/quarantine decisions, the router's recovery) so
    /// the dump carries the decisions *about* the shard alongside the
    /// events *inside* it.
    pub(crate) fn record_span(&mut self, kind: SpanKind, a: u64, b: u64) {
        self.recorder.record(kind, a, b);
    }

    /// Current serving status.
    pub fn status(&self) -> ShardStatus {
        self.health.get()
    }

    /// Set the serving status (supervisor side); read handles observe it
    /// on their next fan-in.
    pub fn set_status(&self, s: ShardStatus) {
        self.health.set(s);
    }

    /// Borrow the writer engine (read-only: probes, diagnostics).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Size of the batch the most recent failed flush requeued (0 after a
    /// successful round).
    pub fn last_attempt_len(&self) -> usize {
        self.last_attempt
    }

    /// Attach durable state: from here on every applied round is
    /// write-ahead logged and the store checkpoints on its cadence. The
    /// explicit-block entries ([`Shard::apply_batch`],
    /// [`Shard::apply_update`], [`Shard::apply_update_multi`]) are
    /// rejected while a store is attached — they would mutate the engine
    /// without a WAL record.
    pub fn attach_store(&mut self, mut store: ShardStore) {
        // one registry per shard: the store's WAL/checkpoint slots land in
        // the same instance as the round slots (its pre-attach counts —
        // e.g. the create()-time snapshot — are absorbed first)
        store.set_telemetry(Arc::clone(&self.telemetry));
        self.store = Some(store);
    }

    /// True when this shard is durably logged.
    pub fn is_durable(&self) -> bool {
        self.store.is_some()
    }

    /// Highest applied event sequence number (the exactly-once re-feed
    /// cutoff after recovery).
    pub fn high_seq(&self) -> u64 {
        self.high_seq
    }

    /// The durability counters, when a store is attached (a string-keyed
    /// view over the store's registry slots).
    pub fn durability_counters(&self) -> Option<Counters> {
        self.store.as_ref().map(|s| s.counters())
    }

    fn ensure_not_durable(&self, ctx: &'static str) -> Result<()> {
        if self.store.is_some() {
            return Err(crate::error::Error::Config(format!(
                "{ctx} bypasses the write-ahead log; durable shards apply \
                 rounds via flush / evict_outliers / heal"
            )));
        }
        Ok(())
    }

    /// Pull the first `n` pending events off the queue — the supervisor's
    /// poison-batch quarantine: the events leave the requeue loop for good
    /// and become inspectable evidence instead.
    pub fn quarantine_front(&mut self, n: usize) -> Vec<StreamEvent> {
        let n = n.min(self.pending.len());
        self.pending.drain(..n).collect()
    }

    /// Self-heal: full refactorization of the writer engine from its
    /// retained training view ([`Engine::refit`]), then publish the healed
    /// state and mark the shard healthy. Readers keep serving the previous
    /// epoch for the whole (O(N·J²)-ish) rebuild — the heal only ever
    /// delays *freshness*, never a read.
    pub fn heal(&mut self) -> Result<u64> {
        if let Some(store) = self.store.as_mut() {
            // write-ahead: replay re-runs the refit at the same round
            store.log_heal(self.cell.epoch() + 1)?;
        }
        self.heal_inner()
    }

    fn heal_inner(&mut self) -> Result<u64> {
        self.engine.refit()?;
        let epoch = self.cell.publish(self.engine.clone());
        self.telemetry.inc(MetricId::Heals);
        self.recorder.record(SpanKind::Heal, self.id as u64, 0);
        self.health.set(ShardStatus::Healthy);
        Ok(epoch)
    }

    /// Chaos-only: make the next `rounds` flushes fail with
    /// `Error::Numerical` (a wedged shard / forced transient failure).
    #[cfg(feature = "chaos")]
    pub fn chaos_wedge(&mut self, rounds: u32) {
        self.chaos_fail_rounds = self.chaos_fail_rounds.max(rounds);
    }

    /// Chaos-only: mutate the oldest pending event in place (NaN/Inf/
    /// poison row injection).
    #[cfg(feature = "chaos")]
    pub fn chaos_mutate_front(&mut self, f: impl FnOnce(&mut StreamEvent)) {
        if let Some(ev) = self.pending.first_mut() {
            f(ev);
        }
    }

    /// Chaos-only: corrupt the writer engine's maintained inverse so the
    /// health probe has real drift to find.
    #[cfg(feature = "chaos")]
    pub fn chaos_corrupt_inverse(&mut self, factor: f64) {
        self.engine.chaos_corrupt_inverse(factor);
    }

    /// Apply ONE fused round over an explicit batch of events: nominate
    /// outliers on the current set, fold removals and insertions into a
    /// single multiple inc/dec update (with per-shard snapshot rollback if
    /// configured), then publish the new epoch.
    pub fn apply_batch(&mut self, events: &[StreamEvent]) -> Result<RoundOutcome> {
        self.ensure_not_durable("Shard::apply_batch")?;
        self.apply_batch_inner(events)
    }

    fn apply_batch_inner(&mut self, events: &[StreamEvent]) -> Result<RoundOutcome> {
        self.recorder.record(SpanKind::RoundStart, events.len() as u64, 0);
        // plan phase: outlier nomination + insertion-block staging
        let t_plan = Timer::start();
        let removals: Vec<usize> = match &self.cfg.outlier {
            Some(ocfg) => {
                let pred = self.engine.krr().predict_training_multi()?;
                detect_scored_multi(&pred, self.engine.training_view().1, ocfg)?
                    .into_iter()
                    .map(|v| v.index)
                    .collect()
            }
            None => Vec::new(),
        };
        let dim = self.engine.dim();
        let d = self.engine.n_outputs();
        self.x_new.resize_scratch(0, dim);
        self.y_new.resize_scratch(0, d);
        for ev in events {
            // validate here, where it is still an Err: the engines' feature
            // maps assert on dimension, and a NaN/Inf row admitted past
            // this point poisons the maintained inverse silently
            ev.validate(dim, d)?;
            self.x_new.push_row(&ev.x)?;
            self.y_row.clear();
            self.y_row.push(ev.y);
            self.y_row.extend_from_slice(&ev.y_tail);
            self.y_new.push_row(&self.y_row)?;
        }
        self.telemetry.record_secs(HistId::PhasePlanUs, t_plan.elapsed());
        self.update_and_publish(&removals)
    }

    /// Apply ONE fused round with an explicit insertion block and removal
    /// set (no outlier detection) — the replay / bench / delegation entry
    /// (`D = 1`).
    pub fn apply_update(
        &mut self,
        x_new: &Mat,
        y_new: &[f64],
        remove_idx: &[usize],
    ) -> Result<RoundOutcome> {
        self.ensure_not_durable("Shard::apply_update")?;
        if self.engine.n_outputs() != 1 {
            return Err(crate::error::Error::Config(
                "apply_update is the D=1 surface; use apply_update_multi".into(),
            ));
        }
        self.stage_x(x_new)?;
        self.check_targets_finite(y_new)?;
        self.y_new.resize_scratch(y_new.len(), 1);
        self.y_new.as_mut_slice().copy_from_slice(y_new);
        self.update_and_publish(remove_idx)
    }

    /// Multi-output [`Shard::apply_update`]: `y_new` is `(B, D)`.
    pub fn apply_update_multi(
        &mut self,
        x_new: &Mat,
        y_new: &Mat,
        remove_idx: &[usize],
    ) -> Result<RoundOutcome> {
        self.ensure_not_durable("Shard::apply_update_multi")?;
        self.stage_x(x_new)?;
        self.check_targets_finite(y_new.as_slice())?;
        self.y_new.resize_scratch(y_new.rows(), y_new.cols());
        self.y_new.as_mut_slice().copy_from_slice(y_new.as_slice());
        self.update_and_publish(remove_idx)
    }

    /// Boundary float validation for the explicit-block entry points (the
    /// event path goes through [`StreamEvent::validate`] instead).
    fn check_targets_finite(&mut self, y: &[f64]) -> Result<()> {
        if y.iter().all(|v| v.is_finite()) {
            Ok(())
        } else {
            self.telemetry.inc(MetricId::RejectedNonfinite);
            Err(crate::error::Error::InvalidUpdate(
                "insertion targets carry non-finite values".into(),
            ))
        }
    }

    /// Copy the insertion features into the warm staging buffer.
    fn stage_x(&mut self, x_new: &Mat) -> Result<()> {
        ensure_shape!(
            x_new.rows() == 0 || x_new.cols() == self.engine.dim(),
            "Shard::apply_update",
            "insertion block has {} cols, expected {}",
            x_new.cols(),
            self.engine.dim()
        );
        if !x_new.is_finite() {
            self.telemetry.inc(MetricId::RejectedNonfinite);
            return Err(crate::error::Error::InvalidUpdate(
                "insertion features carry non-finite values".into(),
            ));
        }
        if x_new.rows() > 0 {
            self.x_new.resize_scratch(x_new.rows(), x_new.cols());
            self.x_new.as_mut_slice().copy_from_slice(x_new.as_slice());
        } else {
            self.x_new.resize_scratch(0, self.engine.dim());
        }
        Ok(())
    }

    /// Drain up to `max_batch` pending events through one fused round.
    /// `Ok(None)` when nothing is pending (or everything drained was
    /// malformed).
    ///
    /// Failure policy: malformed events (wrong dimension / target count /
    /// non-finite floats) can never succeed, so they are discarded up
    /// front (`counters["rejected"]`, non-finite ones additionally under
    /// `counters["rejected_nonfinite"]`) instead of poisoning the queue.
    /// If the engine update itself fails, the batch is requeued only when
    /// `snapshot_rollback` restored the pre-round state — without a
    /// snapshot the engine may have partially absorbed the batch (KRR
    /// updates before KBR inside [`Engine::inc_dec`]), and retrying would
    /// double-apply it, so the batch is dropped (`counters["dropped"]`)
    /// and the error surfaced. A requeued batch records its size in
    /// [`Shard::last_attempt_len`], which is what the supervisor
    /// quarantines once the retry budget is spent.
    pub fn flush(&mut self) -> Result<Option<RoundOutcome>> {
        if self.pending.is_empty() {
            return Ok(None);
        }
        self.recorder.record(SpanKind::Flush, self.pending.len() as u64, 0);
        let take = self.pending.len().min(self.cfg.batch.max_batch);
        // drain the OLDEST events first (arrival order)
        let batch: Vec<StreamEvent> = self.pending.drain(..take).collect();
        let dim = self.engine.dim();
        let d = self.engine.n_outputs();
        let before = batch.len();
        let mut nonfinite = 0u64;
        let batch: Vec<StreamEvent> = batch
            .into_iter()
            .filter(|ev| {
                let ok = ev.validate(dim, d).is_ok();
                if !ok && !ev.is_finite() {
                    nonfinite += 1;
                }
                ok
            })
            .collect();
        if nonfinite > 0 {
            self.telemetry.add(MetricId::RejectedNonfinite, nonfinite);
        }
        if batch.len() < before {
            self.telemetry.add(MetricId::Rejected, (before - batch.len()) as u64);
        }
        if batch.is_empty() {
            return Ok(None);
        }
        #[cfg(feature = "chaos")]
        if self.chaos_fail_rounds > 0 {
            self.chaos_fail_rounds -= 1;
            self.telemetry.inc(MetricId::ChaosForcedFailures);
            self.last_attempt = batch.len();
            self.recorder.record(SpanKind::Rollback, batch.len() as u64, 0);
            if self.cfg.snapshot_rollback {
                self.pending.splice(0..0, batch);
            } else {
                self.telemetry.add(MetricId::Dropped, batch.len() as u64);
            }
            return Err(crate::error::Error::numerical(
                "Shard::flush",
                "chaos-injected failure",
            ));
        }
        // write-ahead: the filtered batch is logged before the engine sees
        // it. On a WAL failure the engine is untouched, so the batch is
        // ALWAYS requeued (no rollback needed) and the error surfaces as
        // transient or permanent per its persist classification.
        if let Some(store) = self.store.as_mut() {
            let seq = self.cell.epoch() + 1;
            let t = Timer::start();
            let logged = store.log_batch(seq, &batch);
            let wal_us = (t.elapsed() * 1e6) as u64;
            self.telemetry.record_hist(HistId::PhaseWalUs, wal_us);
            self.recorder.record(SpanKind::WalAppend, seq, wal_us);
            if let Err(e) = logged {
                self.last_attempt = batch.len();
                self.pending.splice(0..0, batch);
                return Err(e);
            }
        }
        match self.apply_batch_inner(&batch) {
            Ok(out) => {
                self.last_attempt = 0;
                let max_seq = batch.iter().map(|ev| ev.seq).max().unwrap_or(0);
                self.high_seq = self.high_seq.max(max_seq);
                // checkpoint cadence: the round is already applied and
                // published, so a checkpoint failure surfaces as an error
                // WITHOUT requeueing (retrying the batch would double-apply)
                if self.store.is_some() {
                    self.checkpoint_if_due()?;
                }
                Ok(Some(out))
            }
            Err(e) => {
                self.last_attempt = batch.len();
                if self.cfg.snapshot_rollback {
                    self.pending.splice(0..0, batch);
                } else {
                    self.telemetry.add(MetricId::Dropped, batch.len() as u64);
                }
                Err(e)
            }
        }
    }

    /// Run the store's checkpoint cadence against the current engine.
    fn checkpoint_if_due(&mut self) -> Result<()> {
        let epoch = self.cell.epoch();
        let high_seq = self.high_seq;
        if let Some(store) = self.store.as_mut() {
            let t = Timer::start();
            if store.maybe_checkpoint(&self.engine, epoch, high_seq)? {
                let us = (t.elapsed() * 1e6) as u64;
                self.recorder.record(SpanKind::Checkpoint, store.generation(), us);
            }
        }
        Ok(())
    }

    /// An insertion-free round: outlier nomination + decremental update
    /// only (the explicit eviction entry).
    pub fn evict_outliers(&mut self) -> Result<RoundOutcome> {
        if let Some(store) = self.store.as_mut() {
            store.log_evict(self.cell.epoch() + 1)?;
        }
        self.apply_batch_inner(&[])
    }

    /// Replay one recovered WAL record onto this shard. Records at or
    /// below the published epoch are no-ops (`Ok(false)`) — the snapshot
    /// already contains them. A record that fails to apply returns the
    /// error; because round failures are deterministic functions of engine
    /// state + batch, a replay failure reproduces a failure the live run
    /// already saw (and resolved by quarantine or drop), so the caller
    /// counts it and moves on.
    pub(crate) fn replay_record(&mut self, rec: &WalRecord) -> Result<bool> {
        if rec.seq() <= self.cell.epoch() {
            return Ok(false);
        }
        match rec {
            WalRecord::Batch { events, .. } => {
                self.apply_batch_inner(events)?;
                let max_seq = events.iter().map(|ev| ev.seq).max().unwrap_or(0);
                self.high_seq = self.high_seq.max(max_seq);
            }
            WalRecord::Evict { .. } => {
                self.apply_batch_inner(&[])?;
            }
            WalRecord::Heal { .. } => {
                self.heal_inner()?;
            }
        }
        Ok(true)
    }

    /// The fused update on the writer engine + epoch publish. The insertion
    /// block is whatever `x_new`/`y_new` currently hold.
    fn update_and_publish(&mut self, removals: &[usize]) -> Result<RoundOutcome> {
        let t = Timer::start();
        let snapshot = self.cfg.snapshot_rollback.then(|| self.engine.snapshot());
        match self.engine.inc_dec_multi(&self.x_new, &self.y_new, removals) {
            Ok(()) => {}
            Err(e) => {
                if let Some(snap) = snapshot {
                    self.engine.restore(snap);
                    self.telemetry.inc(MetricId::Rollbacks);
                    self.recorder.record(SpanKind::Rollback, self.y_new.rows() as u64, 0);
                }
                return Err(e);
            }
        }
        let incdec_us = (t.elapsed() * 1e6) as u64;
        self.telemetry.record_hist(HistId::PhaseIncDecUs, incdec_us);
        self.recorder.record(SpanKind::IncDec, self.y_new.rows() as u64, incdec_us);
        self.telemetry.add(MetricId::Folded, self.engine.last_round_folds() as u64);
        // publish: the O(state) clone is the epoch snapshot itself; readers
        // switch to it atomically and the writer keeps its private copy
        let t_pub = Timer::start();
        let epoch = self.cell.publish(self.engine.clone());
        let publish_us = (t_pub.elapsed() * 1e6) as u64;
        self.telemetry.record_hist(HistId::PhasePublishUs, publish_us);
        self.telemetry.inc(MetricId::EpochsPublished);
        self.recorder.record(SpanKind::Publish, epoch, publish_us);
        let dt = t.elapsed();
        let outcome = RoundOutcome {
            added: self.y_new.rows(),
            removed: removals.len(),
            update_secs: dt,
            n_after: self.engine.n_samples(),
        };
        debug_assert!(epoch > 0);
        self.telemetry.inc(MetricId::Rounds);
        self.telemetry.add(MetricId::Added, outcome.added as u64);
        self.telemetry.add(MetricId::Removed, outcome.removed as u64);
        self.telemetry.record_secs(HistId::RoundLatencyUs, dt);
        self.recorder.record(SpanKind::RoundEnd, outcome.added as u64, (dt * 1e6) as u64);
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Space;
    use crate::data::synth;
    use crate::kernels::Kernel;
    use crate::streaming::batcher::BatchPolicy;
    use std::time::Duration;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            kernel: Kernel::poly(2, 1.0),
            ridge: 0.5,
            space: None,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) },
            outlier: None,
            with_uncertainty: false,
            snapshot_rollback: false,
            fold_eps: None,
        }
    }

    fn events(n: usize, dim: usize, seed: u64) -> Vec<StreamEvent> {
        let d = synth::ecg_like(n, dim, seed);
        (0..n)
            .map(|i| StreamEvent::single(d.x.row(i).to_vec(), d.y[i], 0, i as u64))
            .collect()
    }

    #[test]
    fn rounds_publish_monotonic_epochs() {
        let d = synth::ecg_like(40, 6, 1);
        let mut s = Shard::bootstrap(0, &d.x, &d.y, &cfg(), Space::Intrinsic).unwrap();
        let h = s.handle();
        assert_eq!(h.epoch(), 0);
        assert_eq!(h.n_samples(), 40);
        for (round, ev) in events(8, 6, 2).chunks(4).enumerate() {
            let out = s.apply_batch(ev).unwrap();
            assert_eq!(out.added, 4);
            assert_eq!(h.epoch(), round as u64 + 1);
            assert_eq!(h.n_samples(), out.n_after);
        }
        assert_eq!(s.n_samples(), 48);
    }

    #[test]
    fn flush_respects_batch_policy() {
        let d = synth::ecg_like(30, 5, 3);
        let mut s = Shard::bootstrap(0, &d.x, &d.y, &cfg(), Space::Intrinsic).unwrap();
        for ev in events(6, 5, 4) {
            s.push(ev);
        }
        let out = s.flush().unwrap().unwrap();
        assert_eq!(out.added, 4, "max_batch caps one flush");
        assert_eq!(s.pending(), 2);
        let out = s.flush().unwrap().unwrap();
        assert_eq!(out.added, 2);
        assert!(s.flush().unwrap().is_none());
    }

    #[test]
    fn failed_round_keeps_published_epoch_intact() {
        let d = synth::ecg_like(30, 5, 5);
        let mut s = Shard::bootstrap(0, &d.x, &d.y, &cfg(), Space::Intrinsic).unwrap();
        let h = s.handle();
        let p0 = h.predict(&d.x.block(0, 3, 0, 5)).unwrap();
        // wrong-dimension event: the round errors before any engine edit
        let bad = StreamEvent::single(vec![1.0; 3], 0.0, 0, 0);
        assert!(s.apply_batch(std::slice::from_ref(&bad)).is_err());
        assert_eq!(h.epoch(), 0, "failed round must not publish");
        let p1 = h.predict(&d.x.block(0, 3, 0, 5)).unwrap();
        crate::testutil::assert_vec_close(&p1, &p0, 1e-15);
    }

    #[test]
    fn explicit_update_matches_engine_round() {
        let d = synth::ecg_like(36, 5, 6);
        let extra = synth::ecg_like(4, 5, 7);
        let mut s = Shard::bootstrap(0, &d.x, &d.y, &cfg(), Space::Intrinsic).unwrap();
        let mut reference =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false)
                .unwrap();
        s.apply_update(&extra.x, &extra.y, &[1, 3]).unwrap();
        reference.inc_dec(&extra.x, &extra.y, &[1, 3]).unwrap();
        let q = synth::ecg_like(5, 5, 8);
        let ps = s.handle().predict(&q.x).unwrap();
        let pr = reference.predict(&q.x).unwrap();
        crate::testutil::assert_vec_close(&ps, &pr, 1e-12);
    }
}
