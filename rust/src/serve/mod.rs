//! The sharded serving layer: multi-engine shard routing with
//! micro-batched prediction traffic.
//!
//! PRs 1–4 made the per-update compute core fully packed and parallel, so
//! at serving scale the bottleneck moved **above** the engine: one
//! maintained inverse behind one `RwLock` serializes every update against
//! every read. This subsystem is the first where the headline metric is
//! **throughput under concurrent updates** (requests/sec), not per-op
//! latency, and it attacks the lock three ways:
//!
//! * **Sharding** ([`router`]) — the stream is partitioned across K
//!   independent [`crate::coordinator::engine::Engine`] replicas
//!   (round-robin or content-hash placement, per-shard batching), so each
//!   shard runs the paper's fused inc/dec update (eq. 15 / eq. 30) on
//!   1/K-sized state. Reads average the shard predictions — the
//!   divide-and-conquer KRR estimator (You et al.) — and fuse the KBR
//!   twins' posteriors by precision weighting. Bounding each shard's
//!   working set is the same lever StreaMRAK pulls to keep streaming
//!   kernel regression scalable.
//! * **Epoch publishing** ([`publish`], [`shard`]) — every shard update
//!   lands as an immutable `Arc` snapshot; readers serve the last
//!   published epoch and *never* contend with the writer. An in-flight
//!   update delays freshness by one epoch instead of blocking the read
//!   fleet (the `RwLock` pattern it replaces did the opposite).
//! * **Micro-batching** ([`microbatch`]) — concurrent single-row predict
//!   requests coalesce into one batched `predict_into` per shard:
//!   per-request GEMVs become one packed BLAS-3 product, and the warm
//!   workspaces make the steady-state read path allocation-free.
//!
//! Bench coverage lives in `rust/benches/microbench.rs` (`serve/*`:
//! micro-batched GEMM predict vs per-request GEMV, K=1 vs K=4 update
//! rounds) with the `speedup_serve_microbatch` headline wired into the CI
//! perf gate; see EXPERIMENTS.md §Perf and `examples/serve_shard.rs` for
//! the end-to-end drive.
//!
//! # Failure semantics and recovery
//!
//! The write path is supervised ([`supervisor`]); every failure mode has a
//! bounded, observable outcome — never an infinite requeue, never a
//! blocked read:
//!
//! * **Malformed input** (wrong dimension / target count, NaN/±Inf
//!   payloads) is rejected at the event boundary
//!   ([`crate::streaming::StreamEvent::validate`], counted under
//!   `rejected` / `rejected_nonfinite`) before any engine sees it. A bad
//!   float that slipped past would corrupt the maintained inverse
//!   *silently*; a reject is loud and cheap.
//! * **Transient update failures** (`Error::is_transient()`: numerical,
//!   stream, I/O, runtime) are retried in place with deterministic
//!   exponential backoff + jitter, up to `RetryPolicy::max_attempts`,
//!   provided the shard's `snapshot_rollback` restored the pre-round state
//!   (a dropped batch is never retried — the retry would consume the next
//!   batch). Rollback means a failed round leaves the writer engine
//!   exactly as it was, and the published epoch was never touched.
//! * **Poison batches** — out of retry budget, or a permanent error — are
//!   **quarantined**: pulled off the pending queue into
//!   [`supervisor::QuarantinedBatch`] (who, when, how many attempts,
//!   which events), counted under `batches_quarantined` /
//!   `events_quarantined`. The requeue loop therefore strictly shrinks
//!   and the router drain can never livelock on a batch that will never
//!   succeed.
//! * **Failing shards** — `quarantine_after` consecutive failed rounds,
//!   or a critical health probe whose heal failed — flip their shared
//!   [`publish::ShardStatus`] cell to `Quarantined`. Every read fan-in
//!   skips them and renormalizes over the remaining K−1 shards (same
//!   DC-KRR average / precision weighting, fewer estimators); if *all*
//!   shards are quarantined the fan-in fails open and uses everything.
//! * **Silent numerical drift** is caught by rotating residual probes on
//!   the maintained inverse ([`crate::health::probe::HealthProbe`], warm
//!   and allocation-free). `trip_after` consecutive breaches escalate to
//!   a **self-heal**: a full refactorization from the shard's retained
//!   training view with multiplicity replay
//!   ([`crate::coordinator::engine::Engine::refit`]) on the *writer* copy,
//!   then a republish. Readers serve the last published epoch for the
//!   whole rebuild — recovery costs freshness, never availability.
//! * **Process crashes** are survivable once the fleet is made durable
//!   ([`ShardRouter::make_durable`], [`crate::persist`]): every applied
//!   round is **write-ahead logged** before the engine sees it, the
//!   engine is snapshotted every `checkpoint_every` rounds with
//!   crash-consistent tmp + fsync + atomic-rename generations, and
//!   [`ShardRouter::recover`] rebuilds each shard from its newest intact
//!   snapshot plus an idempotent (sequence-numbered) WAL replay. A
//!   corrupted newest snapshot falls back one generation and replays a
//!   longer suffix; recovered inverses are probe-verified before serving,
//!   and a shard that fails verification comes back `Quarantined` —
//!   into the same heal machinery as live drift — instead of failing the
//!   fleet. Events still in flight at the crash (never WAL-logged) are
//!   re-fed by the caller, filtered to `seq > high_seq` per shard
//!   ([`ShardRouter::high_seqs`]) so nothing applies twice. While a store
//!   is attached, the explicit-block entries (`apply_batch`,
//!   `apply_update*`) are rejected: they would mutate an engine with no
//!   WAL record, silently widening the crash window.
//!
//! # The query API
//!
//! All reads — in-process and network — are one request/response pair:
//! [`PredictRequest`] `{ x: Mat, want: QueryKind }` in,
//! [`PredictResponse`] `{ mean: Mat, variance: Option<Vec<f64>> }` out,
//! through a single `query` entry point per layer
//! ([`SnapshotHandle::query`], [`RouterHandle::query`],
//! [`PredictClient::query`]). [`QueryKind`] selects the estimator surface
//! (`Mean`/`MeanMulti` = KRR point path, `MeanVar`/`MeanVarMulti` = KBR
//! posterior with precision-weighted fan-in); the legacy
//! `predict*`/`predict*_into` explosion survives as deprecated shims over
//! the same path. Both types carry `encode_into`/`decode_from`
//! ([`serve::query`](query)) so the network frame is the canonical
//! serialization of the exact structs the in-process API uses.
//!
//! # Network serving and admission control
//!
//! [`crate::net`] puts this layer behind a socket: a dependency-free
//! epoll reactor accepts nonblocking connections and speaks a
//! length-prefixed, CRC-framed protocol built on the [`crate::persist`]
//! codec section format.
//!
//! **Frame grammar.** Every frame is one persist-codec section:
//! `[tag u32][len u64][payload][crc32 u32]`, little-endian, CRC over
//! tag‖len‖payload. Tags (ASCII-mnemonic u32s): `MKPR` predict request
//! (`[id u64][PredictRequest]`), `MKUP` update
//! (`[id u64][StreamEvent]`), `MKRS` predict response
//! (`[id u64][PredictResponse]`), `MKAK` update ack (`[id u64]`),
//! `MKRA` retry-after (`[id u64][retry_ms u32]`), `MKER` error
//! (`[id u64][transient u8][len u32][utf8 msg]`). The `id` is an opaque
//! client-chosen correlation token echoed back verbatim; responses may
//! arrive out of order relative to other connections' traffic but are
//! in-order per connection. A frame that fails CRC or framing, or whose
//! declared length exceeds `max_frame_len`, is answered with a permanent
//! `MKER` and the connection is closed — a torn frame means the byte
//! stream is unrecoverable.
//!
//! **Batching.** Predict frames from all connections coalesce into the
//! same per-[`QueryKind`] micro-batch window the in-process server uses
//! ([`microbatch::QueryLanes`]): B concurrent network reads become one
//! packed GEMM per kind. Update frames decode to
//! [`crate::streaming::StreamEvent`] and feed the [`ShardRouter`] ingest
//! path through a bounded queue.
//!
//! **Shed semantics / retry-after contract.** Admission control is
//! load-shedding, never unbounded queueing: each connection has an
//! inflight cap, the reactor has a global pending-rows budget, and the
//! update queue is bounded. An over-budget frame is answered *immediately*
//! with `MKRA` carrying a client hint of `retry_after_ms` milliseconds;
//! nothing about it is queued, so pending memory is bounded by
//! `pending_budget` + per-connection buffers regardless of offered load.
//! A shed is not an error: the request was never admitted, state did not
//! change, and the client should back off `retry_ms` (plus jitter) and
//! resend the identical frame. Sheds are counted (`shed_predict` /
//! `shed_update` in [`crate::metrics::Counters`]) so the loopback tests
//! can assert shed ≡ excess exactly; a slow reader whose write buffer
//! exceeds its cap is closed rather than buffered indefinitely.
//!
//! # Telemetry and flight recording
//!
//! Observability follows one discipline: **the instrument must not
//! perturb what it measures**. Three pieces
//! ([`crate::telemetry`]):
//!
//! * **Registries, not string counters.** Every tier — shard, router,
//!   micro-batch worker, net reactor, supervisor, shard store — owns one
//!   [`crate::telemetry::Registry`]: statically-keyed `AtomicU64` slots
//!   addressed by [`crate::telemetry::MetricId`] /
//!   [`crate::telemetry::HistId`] enums. A warm-path increment is one
//!   relaxed atomic add — no map lookup, no allocation, no lock (the
//!   `alloc_count.rs` contract covers counters, histograms, and span
//!   recording). Latency histograms are fixed log₂ buckets with
//!   bucket-derived `p50`/`p99`, O(1) memory forever. The legacy
//!   [`crate::metrics::Counters`] remains as the string-keyed *view*
//!   (`counters()` on each owner) for rendering and tests; hot paths
//!   never touch it (CI greps `serve/ net/ persist/` for string-keyed
//!   increments).
//! * **What is instrumented.** Shard rounds time their phases —
//!   plan (outlier nomination), WAL append, fused inc/dec, publish —
//!   plus round latency; the micro-batch window records occupancy and
//!   per-[`QueryKind`] lane latency; the reactor counts
//!   accept/shed/serve/protocol-error events; the store times WAL
//!   appends and checkpoints; probes feed a residual-trend histogram
//!   (pico-units). Registries merge upward:
//!   [`RouterHandle::telemetry`] folds router + every shard into one
//!   [`crate::telemetry::TelemetrySnapshot`] fleet view.
//! * **Flight recorder.** Each shard and the reactor keep a
//!   fixed-capacity ring of POD span events
//!   ([`crate::telemetry::FlightRecorder`]: round start/end, WAL, inc/dec,
//!   publish, rollback, retry, probe, quarantine, heal, shed, accept...).
//!   Recording is a 25-byte struct store into a pre-reserved ring. The
//!   ring is frozen into a labeled [`crate::telemetry::FlightDump`] at
//!   failure boundaries — shard quarantine
//!   ([`ShardSupervisor::flight_dumps`]) and crash recovery
//!   ([`ShardRouter::recovery_flight_dumps`]) — so every post-mortem
//!   ships with the event trail that led into it.
//!
//! On the wire, the `MKTL` stats frame ([`crate::net::NetClient::stats`])
//! carries the canonical snapshot encoding — deterministic, so two pulls
//! against an idle server are byte-identical; the pull path itself
//! records nothing. `TelemetrySnapshot::render_text` / `write_json` are
//! the human/machine exposition formats, and the
//! `serve/telemetry_overhead` microbench gates the instrumented round at
//! ≤ 3% over a [`crate::telemetry::Registry::disabled`] baseline.
//!
//! Chaos coverage: the `chaos` cargo feature compiles in seeded fault
//! hooks ([`crate::health::fault::FaultPlan`]) and
//! `rust/tests/chaos_suite.rs` drives NaN rows, poison batches, forced
//! failures, wedged shards, and corrupted inverses across a seed matrix
//! (see EXPERIMENTS.md §Robustness). The durability half lives in
//! `rust/tests/recovery_kill_matrix.rs`: deterministic kill points at
//! every persist write/fsync/rename boundary
//! ([`crate::health::fault::KillPoint`]), with recovered predictions
//! checked against an uninterrupted control run at every point (see
//! EXPERIMENTS.md §Durability).

pub mod microbatch;
pub mod publish;
pub mod query;
pub mod router;
pub mod shard;
pub mod supervisor;

pub use microbatch::{MicroBatchPolicy, MicroBatchServer, MicroBatchStats, PredictClient};
pub use publish::{Epoch, HealthCell, ShardStatus};
pub use query::{PredictRequest, PredictResponse, QueryKind};
pub use router::{
    Placement, RoundReport, RouterHandle, RouterPredictWork, ServeConfig, ShardRouter,
};
pub use shard::{Shard, SnapshotHandle, SnapshotQueryWork};
pub use supervisor::{
    QuarantinedBatch, RetryPolicy, ShardSupervisor, SupervisorConfig,
};
