//! The sharded serving layer: multi-engine shard routing with
//! micro-batched prediction traffic.
//!
//! PRs 1–4 made the per-update compute core fully packed and parallel, so
//! at serving scale the bottleneck moved **above** the engine: one
//! maintained inverse behind one `RwLock` serializes every update against
//! every read. This subsystem is the first where the headline metric is
//! **throughput under concurrent updates** (requests/sec), not per-op
//! latency, and it attacks the lock three ways:
//!
//! * **Sharding** ([`router`]) — the stream is partitioned across K
//!   independent [`crate::coordinator::engine::Engine`] replicas
//!   (round-robin or content-hash placement, per-shard batching), so each
//!   shard runs the paper's fused inc/dec update (eq. 15 / eq. 30) on
//!   1/K-sized state. Reads average the shard predictions — the
//!   divide-and-conquer KRR estimator (You et al.) — and fuse the KBR
//!   twins' posteriors by precision weighting. Bounding each shard's
//!   working set is the same lever StreaMRAK pulls to keep streaming
//!   kernel regression scalable.
//! * **Epoch publishing** ([`publish`], [`shard`]) — every shard update
//!   lands as an immutable `Arc` snapshot; readers serve the last
//!   published epoch and *never* contend with the writer. An in-flight
//!   update delays freshness by one epoch instead of blocking the read
//!   fleet (the `RwLock` pattern it replaces did the opposite).
//! * **Micro-batching** ([`microbatch`]) — concurrent single-row predict
//!   requests coalesce into one batched `predict_into` per shard:
//!   per-request GEMVs become one packed BLAS-3 product, and the warm
//!   workspaces make the steady-state read path allocation-free.
//!
//! Bench coverage lives in `rust/benches/microbench.rs` (`serve/*`:
//! micro-batched GEMM predict vs per-request GEMV, K=1 vs K=4 update
//! rounds) with the `speedup_serve_microbatch` headline wired into the CI
//! perf gate; see EXPERIMENTS.md §Perf and `examples/serve_shard.rs` for
//! the end-to-end drive.

pub mod microbatch;
pub mod publish;
pub mod router;
pub mod shard;

pub use microbatch::{MicroBatchPolicy, MicroBatchServer, MicroBatchStats, PredictClient};
pub use publish::Epoch;
pub use router::{
    Placement, RoundReport, RouterHandle, RouterPredictWork, ServeConfig, ShardRouter,
};
pub use shard::{Shard, SnapshotHandle};
