//! Micro-batched prediction front-end: concurrent predict requests are
//! coalesced into per-[`QueryKind`] batched reads against the router.
//!
//! A request fleet issuing individual predictions pays a per-request
//! GEMV — for the KBR twin an O(J²) covariance product *per request* —
//! plus per-call allocation and dispatch overhead. The micro-batcher
//! collects whatever requests arrive within a short window (or until
//! `max_rows` rows are pending) and executes them through [`QueryLanes`]:
//! each [`QueryKind`] present in the window gets ONE batched
//! [`RouterHandle::query_into`] over exactly its own rows — the covariance
//! product becomes a single (J, J)·(J, B) packed GEMM above the dispatch
//! crossover, the feature map and cross-Gram builds amortize across the
//! sub-batch, and the worker's warm [`RouterPredictWork`] keeps the whole
//! serving loop allocation-free (measured in `rust/tests/alloc_count.rs`).
//!
//! Per-kind sub-batching (instead of the four historical passes over the
//! full window) preserves the estimator-separation invariant for free: a
//! `Mean` request is answered by the KRR point path and never shares an
//! execution with the KBR posterior rows it happened to coalesce with.
//! The same lanes are driven directly by the network reactor
//! ([`crate::net`]), so socket traffic and in-process clients share one
//! batch-execution core.
//!
//! The batching window trades tail latency for throughput exactly like the
//! update-side [`crate::streaming::batcher`]: `max_wait` bounds the added
//! latency, `max_rows` bounds the batch.

use crate::error::{Error, PersistDetail, Result};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::query::{PredictRequest, PredictResponse, QueryKind};
use super::router::{RouterHandle, RouterPredictWork};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::telemetry::{HistId, MetricId, Registry};

/// Per-lane latency histogram, indexed by [`QueryKind::lane`] (the
/// [`QueryKind::ALL`] order: Mean, MeanMulti, MeanVar, MeanVarMulti).
const LANE_HIST: [HistId; 4] = [
    HistId::LaneMeanUs,
    HistId::LaneMeanMultiUs,
    HistId::LaneMeanVarUs,
    HistId::LaneMeanVarMultiUs,
];

/// Batching policy for the prediction front-end.
#[derive(Clone, Debug)]
pub struct MicroBatchPolicy {
    /// Execute once this many rows are pending.
    pub max_rows: usize,
    /// Execute once the first pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for MicroBatchPolicy {
    fn default() -> Self {
        // 64 rows puts the J=253 KBR covariance product over the packed
        // dispatch crossover; 200us keeps the added latency below typical
        // network jitter
        Self { max_rows: 64, max_wait: Duration::from_micros(200) }
    }
}

/// One per-[`QueryKind`] sub-batch: the rows that joined this window for
/// that kind, the batched response, and the pass error if the kind failed.
#[derive(Default)]
struct QueryLane {
    xb: Mat,
    resp: PredictResponse,
    err: Option<Error>,
}

/// The shared batch-execution core: four [`QueryLane`]s (one per
/// [`QueryKind`]) over one warm [`RouterPredictWork`].
///
/// Both front-ends drive it the same way — `reset`, `push_rows` per
/// request (remembering the returned start row), `execute`, then slice
/// each caller's answer back out of its kind's lane. A kind's query runs
/// over ONLY that kind's rows; a failing kind poisons its own lane and no
/// other. `pub(crate)` so the network reactor batches socket requests
/// through the exact same code the in-process server uses.
#[derive(Default)]
pub(crate) struct QueryLanes {
    lanes: [QueryLane; 4],
    work: RouterPredictWork,
    dim: usize,
}

impl QueryLanes {
    /// Lanes for `dim`-column query rows.
    pub fn new(dim: usize) -> Self {
        Self { dim, ..Self::default() }
    }

    /// Clear every lane for a new window (buffers stay warm).
    pub fn reset(&mut self) {
        for lane in &mut self.lanes {
            lane.xb.resize_scratch(0, self.dim);
            lane.err = None;
        }
    }

    /// Append `x`'s rows to `want`'s lane; returns the start row the
    /// caller must remember to slice its reply back out. Callers validate
    /// `x.cols() == dim` first.
    pub fn push_rows(&mut self, want: QueryKind, x: &Mat) -> usize {
        let lane = &mut self.lanes[want.lane()];
        let start = lane.xb.rows();
        lane.xb.push_rows(x).expect("caller validates request dims");
        start
    }

    /// Total rows pending across all lanes.
    pub fn total_rows(&self) -> usize {
        self.lanes.iter().map(|l| l.xb.rows()).sum()
    }

    /// Run ONE batched router query per non-empty lane. Transient
    /// failures are retried once (see [`retry_once`]); the outcome lands
    /// in the lane for [`QueryLanes::reply_for`] / [`QueryLanes::lane_result`].
    ///
    /// `telemetry` records the window occupancy and one latency sample
    /// per executed lane — relaxed atomics on the warm path, no
    /// allocation (pass [`Registry::disabled`] to opt out entirely).
    pub fn execute(&mut self, handle: &RouterHandle, telemetry: &Registry) {
        let Self { lanes, work, .. } = self;
        let occupancy: usize = lanes.iter().map(|l| l.xb.rows()).sum();
        telemetry.record_hist(HistId::WindowOccupancyRows, occupancy as u64);
        for (i, lane) in lanes.iter_mut().enumerate() {
            if lane.xb.rows() == 0 {
                continue;
            }
            let want = QueryKind::ALL[i];
            let t = Timer::start();
            lane.err =
                retry_once(|| handle.query_inner(&lane.xb, want, &mut lane.resp, work));
            telemetry.record_secs(LANE_HIST[i], t.elapsed());
        }
    }

    /// Borrow a lane's batched outcome (the reactor encodes reply frames
    /// straight from this, no per-request materialization).
    pub fn lane_result(&self, want: QueryKind) -> std::result::Result<&PredictResponse, &Error> {
        let lane = &self.lanes[want.lane()];
        match &lane.err {
            Some(e) => Err(e),
            None => Ok(&lane.resp),
        }
    }

    /// Materialize one caller's reply: rows `[start, start + rows)` of
    /// `want`'s lane as an owned response (channel replies transfer
    /// ownership to the client thread).
    pub fn reply_for(&self, want: QueryKind, start: usize, rows: usize) -> Result<PredictResponse> {
        match self.lane_result(want) {
            Err(e) => Err(replicate(e)),
            Ok(resp) => Ok(PredictResponse {
                mean: resp.mean.block(start, start + rows, 0, resp.mean.cols()),
                variance: resp.variance.as_ref().map(|v| v[start..start + rows].to_vec()),
            }),
        }
    }
}

type Reply = Result<PredictResponse>;

struct Request {
    req: PredictRequest,
    resp: SyncSender<Reply>,
}

/// Worker inbox message: a request, or the server's stop marker (clients
/// hold sender clones, so channel disconnect alone cannot signal
/// shutdown while any client is alive).
enum Msg {
    Req(Request),
    Shutdown,
}

/// Worker-side statistics, returned by [`MicroBatchServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct MicroBatchStats {
    /// Batched executions performed.
    pub batches: u64,
    /// Requests served (including per-request errors).
    pub requests: u64,
    /// Largest window coalesced, in rows.
    pub max_batch_rows: usize,
}

/// A blocking client onto the micro-batch server. Each client owns its
/// response channel, so it is cheap and single-threaded by construction —
/// mint one per request thread via [`MicroBatchServer::client`].
pub struct PredictClient {
    tx: SyncSender<Msg>,
    resp_tx: SyncSender<Reply>,
    resp_rx: Receiver<Reply>,
}

impl PredictClient {
    /// Run one [`PredictRequest`] — blocks until the window it joined
    /// executes. Multi-row requests coalesce like everything else; the
    /// reply covers exactly this request's rows.
    pub fn query(&mut self, req: PredictRequest) -> Result<PredictResponse> {
        let req = Request { req, resp: self.resp_tx.clone() };
        self.tx
            .send(Msg::Req(req))
            .map_err(|_| Error::Stream("prediction server is down".into()))?;
        self.resp_rx
            .recv()
            .map_err(|_| Error::Stream("prediction server dropped the request".into()))?
    }

    /// Predict one observation (`D = 1`).
    #[deprecated(since = "0.4.0", note = "use PredictClient::query with QueryKind::Mean")]
    pub fn predict(&mut self, x: &[f64]) -> Result<f64> {
        let resp = self.query(PredictRequest::single(x, QueryKind::Mean))?;
        Ok(resp.scalar())
    }

    /// Predict one observation with predictive variance (requires the
    /// shards' KBR twins; `D = 1`).
    #[deprecated(since = "0.4.0", note = "use PredictClient::query with QueryKind::MeanVar")]
    pub fn predict_with_uncertainty(&mut self, x: &[f64]) -> Result<(f64, f64)> {
        let resp = self.query(PredictRequest::single(x, QueryKind::MeanVar))?;
        Ok((resp.scalar(), resp.variance_at(0)))
    }

    /// Predict all D output columns for one observation.
    #[deprecated(since = "0.4.0", note = "use PredictClient::query with QueryKind::MeanMulti")]
    pub fn predict_multi(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        let resp = self.query(PredictRequest::single(x, QueryKind::MeanMulti))?;
        Ok(resp.mean.row(0).to_vec())
    }

    /// Predict all D output columns plus the shared predictive variance
    /// for one observation (requires the shards' KBR twins).
    #[deprecated(
        since = "0.4.0",
        note = "use PredictClient::query with QueryKind::MeanVarMulti"
    )]
    pub fn predict_with_uncertainty_multi(&mut self, x: &[f64]) -> Result<(Vec<f64>, f64)> {
        let resp = self.query(PredictRequest::single(x, QueryKind::MeanVarMulti))?;
        Ok((resp.mean.row(0).to_vec(), resp.variance_at(0)))
    }
}

/// The micro-batching prediction server: one worker thread coalescing
/// requests into batched reads against the router's published epochs.
pub struct MicroBatchServer {
    tx: Option<SyncSender<Msg>>,
    worker: Option<JoinHandle<MicroBatchStats>>,
    telemetry: Arc<Registry>,
}

impl MicroBatchServer {
    /// Spawn the worker over a router read handle. `dim` is the feature
    /// dimension every request row must have.
    pub fn spawn(handle: RouterHandle, dim: usize, policy: MicroBatchPolicy) -> Self {
        assert!(policy.max_rows >= 1, "max_rows must be >= 1");
        let telemetry = Arc::new(Registry::new());
        let reg = Arc::clone(&telemetry);
        let (tx, rx) = sync_channel::<Msg>(policy.max_rows.saturating_mul(4).max(16));
        let worker = std::thread::spawn(move || worker_loop(handle, dim, policy, rx, &reg));
        Self { tx: Some(tx), worker: Some(worker), telemetry }
    }

    /// The front-end's metrics registry: window sizes, per-lane latency
    /// histograms, and the batch/request counters, live while the worker
    /// runs (unlike [`MicroBatchServer::shutdown`]'s final stats).
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Mint a client (one per request thread).
    pub fn client(&self) -> PredictClient {
        let (resp_tx, resp_rx) = sync_channel(1);
        let tx = self.tx.as_ref().expect("server already shut down").clone();
        PredictClient { tx, resp_tx, resp_rx }
    }

    /// Stop the worker — it serves the batch in flight, drops any requests
    /// queued behind the stop marker (their clients get a "dropped the
    /// request" error), and returns its statistics. Works with clients
    /// still alive (they hold sender clones, so this cannot rely on
    /// channel disconnect); once the worker exits, every later client call
    /// gets a "server is down" error.
    pub fn shutdown(mut self) -> MicroBatchStats {
        self.signal_stop();
        self.worker
            .take()
            .expect("server already shut down")
            .join()
            .expect("microbatch worker panicked")
    }

    fn signal_stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
    }
}

impl Drop for MicroBatchServer {
    fn drop(&mut self) {
        self.signal_stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    handle: RouterHandle,
    dim: usize,
    policy: MicroBatchPolicy,
    rx: Receiver<Msg>,
    telemetry: &Registry,
) -> MicroBatchStats {
    let mut stats = MicroBatchStats::default();
    let mut batch: Vec<Request> = Vec::with_capacity(policy.max_rows);
    let mut lanes = QueryLanes::new(dim);
    let mut valid: Vec<(Request, usize)> = Vec::with_capacity(policy.max_rows);
    let mut stopping = false;
    while !stopping {
        // block for the first request of the batch
        let mut rows_pending = match rx.recv() {
            Ok(Msg::Req(first)) => {
                let rows = first.req.x.rows();
                batch.push(first);
                rows
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        // coalesce until the window closes, the batch fills, the server
        // signals shutdown, or every sender is gone
        let deadline = Instant::now() + policy.max_wait;
        while rows_pending < policy.max_rows {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(Msg::Req(req)) => {
                    rows_pending += req.req.x.rows();
                    batch.push(req);
                }
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
            }
        }
        let served = serve_batch(&handle, dim, &mut batch, &mut lanes, &mut valid, telemetry);
        stats.requests += served as u64;
        stats.max_batch_rows = stats.max_batch_rows.max(rows_pending);
        stats.batches += 1;
        telemetry.inc(MetricId::Batches);
        telemetry.add(MetricId::Requests, served as u64);
        telemetry.gauge_max(MetricId::MaxBatchRows, rows_pending as u64);
    }
    stats
}

/// Run one coalesced window: validate shapes, push every request's rows
/// onto its kind's lane, execute ONE batched router query per kind
/// present, and slice replies back out. A `Mean` request is ALWAYS
/// answered from the KRR point path and a `MeanVar` request from the KBR
/// posterior fan-in — per-kind lanes make crossing estimators structurally
/// impossible. Returns the number of requests replied to (including error
/// replies).
fn serve_batch(
    handle: &RouterHandle,
    dim: usize,
    batch: &mut Vec<Request>,
    lanes: &mut QueryLanes,
    valid: &mut Vec<(Request, usize)>,
    telemetry: &Registry,
) -> usize {
    let total = batch.len();
    lanes.reset();
    valid.clear();
    for r in batch.drain(..) {
        if r.req.x.cols() != dim || r.req.x.rows() == 0 {
            let msg = format!(
                "request batch is {}x{}, expected (>=1, {dim})",
                r.req.x.rows(),
                r.req.x.cols()
            );
            let _ = r.resp.send(Err(Error::shape("microbatch", msg)));
            continue;
        }
        let start = lanes.push_rows(r.req.want, &r.req.x);
        valid.push((r, start));
    }
    if valid.is_empty() {
        return total;
    }
    lanes.execute(handle, telemetry);
    for (r, start) in valid.drain(..) {
        let reply = lanes.reply_for(r.req.want, start, r.req.x.rows());
        let _ = r.resp.send(reply);
    }
    total
}

/// Run one predict pass, retrying it exactly once when the failure is
/// transient ([`Error::is_transient`]): the read path is stateless over a
/// published epoch, so a second attempt against the (possibly newer)
/// snapshot is safe and often lands after a mid-read republish or heal.
/// Permanent errors (shape, config) are returned immediately — retrying
/// cannot change them.
pub(crate) fn retry_once(mut pass: impl FnMut() -> Result<()>) -> Option<Error> {
    match pass() {
        Ok(()) => None,
        Err(e) if e.is_transient() => pass().err(),
        Err(e) => Some(e),
    }
}

/// Re-materialize a pass error for each affected request. [`Error`] is not
/// `Clone` (its `Io` variant wraps `std::io::Error`), but preserving the
/// variant matters to clients: a permanent `Config` problem (no KBR twin)
/// must stay distinguishable from a transient transport failure.
pub(crate) fn replicate(e: &Error) -> Error {
    match e {
        Error::Shape { context, detail } => {
            Error::Shape { context: *context, detail: detail.clone() }
        }
        Error::Numerical { context, detail } => {
            Error::Numerical { context: *context, detail: detail.clone() }
        }
        Error::InvalidUpdate(m) => Error::InvalidUpdate(m.clone()),
        Error::Config(m) => Error::Config(m.clone()),
        Error::Artifact(m) => Error::Artifact(m.clone()),
        Error::Runtime(m) => Error::Runtime(m.clone()),
        Error::Stream(m) => Error::Stream(m.clone()),
        Error::Io(io) => Error::Stream(format!("io error: {io}")),
        // transient/permanent split of Persist survives replication:
        // Io -> Stream (both transient), Corruption stays permanent
        Error::Persist { context, detail } => match detail {
            PersistDetail::Io(io) => {
                Error::Stream(format!("persist io error in {context}: {io}"))
            }
            PersistDetail::Corruption(d) => Error::persist_corruption(context, d.clone()),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernels::Kernel;
    use crate::serve::router::{ServeConfig, ShardRouter};

    fn router(uncertainty: bool) -> ShardRouter {
        let d = synth::ecg_like(60, 5, 1);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = uncertainty;
        ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap()
    }

    fn direct(h: &RouterHandle, x: &Mat, want: QueryKind) -> PredictResponse {
        h.query(&PredictRequest::new(x.clone(), want)).unwrap()
    }

    fn single_query(
        client: &mut PredictClient,
        row: &[f64],
        want: QueryKind,
    ) -> Result<PredictResponse> {
        client.query(PredictRequest::single(row, want))
    }

    #[test]
    fn single_requests_match_batched_read_path() {
        let r = router(false);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(6, 5, 2);
        let want = QueryKind::Mean;
        let d = direct(&h, &q.x, want);
        for i in 0..6 {
            let got = single_query(&mut client, q.x.row(i), want).unwrap();
            crate::testutil::assert_close(got.scalar(), d.mean[(i, 0)], 1e-9);
            assert!(got.variance.is_none());
        }
        drop(client);
        let stats = server.shutdown();
        assert!(stats.batches >= 1);
    }

    #[test]
    fn uncertainty_requests_round_trip() {
        let r = router(true);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(4, 5, 3);
        let d = direct(&h, &q.x, QueryKind::MeanVar);
        for i in 0..4 {
            let got = single_query(&mut client, q.x.row(i), QueryKind::MeanVar).unwrap();
            crate::testutil::assert_close(got.scalar(), d.mean[(i, 0)], 1e-9);
            crate::testutil::assert_close(got.variance_at(0), d.variance_at(i), 1e-9);
            assert!(got.variance_at(0) > 0.0);
        }
    }

    #[test]
    fn multi_row_requests_slice_their_own_window() {
        let r = router(false);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(6, 5, 9);
        let d = direct(&h, &q.x, QueryKind::Mean);
        // one request carrying all 6 rows comes back as one (6, 1) answer
        let got = client.query(PredictRequest::new(q.x.clone(), QueryKind::Mean)).unwrap();
        assert_eq!(got.mean.shape(), (6, 1));
        crate::testutil::assert_vec_close(got.mean.as_slice(), d.mean.as_slice(), 1e-12);
    }

    #[test]
    fn mixed_batches_keep_estimators_separate() {
        // a Mean request coalesced with a MeanVar request must still be
        // answered by the KRR point predictor, not the KBR posterior mean
        let r = router(true);
        let h = r.handle();
        let q = synth::ecg_like(2, 5, 6);
        let dmean = direct(&h, &q.x, QueryKind::Mean);
        let dvar = direct(&h, &q.x, QueryKind::MeanVar);
        // max_rows 2 + a generous window forces the two concurrent
        // requests into one batch
        let server = MicroBatchServer::spawn(
            h,
            5,
            MicroBatchPolicy { max_rows: 2, max_wait: Duration::from_secs(1) },
        );
        let mut c1 = server.client();
        let mut c2 = server.client();
        let row0 = q.x.row(0).to_vec();
        let t = std::thread::spawn(move || {
            single_query(&mut c1, &row0, QueryKind::Mean).unwrap().scalar()
        });
        let got = single_query(&mut c2, q.x.row(1), QueryKind::MeanVar).unwrap();
        let m0 = t.join().unwrap();
        crate::testutil::assert_close(m0, dmean.mean[(0, 0)], 1e-9);
        crate::testutil::assert_close(got.scalar(), dvar.mean[(1, 0)], 1e-9);
        crate::testutil::assert_close(got.variance_at(0), dvar.variance_at(1), 1e-9);
    }

    #[test]
    fn wrong_dim_and_missing_twin_error_cleanly() {
        let r = router(false);
        let server = MicroBatchServer::spawn(r.handle(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let e = single_query(&mut client, &[1.0, 2.0], QueryKind::Mean).unwrap_err();
        assert!(matches!(e, Error::Shape { .. }), "wrong dim: {e:?}");
        // mean requests still work after an error reply
        let q = synth::ecg_like(1, 5, 4);
        assert!(single_query(&mut client, q.x.row(0), QueryKind::Mean).is_ok());
        // no KBR twin: variance requests get the Config error (variant
        // preserved through replicate()), without killing the server
        let err = single_query(&mut client, q.x.row(0), QueryKind::MeanVar).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "got {err:?}");
        assert!(single_query(&mut client, q.x.row(0), QueryKind::Mean).is_ok());
    }

    #[test]
    fn shutdown_with_live_clients_does_not_deadlock() {
        let r = router(false);
        let server = MicroBatchServer::spawn(r.handle(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(1, 5, 7);
        assert!(single_query(&mut client, q.x.row(0), QueryKind::Mean).is_ok());
        // the client still holds a live sender: shutdown must not rely on
        // channel disconnect to stop the worker
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert!(
            single_query(&mut client, q.x.row(0), QueryKind::Mean).is_err(),
            "post-shutdown calls error"
        );
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let r = router(false);
        let h = r.handle();
        let server = MicroBatchServer::spawn(
            h.clone(),
            5,
            MicroBatchPolicy { max_rows: 16, max_wait: Duration::from_millis(20) },
        );
        let q = synth::ecg_like(24, 5, 5);
        let d = direct(&h, &q.x, QueryKind::Mean);
        let mut joins = Vec::new();
        for t in 0..3 {
            let mut client = server.client();
            let rows: Vec<Vec<f64>> =
                (0..8).map(|i| q.x.row(t * 8 + i).to_vec()).collect();
            joins.push(std::thread::spawn(move || {
                rows.iter()
                    .map(|r| single_query(&mut client, r, QueryKind::Mean).unwrap().scalar())
                    .collect::<Vec<f64>>()
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let got = j.join().unwrap();
            crate::testutil::assert_vec_close(
                &got,
                &d.mean.as_slice()[t * 8..(t + 1) * 8],
                1e-9,
            );
        }
        let telemetry = Arc::clone(server.telemetry());
        let stats = server.shutdown();
        assert_eq!(stats.requests, 24);
        assert!(stats.batches <= 24, "some coalescing expected under load");
        // the registry view agrees with the worker's returned stats
        assert_eq!(telemetry.get(MetricId::Requests), 24);
        assert_eq!(telemetry.get(MetricId::Batches), stats.batches);
        assert_eq!(telemetry.get(MetricId::MaxBatchRows), stats.max_batch_rows as u64);
        let occ = telemetry.snapshot();
        assert_eq!(
            occ.hist(HistId::WindowOccupancyRows).count,
            stats.batches,
            "one occupancy sample per executed window"
        );
        assert!(occ.hist(HistId::LaneMeanUs).count >= 1, "Mean lane latency sampled");
    }

    fn router_multi(uncertainty: bool) -> ShardRouter {
        let d = synth::ecg_like(60, 5, 1);
        let y = Mat::from_fn(60, 2, |i, j| if j == 0 { d.y[i] } else { 2.0 * d.y[i] - 0.5 });
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = uncertainty;
        ShardRouter::bootstrap_multi(&d.x, &y, cfg).unwrap()
    }

    #[test]
    fn multi_output_requests_round_trip() {
        let r = router_multi(true);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(4, 5, 8);
        let dm = direct(&h, &q.x, QueryKind::MeanMulti);
        let dmv = direct(&h, &q.x, QueryKind::MeanVarMulti);
        for i in 0..4 {
            let got = single_query(&mut client, q.x.row(i), QueryKind::MeanMulti).unwrap();
            assert_eq!(got.mean.shape(), (1, 2));
            crate::testutil::assert_vec_close(got.mean.row(0), dm.mean.row(i), 1e-9);
            let gv = single_query(&mut client, q.x.row(i), QueryKind::MeanVarMulti).unwrap();
            crate::testutil::assert_vec_close(gv.mean.row(0), dmv.mean.row(i), 1e-9);
            crate::testutil::assert_close(gv.variance_at(0), dmv.variance_at(i), 1e-9);
        }
        // scalar requests against a D=2 deployment error cleanly (D=1
        // guard propagates through the coalesced batch) without killing
        // concurrent multi traffic
        let err = single_query(&mut client, q.x.row(0), QueryKind::Mean).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "got {err:?}");
        assert!(single_query(&mut client, q.x.row(0), QueryKind::MeanMulti).is_ok());
    }

    /// The deprecated per-flavor client methods are views of `query`.
    #[test]
    #[allow(deprecated)]
    fn deprecated_client_shims_still_serve() {
        let r = router(true);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(2, 5, 11);
        let dmean = direct(&h, &q.x, QueryKind::Mean);
        let dvar = direct(&h, &q.x, QueryKind::MeanVar);
        let m = client.predict(q.x.row(0)).unwrap();
        crate::testutil::assert_close(m, dmean.mean[(0, 0)], 1e-9);
        let (mu, v) = client.predict_with_uncertainty(q.x.row(1)).unwrap();
        crate::testutil::assert_close(mu, dvar.mean[(1, 0)], 1e-9);
        crate::testutil::assert_close(v, dvar.variance_at(1), 1e-9);
    }
}
