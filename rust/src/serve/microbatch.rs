//! Micro-batched prediction front-end: concurrent single-row predict
//! requests are coalesced into one batched predict per shard.
//!
//! A request fleet issuing individual predictions pays a per-request
//! GEMV — for the KBR twin an O(J²) covariance product *per request* —
//! plus per-call allocation and dispatch overhead. The micro-batcher
//! collects whatever requests arrive within a short window (or until
//! `max_rows`) and executes them as ONE batched `predict_into` through the
//! router: the covariance product becomes a single (J, J)·(J, B) packed
//! GEMM above the dispatch crossover, the feature map and cross-Gram
//! builds amortize across the batch, and the worker's warm
//! [`RouterPredictWork`] keeps the whole serving loop allocation-free
//! (measured in `rust/tests/alloc_count.rs` on the `predict_into` paths).
//!
//! The batching window trades tail latency for throughput exactly like the
//! update-side [`crate::streaming::batcher`]: `max_wait` bounds the added
//! latency, `max_rows` bounds the batch.

use crate::error::{Error, Result};
use crate::linalg::Mat;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::router::{RouterHandle, RouterPredictWork};

/// Batching policy for the prediction front-end.
#[derive(Clone, Debug)]
pub struct MicroBatchPolicy {
    /// Execute once this many rows are pending.
    pub max_rows: usize,
    /// Execute once the first pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for MicroBatchPolicy {
    fn default() -> Self {
        // 64 rows puts the J=253 KBR covariance product over the packed
        // dispatch crossover; 200us keeps the added latency below typical
        // network jitter
        Self { max_rows: 64, max_wait: Duration::from_micros(200) }
    }
}

/// What a request wants back.
#[derive(Clone, Copy)]
enum Want {
    Mean,
    MeanVar,
    MeanMulti,
    MeanVarMulti,
}

/// Reply payload: scalar replies stay allocation-free on the send side;
/// multi-output replies carry the request's D-column mean row.
enum ReplyBody {
    Scalar(f64, Option<f64>),
    Multi(Vec<f64>, Option<f64>),
}

type Reply = Result<ReplyBody>;

struct Request {
    x: Vec<f64>,
    want: Want,
    resp: SyncSender<Reply>,
}

/// Worker inbox message: a request, or the server's stop marker (clients
/// hold sender clones, so channel disconnect alone cannot signal
/// shutdown while any client is alive).
enum Msg {
    Req(Request),
    Shutdown,
}

/// Worker-side statistics, returned by [`MicroBatchServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct MicroBatchStats {
    /// Batched executions performed.
    pub batches: u64,
    /// Requests served (including per-request errors).
    pub requests: u64,
    /// Largest batch coalesced.
    pub max_batch_rows: usize,
}

/// A blocking client onto the micro-batch server. Each client owns its
/// response channel, so it is cheap and single-threaded by construction —
/// mint one per request thread via [`MicroBatchServer::client`].
pub struct PredictClient {
    tx: SyncSender<Msg>,
    resp_tx: SyncSender<Reply>,
    resp_rx: Receiver<Reply>,
}

impl PredictClient {
    /// Predict one observation (blocks until the batch it joined runs;
    /// `D = 1` — errors on a multi-output deployment).
    pub fn predict(&mut self, x: &[f64]) -> Result<f64> {
        match self.call(x, Want::Mean)? {
            ReplyBody::Scalar(m, _) => Ok(m),
            ReplyBody::Multi(..) => unreachable!("Mean requests get scalar replies"),
        }
    }

    /// Predict one observation with predictive variance (requires the
    /// shards' KBR twins; `D = 1`).
    pub fn predict_with_uncertainty(&mut self, x: &[f64]) -> Result<(f64, f64)> {
        match self.call(x, Want::MeanVar)? {
            ReplyBody::Scalar(m, v) => {
                Ok((m, v.expect("MeanVar reply carries a variance")))
            }
            ReplyBody::Multi(..) => unreachable!("MeanVar requests get scalar replies"),
        }
    }

    /// Predict all D output columns for one observation. Coalesced multi
    /// requests are answered as ONE packed `(B, D)` round through the
    /// router.
    pub fn predict_multi(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        match self.call(x, Want::MeanMulti)? {
            ReplyBody::Multi(m, _) => Ok(m),
            ReplyBody::Scalar(..) => unreachable!("MeanMulti requests get multi replies"),
        }
    }

    /// Predict all D output columns plus the shared predictive variance
    /// for one observation (requires the shards' KBR twins).
    pub fn predict_with_uncertainty_multi(&mut self, x: &[f64]) -> Result<(Vec<f64>, f64)> {
        match self.call(x, Want::MeanVarMulti)? {
            ReplyBody::Multi(m, v) => {
                Ok((m, v.expect("MeanVarMulti reply carries a variance")))
            }
            ReplyBody::Scalar(..) => {
                unreachable!("MeanVarMulti requests get multi replies")
            }
        }
    }

    fn call(&mut self, x: &[f64], want: Want) -> Reply {
        let req = Request { x: x.to_vec(), want, resp: self.resp_tx.clone() };
        self.tx
            .send(Msg::Req(req))
            .map_err(|_| Error::Stream("prediction server is down".into()))?;
        self.resp_rx
            .recv()
            .map_err(|_| Error::Stream("prediction server dropped the request".into()))?
    }
}

/// The micro-batching prediction server: one worker thread coalescing
/// requests into batched reads against the router's published epochs.
pub struct MicroBatchServer {
    tx: Option<SyncSender<Msg>>,
    worker: Option<JoinHandle<MicroBatchStats>>,
}

impl MicroBatchServer {
    /// Spawn the worker over a router read handle. `dim` is the feature
    /// dimension every request row must have.
    pub fn spawn(handle: RouterHandle, dim: usize, policy: MicroBatchPolicy) -> Self {
        assert!(policy.max_rows >= 1, "max_rows must be >= 1");
        let (tx, rx) = sync_channel::<Msg>(policy.max_rows.saturating_mul(4).max(16));
        let worker = std::thread::spawn(move || worker_loop(handle, dim, policy, rx));
        Self { tx: Some(tx), worker: Some(worker) }
    }

    /// Mint a client (one per request thread).
    pub fn client(&self) -> PredictClient {
        let (resp_tx, resp_rx) = sync_channel(1);
        let tx = self.tx.as_ref().expect("server already shut down").clone();
        PredictClient { tx, resp_tx, resp_rx }
    }

    /// Stop the worker — it serves the batch in flight, drops any requests
    /// queued behind the stop marker (their clients get a "dropped the
    /// request" error), and returns its statistics. Works with clients
    /// still alive (they hold sender clones, so this cannot rely on
    /// channel disconnect); once the worker exits, every later client call
    /// gets a "server is down" error.
    pub fn shutdown(mut self) -> MicroBatchStats {
        self.signal_stop();
        self.worker
            .take()
            .expect("server already shut down")
            .join()
            .expect("microbatch worker panicked")
    }

    fn signal_stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
    }
}

impl Drop for MicroBatchServer {
    fn drop(&mut self) {
        self.signal_stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The worker's reusable batch-execution buffers (warm across batches, so
/// steady-state serving is allocation-free).
#[derive(Default)]
struct BatchBuffers {
    xb: Mat,
    work: RouterPredictWork,
    /// Validated requests of the batch being served (capacity retained).
    valid: Vec<Request>,
    /// KRR point predictions (the `predict` estimator).
    mean: Vec<f64>,
    /// KBR posterior-fan-in means (a DIFFERENT estimator — never used to
    /// answer a plain `predict` request).
    kmean: Vec<f64>,
    var: Vec<f64>,
    /// Multi-output twins of the three buffers above, (B, D).
    mean_mat: Mat,
    kmean_mat: Mat,
    var_multi: Vec<f64>,
}

fn worker_loop(
    handle: RouterHandle,
    dim: usize,
    policy: MicroBatchPolicy,
    rx: Receiver<Msg>,
) -> MicroBatchStats {
    let mut stats = MicroBatchStats::default();
    let mut batch: Vec<Request> = Vec::with_capacity(policy.max_rows);
    let mut buf = BatchBuffers::default();
    let mut stopping = false;
    while !stopping {
        // block for the first request of the batch
        match rx.recv() {
            Ok(Msg::Req(first)) => batch.push(first),
            Ok(Msg::Shutdown) | Err(_) => break,
        }
        // coalesce until the window closes, the batch fills, the server
        // signals shutdown, or every sender is gone
        let deadline = Instant::now() + policy.max_wait;
        while batch.len() < policy.max_rows {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(Msg::Req(req)) => batch.push(req),
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
            }
        }
        let rows = batch.len();
        let served = serve_batch(&handle, dim, &mut batch, &mut buf);
        stats.requests += served as u64;
        stats.max_batch_rows = stats.max_batch_rows.max(rows);
        stats.batches += 1;
    }
    stats
}

/// Run one coalesced batch: validate rows, execute the batched predict
/// passes, and fan replies out. Mean requests are ALWAYS answered from the
/// KRR point-prediction path and MeanVar requests from the KBR posterior
/// fan-in — coalescing must never change which estimator answers a
/// request, so a mixed batch runs both passes (each still batched over the
/// whole block). Returns the number of requests replied to (including
/// error replies).
fn serve_batch(
    handle: &RouterHandle,
    dim: usize,
    batch: &mut Vec<Request>,
    buf: &mut BatchBuffers,
) -> usize {
    let total = batch.len();
    buf.xb.resize_scratch(0, dim);
    buf.valid.clear();
    for req in batch.drain(..) {
        if req.x.len() != dim {
            let msg = format!("request row has dim {}, expected {dim}", req.x.len());
            let _ = req.resp.send(Err(Error::shape("microbatch", msg)));
            continue;
        }
        buf.xb.push_row(&req.x).expect("dims checked");
        buf.valid.push(req);
    }
    if buf.valid.is_empty() {
        return total;
    }
    let want_mean = buf.valid.iter().any(|r| matches!(r.want, Want::Mean));
    let want_var = buf.valid.iter().any(|r| matches!(r.want, Want::MeanVar));
    let want_mmean = buf.valid.iter().any(|r| matches!(r.want, Want::MeanMulti));
    let want_mvar = buf.valid.iter().any(|r| matches!(r.want, Want::MeanVarMulti));
    // each pass carries its own error so a failure on one estimator (e.g.
    // no KBR twin, a D=1 request against a multi-output deployment)
    // neither blocks the others nor gets rewritten
    let mean_err: Option<Error> = if want_mean {
        retry_once(|| handle.predict_into(&buf.xb, &mut buf.mean, &mut buf.work))
    } else {
        None
    };
    let var_err: Option<Error> = if want_var {
        retry_once(|| {
            handle.predict_with_uncertainty_into(
                &buf.xb,
                &mut buf.kmean,
                &mut buf.var,
                &mut buf.work,
            )
        })
    } else {
        None
    };
    let mmean_err: Option<Error> = if want_mmean {
        retry_once(|| handle.predict_multi_into(&buf.xb, &mut buf.mean_mat, &mut buf.work))
    } else {
        None
    };
    let mvar_err: Option<Error> = if want_mvar {
        retry_once(|| {
            handle.predict_with_uncertainty_multi_into(
                &buf.xb,
                &mut buf.kmean_mat,
                &mut buf.var_multi,
                &mut buf.work,
            )
        })
    } else {
        None
    };
    let (mean, kmean, var) = (&buf.mean, &buf.kmean, &buf.var);
    let (mean_mat, kmean_mat, var_multi) = (&buf.mean_mat, &buf.kmean_mat, &buf.var_multi);
    for (i, req) in buf.valid.drain(..).enumerate() {
        let reply: Reply = match req.want {
            Want::Mean => match &mean_err {
                None => Ok(ReplyBody::Scalar(mean[i], None)),
                Some(e) => Err(replicate(e)),
            },
            Want::MeanVar => match &var_err {
                None => Ok(ReplyBody::Scalar(kmean[i], Some(var[i]))),
                Some(e) => Err(replicate(e)),
            },
            Want::MeanMulti => match &mmean_err {
                None => Ok(ReplyBody::Multi(mean_mat.row(i).to_vec(), None)),
                Some(e) => Err(replicate(e)),
            },
            Want::MeanVarMulti => match &mvar_err {
                None => Ok(ReplyBody::Multi(
                    kmean_mat.row(i).to_vec(),
                    Some(var_multi[i]),
                )),
                Some(e) => Err(replicate(e)),
            },
        };
        let _ = req.resp.send(reply);
    }
    total
}

/// Run one predict pass, retrying it exactly once when the failure is
/// transient ([`Error::is_transient`]): the read path is stateless over a
/// published epoch, so a second attempt against the (possibly newer)
/// snapshot is safe and often lands after a mid-read republish or heal.
/// Permanent errors (shape, config) are returned immediately — retrying
/// cannot change them.
fn retry_once(mut pass: impl FnMut() -> Result<()>) -> Option<Error> {
    match pass() {
        Ok(()) => None,
        Err(e) if e.is_transient() => pass().err(),
        Err(e) => Some(e),
    }
}

/// Re-materialize a pass error for each affected request. [`Error`] is not
/// `Clone` (its `Io` variant wraps `std::io::Error`), but preserving the
/// variant matters to clients: a permanent `Config` problem (no KBR twin)
/// must stay distinguishable from a transient transport failure.
fn replicate(e: &Error) -> Error {
    match e {
        Error::Shape { context, detail } => {
            Error::Shape { context: *context, detail: detail.clone() }
        }
        Error::Numerical { context, detail } => {
            Error::Numerical { context: *context, detail: detail.clone() }
        }
        Error::InvalidUpdate(m) => Error::InvalidUpdate(m.clone()),
        Error::Config(m) => Error::Config(m.clone()),
        Error::Artifact(m) => Error::Artifact(m.clone()),
        Error::Runtime(m) => Error::Runtime(m.clone()),
        Error::Stream(m) => Error::Stream(m.clone()),
        Error::Io(io) => Error::Stream(format!("io error: {io}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernels::Kernel;
    use crate::serve::router::{ServeConfig, ShardRouter};

    fn router(uncertainty: bool) -> ShardRouter {
        let d = synth::ecg_like(60, 5, 1);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = uncertainty;
        ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap()
    }

    #[test]
    fn single_requests_match_batched_read_path() {
        let r = router(false);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(6, 5, 2);
        let direct = h.predict(&q.x).unwrap();
        for i in 0..6 {
            let got = client.predict(q.x.row(i)).unwrap();
            crate::testutil::assert_close(got, direct[i], 1e-9);
        }
        drop(client);
        let stats = server.shutdown();
        assert!(stats.batches >= 1);
    }

    #[test]
    fn uncertainty_requests_round_trip() {
        let r = router(true);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(4, 5, 3);
        let (mu, sig) = h.predict_with_uncertainty(&q.x).unwrap();
        for i in 0..4 {
            let (m, v) = client.predict_with_uncertainty(q.x.row(i)).unwrap();
            crate::testutil::assert_close(m, mu[i], 1e-9);
            crate::testutil::assert_close(v, sig[i], 1e-9);
            assert!(v > 0.0);
        }
    }

    #[test]
    fn mixed_batches_keep_estimators_separate() {
        // a Mean request coalesced with a MeanVar request must still be
        // answered by the KRR point predictor, not the KBR posterior mean
        let r = router(true);
        let h = r.handle();
        let q = synth::ecg_like(2, 5, 6);
        let direct_mean = h.predict(&q.x).unwrap();
        let (dmu, dvar) = h.predict_with_uncertainty(&q.x).unwrap();
        // max_rows 2 + a generous window forces the two concurrent
        // requests into one batch
        let server = MicroBatchServer::spawn(
            h,
            5,
            MicroBatchPolicy { max_rows: 2, max_wait: Duration::from_secs(1) },
        );
        let mut c1 = server.client();
        let mut c2 = server.client();
        let row0 = q.x.row(0).to_vec();
        let t = std::thread::spawn(move || c1.predict(&row0).unwrap());
        let (m1, v1) = c2.predict_with_uncertainty(q.x.row(1)).unwrap();
        let m0 = t.join().unwrap();
        crate::testutil::assert_close(m0, direct_mean[0], 1e-9);
        crate::testutil::assert_close(m1, dmu[1], 1e-9);
        crate::testutil::assert_close(v1, dvar[1], 1e-9);
    }

    #[test]
    fn wrong_dim_and_missing_twin_error_cleanly() {
        let r = router(false);
        let server = MicroBatchServer::spawn(r.handle(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        assert!(client.predict(&[1.0, 2.0]).is_err(), "wrong dim");
        // mean requests still work after an error reply
        let q = synth::ecg_like(1, 5, 4);
        assert!(client.predict(q.x.row(0)).is_ok());
        // no KBR twin: variance requests get the Config error (variant
        // preserved through replicate()), without killing the server
        let err = client.predict_with_uncertainty(q.x.row(0)).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "got {err:?}");
        assert!(client.predict(q.x.row(0)).is_ok());
    }

    #[test]
    fn shutdown_with_live_clients_does_not_deadlock() {
        let r = router(false);
        let server = MicroBatchServer::spawn(r.handle(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(1, 5, 7);
        assert!(client.predict(q.x.row(0)).is_ok());
        // the client still holds a live sender: shutdown must not rely on
        // channel disconnect to stop the worker
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert!(client.predict(q.x.row(0)).is_err(), "post-shutdown calls error");
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let r = router(false);
        let h = r.handle();
        let server = MicroBatchServer::spawn(
            h.clone(),
            5,
            MicroBatchPolicy { max_rows: 16, max_wait: Duration::from_millis(20) },
        );
        let q = synth::ecg_like(24, 5, 5);
        let direct = h.predict(&q.x).unwrap();
        let mut joins = Vec::new();
        for t in 0..3 {
            let mut client = server.client();
            let rows: Vec<Vec<f64>> =
                (0..8).map(|i| q.x.row(t * 8 + i).to_vec()).collect();
            joins.push(std::thread::spawn(move || {
                rows.iter().map(|r| client.predict(r).unwrap()).collect::<Vec<f64>>()
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let got = j.join().unwrap();
            crate::testutil::assert_vec_close(&got, &direct[t * 8..(t + 1) * 8], 1e-9);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 24);
        assert!(stats.batches <= 24, "some coalescing expected under load");
    }

    fn router_multi(uncertainty: bool) -> ShardRouter {
        let d = synth::ecg_like(60, 5, 1);
        let y = Mat::from_fn(60, 2, |i, j| if j == 0 { d.y[i] } else { 2.0 * d.y[i] - 0.5 });
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = uncertainty;
        ShardRouter::bootstrap_multi(&d.x, &y, cfg).unwrap()
    }

    #[test]
    fn multi_output_requests_round_trip() {
        let r = router_multi(true);
        let h = r.handle();
        let server = MicroBatchServer::spawn(h.clone(), 5, MicroBatchPolicy::default());
        let mut client = server.client();
        let q = synth::ecg_like(4, 5, 8);
        let direct = h.predict_multi(&q.x).unwrap();
        let mut work = RouterPredictWork::default();
        let mut kmean = Mat::default();
        let mut var = Vec::new();
        h.predict_with_uncertainty_multi_into(&q.x, &mut kmean, &mut var, &mut work).unwrap();
        for i in 0..4 {
            let got = client.predict_multi(q.x.row(i)).unwrap();
            assert_eq!(got.len(), 2);
            crate::testutil::assert_vec_close(&got, direct.row(i), 1e-9);
            let (m, v) = client.predict_with_uncertainty_multi(q.x.row(i)).unwrap();
            crate::testutil::assert_vec_close(&m, kmean.row(i), 1e-9);
            crate::testutil::assert_close(v, var[i], 1e-9);
        }
        // scalar requests against a D=2 deployment error cleanly (D=1 shim
        // guard propagates through the coalesced batch) without killing
        // concurrent multi traffic
        let err = client.predict(q.x.row(0)).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "got {err:?}");
        assert!(client.predict_multi(q.x.row(0)).is_ok());
    }
}
