//! The shard router: K independent engines behind one ingest + read
//! surface.
//!
//! # Write path
//!
//! Arrivals are placed onto shards by [`Placement`] (round-robin keeps
//! shard sizes balanced; hash placement is content-sticky so replayed
//! events land deterministically). Each shard batches its own slice and
//! runs the paper's fused inc/dec round on J/K-sized state — in empirical
//! space the maintained inverse shrinks from one N×N to K blocks of
//! (N/K)², so a full router round costs ~1/K of the monolithic update
//! even applied sequentially.
//!
//! # Read path
//!
//! All reads go through ONE entry point, [`RouterHandle::query`], keyed by
//! [`super::QueryKind`]. The point kinds average the K shard predictions —
//! the divide-and-conquer KRR estimator (You et al., *Accurate, Fast and
//! Scalable Kernel Ridge Regression on Parallel and Distributed Systems*):
//! with data split uniformly at random, each shard is an unbiased
//! estimator of the same regression function and the average concentrates
//! around the full-data solution. The KBR kinds
//! fuse shard posteriors by
//! **precision weighting**: μ = Σₖ λₖ μₖ / Σₖ λₖ with λₖ = 1/σₖ², the
//! minimum-variance unbiased combination of independent shard estimates,
//! and σ̄² = K / Σₖ λₖ — the precision-weighted harmonic mean of shard
//! variances, which stays on a single-model scale (each shard saw 1/K of
//! the data but all share one prior; the product-of-experts 1/Σλ would
//! double-count that prior K times and report overconfident intervals).
//! Both reductions are exact identities at K = 1, which is what the parity
//! tests anchor on.
//!
//! When the supervisor quarantines a shard (see [`super::supervisor`]),
//! every fan-in skips it and renormalizes over the shards actually used —
//! the same DC-KRR average / precision weighting over K−1 unbiased
//! estimators, so degraded serving changes variance, not correctness. If
//! *every* shard is quarantined the handle fails open and uses all of
//! them: a drifted answer beats no answer, and an all-quarantined state
//! only happens mid-heal.

use std::path::Path;
use std::sync::Arc;

use crate::config::Space;
use crate::coordinator::engine::EnginePredictWork;
use crate::coordinator::{CoordinatorConfig, RoundOutcome};
use crate::error::{Error, Result};
use crate::health::probe::{HealthProbe, HealthVerdict, ProbeConfig};
use crate::kernels::Kernel;
use crate::krr::advisor::Advisor;
use crate::linalg::Mat;
use crate::metrics::Counters;
use crate::persist::snapshot::{quarantine_snapshot, snapshot_path};
use crate::persist::store::{self, recover_shard, DurabilityConfig, RouterMeta, ShardStore};
use crate::streaming::batcher::Batcher;
use crate::streaming::sink::SinkNode;
use crate::streaming::StreamEvent;
use crate::telemetry::{FlightDump, MetricId, Registry, SpanKind, TelemetrySnapshot};

use super::publish::ShardStatus;
use super::query::{PredictRequest, PredictResponse, QueryKind};
use super::shard::{Shard, SnapshotHandle};

/// How arrivals are placed onto shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Cycle through shards — balanced sizes, stream-order interleaving.
    RoundRobin,
    /// FNV-1a over the feature bytes — content-sticky (the same
    /// observation always lands on the same shard, regardless of arrival
    /// order or source).
    Hash,
}

impl Placement {
    /// The shard a feature row deterministically maps to, when placement
    /// is content-addressed. `None` for round-robin, which is stateful —
    /// only the router's own cursor can answer it. Recovery re-feed uses
    /// this to route lost events back to exactly the shard that would have
    /// received them.
    pub fn shard_of(&self, x: &[f64], k: usize) -> Option<usize> {
        match self {
            Placement::RoundRobin => None,
            Placement::Hash => Some((hash_row(x) % k as u64) as usize),
        }
    }
}

/// FNV-1a over the row's f64 bit patterns.
fn hash_row(x: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in x {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Serving-layer configuration: shard count + placement on top of the
/// per-engine round policy.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Number of independent engine replicas (K ≥ 1).
    pub shards: usize,
    /// Arrival placement policy.
    pub placement: Placement,
    /// Per-shard round policy (kernel, ridge, batching, outliers,
    /// uncertainty twin, rollback) — the same knobs as the single-engine
    /// coordinator.
    pub base: CoordinatorConfig,
}

impl ServeConfig {
    /// Round-robin defaults over the coordinator's default round policy.
    pub fn default_for(kernel: Kernel, shards: usize) -> Self {
        Self {
            shards,
            placement: Placement::RoundRobin,
            base: CoordinatorConfig::default_for(kernel),
        }
    }
}

/// What one router round did, across all shards. Shards are independent:
/// a failure on one never blocks (or unrecords) the rounds that the other
/// shards already applied and published.
#[derive(Debug, Default)]
pub struct RoundReport {
    /// Successful shard rounds, in shard order.
    pub outcomes: Vec<RoundOutcome>,
    /// Per-shard failures `(shard id, error)` from the same round. The
    /// failing shard's batch was requeued or dropped per
    /// [`Shard::flush`]'s policy.
    pub errors: Vec<(usize, Error)>,
}

impl RoundReport {
    /// Total samples added by the successful rounds.
    pub fn added(&self) -> usize {
        self.outcomes.iter().map(|o| o.added).sum()
    }

    /// Total samples removed by the successful rounds.
    pub fn removed(&self) -> usize {
        self.outcomes.iter().map(|o| o.removed).sum()
    }

    /// True when nothing happened (no outcomes, no errors).
    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty() && self.errors.is_empty()
    }

    /// Fold another report into this one.
    pub fn merge(&mut self, mut other: RoundReport) {
        self.outcomes.append(&mut other.outcomes);
        self.errors.append(&mut other.errors);
    }
}

/// Caller-owned workspace for the router's allocation-free read path.
#[derive(Default)]
pub struct RouterPredictWork {
    engine: EnginePredictWork,
    shard_out: Vec<f64>,
    shard_mean: Vec<f64>,
    shard_var: Vec<f64>,
    acc_mean: Vec<f64>,
    acc_prec: Vec<f64>,
    /// Multi-output shard scratch and accumulators, (B, D).
    shard_mat: Mat,
    acc_mat: Mat,
    /// Parked variance buffer so alternating query kinds stay warm.
    spare_var: Vec<f64>,
    /// Response staging for the deprecated `*_into` shims.
    resp: PredictResponse,
}

/// Cloneable read front-end over all shards' published epochs.
#[derive(Clone)]
pub struct RouterHandle {
    shards: Vec<SnapshotHandle>,
    router_telemetry: Arc<Registry>,
}

impl RouterHandle {
    /// Number of shards behind this handle.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Merge the fleet's live registries — the router's own slots plus
    /// every shard's (rounds, phase histograms, durability) — into one
    /// frozen [`TelemetrySnapshot`]. This is the serve-tier half of the
    /// `MKTL` stats payload; it reads only relaxed atomics, so it never
    /// contends with the writers it observes.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::new();
        self.router_telemetry.merge_into(&mut snap);
        for s in &self.shards {
            s.telemetry().merge_into(&mut snap);
        }
        snap
    }

    /// The same handle with its shards visited in `order` — a test/debug
    /// constructor: every fan-in reduction (DC-KRR average, precision
    /// weighting) is permutation-invariant, and the shard-permutation
    /// tests pin that down through this.
    pub fn permuted(&self, order: &[usize]) -> Result<RouterHandle> {
        if order.len() != self.shards.len() {
            return Err(Error::Config(format!(
                "permutation of {} entries over {} shards",
                order.len(),
                self.shards.len()
            )));
        }
        let mut seen = vec![false; self.shards.len()];
        for &i in order {
            if i >= self.shards.len() || seen[i] {
                return Err(Error::Config(format!("invalid shard permutation {order:?}")));
            }
            seen[i] = true;
        }
        Ok(RouterHandle {
            shards: order.iter().map(|&i| self.shards[i].clone()).collect(),
            router_telemetry: Arc::clone(&self.router_telemetry),
        })
    }

    /// Read handle for one shard.
    pub fn shard(&self, i: usize) -> &SnapshotHandle {
        &self.shards[i]
    }

    /// Per-shard serving statuses (one atomic load each).
    pub fn statuses(&self) -> Vec<super::publish::ShardStatus> {
        self.shards.iter().map(|s| s.status()).collect()
    }

    /// How many shards the next fan-in will use (all, when every shard is
    /// quarantined — fail-open).
    pub fn num_serving(&self) -> usize {
        let n = self.shards.iter().filter(|s| s.serving()).count();
        if n == 0 {
            self.shards.len()
        } else {
            n
        }
    }

    /// True when the fan-ins must ignore quarantine flags because nothing
    /// is serving.
    fn fail_open(&self) -> bool {
        !self.shards.iter().any(SnapshotHandle::serving)
    }

    /// Per-shard epoch numbers (freshness diagnostics).
    pub fn epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch()).collect()
    }

    /// Total training samples across the last published epochs.
    pub fn n_samples(&self) -> usize {
        self.shards.iter().map(|s| s.n_samples()).sum()
    }

    /// Run one [`PredictRequest`] across the shard fleet, allocating a
    /// fresh response. Serving loops should prefer
    /// [`RouterHandle::query_into`] with warm buffers.
    pub fn query(&self, req: &PredictRequest) -> Result<PredictResponse> {
        let mut resp = PredictResponse::default();
        self.query_inner(&req.x, req.want, &mut resp, &mut RouterPredictWork::default())?;
        Ok(resp)
    }

    /// Run one [`PredictRequest`] through caller-owned buffers — THE fan-in
    /// entry point: every legacy `predict*` shim, the micro-batch window,
    /// and the network reactor all funnel through here. Allocation-free
    /// once `resp`/`work` are warm.
    pub fn query_into(
        &self,
        req: &PredictRequest,
        resp: &mut PredictResponse,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        self.query_inner(&req.x, req.want, resp, work)
    }

    /// Shared body of the query surface (borrows `x` so the deprecated
    /// shims avoid copying the batch into a request).
    ///
    /// ONE loop visits every serving shard; each [`QueryKind`] dispatches
    /// to the same engine kernel and accumulation rule the legacy fan-ins
    /// used (DC-KRR average for the point kinds, precision weighting for
    /// the KBR kinds), so answers are bitwise-unchanged by the redesign.
    /// Quarantine-skip, fail-open, and the `used.max(1)` renormalization
    /// are applied once, identically for every kind.
    pub(crate) fn query_inner(
        &self,
        x: &Mat,
        want: QueryKind,
        resp: &mut PredictResponse,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let b = x.rows();
        match want {
            QueryKind::Mean => {
                resp.mean.resize_scratch(b, 1);
                resp.mean.as_mut_slice().fill(0.0);
            }
            QueryKind::MeanMulti => {}
            QueryKind::MeanVar => {
                work.acc_mean.clear();
                work.acc_mean.resize(b, 0.0);
                work.acc_prec.clear();
                work.acc_prec.resize(b, 0.0);
            }
            QueryKind::MeanVarMulti => {
                work.acc_prec.clear();
                work.acc_prec.resize(b, 0.0);
            }
        }
        let fail_open = self.fail_open();
        let mut used = 0usize;
        for h in &self.shards {
            if !fail_open && !h.serving() {
                continue;
            }
            let snap = h.snapshot();
            match want {
                QueryKind::Mean => {
                    snap.predict_into(x, &mut work.shard_out, &mut work.engine)?;
                    let acc = resp.mean.as_mut_slice().iter_mut();
                    for (o, s) in acc.zip(&work.shard_out) {
                        *o += s;
                    }
                }
                QueryKind::MeanMulti => {
                    snap.predict_multi_into(x, &mut work.shard_mat, &mut work.engine)?;
                    if used == 0 {
                        resp.mean.resize_scratch(work.shard_mat.rows(), work.shard_mat.cols());
                        resp.mean.as_mut_slice().copy_from_slice(work.shard_mat.as_slice());
                    } else {
                        let acc = resp.mean.as_mut_slice().iter_mut();
                        for (o, s) in acc.zip(work.shard_mat.as_slice()) {
                            *o += s;
                        }
                    }
                }
                QueryKind::MeanVar => {
                    snap.predict_with_uncertainty_into(
                        x,
                        &mut work.shard_mean,
                        &mut work.shard_var,
                        &mut work.engine,
                    )?;
                    let acc = work.acc_mean.iter_mut().zip(work.acc_prec.iter_mut());
                    for ((&m, &v), (am, ap)) in
                        work.shard_mean.iter().zip(&work.shard_var).zip(acc)
                    {
                        // shard variances are >= sigma_b^2 > 0 by construction
                        let lam = 1.0 / v;
                        *ap += lam;
                        *am += lam * m;
                    }
                }
                QueryKind::MeanVarMulti => {
                    snap.predict_with_uncertainty_multi_into(
                        x,
                        &mut work.shard_mat,
                        &mut work.shard_var,
                        &mut work.engine,
                    )?;
                    if used == 0 {
                        work.acc_mat.resize_scratch(b, work.shard_mat.cols());
                        work.acc_mat.as_mut_slice().fill(0.0);
                    }
                    for r in 0..b {
                        // shard variances are >= sigma_b^2 > 0 by construction
                        let lam = 1.0 / work.shard_var[r];
                        work.acc_prec[r] += lam;
                        for (a, &m) in
                            work.acc_mat.row_mut(r).iter_mut().zip(work.shard_mat.row(r))
                        {
                            *a += lam * m;
                        }
                    }
                }
            }
            used += 1;
        }
        let k = used.max(1) as f64;
        match want {
            QueryKind::Mean | QueryKind::MeanMulti => {
                for o in resp.mean.as_mut_slice() {
                    *o /= k;
                }
                resp.clear_into_spare(&mut work.spare_var);
            }
            QueryKind::MeanVar => {
                let mut var = resp.take_variance_buf(&mut work.spare_var);
                resp.mean.resize_scratch(b, 1);
                let rows = resp.mean.as_mut_slice().iter_mut();
                for ((am, ap), m) in work.acc_mean.iter().zip(&work.acc_prec).zip(rows) {
                    *m = am / ap;
                    var.push(k / ap);
                }
                resp.variance = Some(var);
            }
            QueryKind::MeanVarMulti => {
                let mut var = resp.take_variance_buf(&mut work.spare_var);
                let d = work.acc_mat.cols();
                resp.mean.resize_scratch(b, d);
                for (r, &ap) in work.acc_prec.iter().enumerate() {
                    let acc = resp.mean.row_mut(r).iter_mut();
                    for (m, &a) in acc.zip(work.acc_mat.row(r)) {
                        *m = a / ap;
                    }
                    var.push(k / ap);
                }
                resp.variance = Some(var);
            }
        }
        Ok(())
    }

    /// DC-KRR averaged prediction across shards.
    #[deprecated(since = "0.4.0", note = "use RouterHandle::query with QueryKind::Mean")]
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut resp = PredictResponse::default();
        self.query_inner(x, QueryKind::Mean, &mut resp, &mut RouterPredictWork::default())?;
        Ok(resp.mean.as_slice().to_vec())
    }

    /// [`RouterHandle::predict`] through a warm workspace.
    #[deprecated(since = "0.4.0", note = "use RouterHandle::query_into with QueryKind::Mean")]
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let mut resp = std::mem::take(&mut work.resp);
        let res = self.query_inner(x, QueryKind::Mean, &mut resp, work);
        if res.is_ok() {
            out.clear();
            out.extend_from_slice(resp.mean.as_slice());
        }
        work.resp = resp;
        res
    }

    /// DC-KRR averaged multi-output prediction across shards: `(B, D)`.
    #[deprecated(since = "0.4.0", note = "use RouterHandle::query with QueryKind::MeanMulti")]
    pub fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        let mut resp = PredictResponse::default();
        self.query_inner(x, QueryKind::MeanMulti, &mut resp, &mut RouterPredictWork::default())?;
        Ok(resp.mean)
    }

    /// [`RouterHandle::predict_multi`] through a warm workspace.
    #[deprecated(
        since = "0.4.0",
        note = "use RouterHandle::query_into with QueryKind::MeanMulti"
    )]
    pub fn predict_multi_into(
        &self,
        x: &Mat,
        out: &mut Mat,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let mut resp = std::mem::take(&mut work.resp);
        let res = self.query_inner(x, QueryKind::MeanMulti, &mut resp, work);
        if res.is_ok() {
            out.resize_scratch(resp.mean.rows(), resp.mean.cols());
            out.as_mut_slice().copy_from_slice(resp.mean.as_slice());
        }
        work.resp = resp;
        res
    }

    /// Precision-weighted posterior fan-in across the shards' KBR twins
    /// (see the module docs for the fusion rule).
    #[deprecated(since = "0.4.0", note = "use RouterHandle::query with QueryKind::MeanVar")]
    pub fn predict_with_uncertainty(&self, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        let mut resp = PredictResponse::default();
        self.query_inner(x, QueryKind::MeanVar, &mut resp, &mut RouterPredictWork::default())?;
        let var = resp.variance.take().unwrap_or_default();
        Ok((resp.mean.as_slice().to_vec(), var))
    }

    /// [`RouterHandle::predict_with_uncertainty`] through a warm workspace.
    #[deprecated(
        since = "0.4.0",
        note = "use RouterHandle::query_into with QueryKind::MeanVar"
    )]
    pub fn predict_with_uncertainty_into(
        &self,
        x: &Mat,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let mut resp = std::mem::take(&mut work.resp);
        let res = self.query_inner(x, QueryKind::MeanVar, &mut resp, work);
        if res.is_ok() {
            mean.clear();
            mean.extend_from_slice(resp.mean.as_slice());
            var.clear();
            var.extend_from_slice(resp.variance.as_deref().unwrap_or_default());
        }
        work.resp = resp;
        res
    }

    /// Multi-output precision-weighted fan-in: `(B, D)` fused means and
    /// the shared per-query fused variance. The shard weights λₖ = 1/σₖ²
    /// come from the shared variance column, so all D output columns of a
    /// query row fuse with the SAME weights.
    #[deprecated(
        since = "0.4.0",
        note = "use RouterHandle::query with QueryKind::MeanVarMulti"
    )]
    pub fn predict_with_uncertainty_multi(&self, x: &Mat) -> Result<(Mat, Vec<f64>)> {
        let mut resp = PredictResponse::default();
        self.query_inner(
            x,
            QueryKind::MeanVarMulti,
            &mut resp,
            &mut RouterPredictWork::default(),
        )?;
        let var = resp.variance.take().unwrap_or_default();
        Ok((resp.mean, var))
    }

    /// [`RouterHandle::predict_with_uncertainty_multi`] through a warm
    /// workspace.
    #[deprecated(
        since = "0.4.0",
        note = "use RouterHandle::query_into with QueryKind::MeanVarMulti"
    )]
    pub fn predict_with_uncertainty_multi_into(
        &self,
        x: &Mat,
        mean: &mut Mat,
        var: &mut Vec<f64>,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let mut resp = std::mem::take(&mut work.resp);
        let res = self.query_inner(x, QueryKind::MeanVarMulti, &mut resp, work);
        if res.is_ok() {
            mean.resize_scratch(resp.mean.rows(), resp.mean.cols());
            mean.as_mut_slice().copy_from_slice(resp.mean.as_slice());
            var.clear();
            var.extend_from_slice(resp.variance.as_deref().unwrap_or_default());
        }
        work.resp = resp;
        res
    }

    /// ~95% credible intervals from the fused posterior, written into a
    /// caller-provided buffer through [`crate::kbr::interval95_from_into`]
    /// — the serve layer's allocation-free uncertainty fan-in (`D = 1`).
    #[deprecated(
        since = "0.4.0",
        note = "use RouterHandle::query_into with QueryKind::MeanVar + interval95_from_into"
    )]
    pub fn predict_interval95_into(
        &self,
        x: &Mat,
        out: &mut Vec<(f64, f64)>,
        work: &mut RouterPredictWork,
    ) -> Result<()> {
        let mut resp = std::mem::take(&mut work.resp);
        let res = self.query_inner(x, QueryKind::MeanVar, &mut resp, work);
        if res.is_ok() {
            crate::kbr::interval95_from_into(
                resp.mean.as_slice(),
                resp.variance.as_deref().unwrap_or_default(),
                out,
            );
        }
        work.resp = resp;
        res
    }
}

/// The multi-engine shard router.
pub struct ShardRouter {
    shards: Vec<Shard>,
    placement: Placement,
    rr: usize,
    batcher: Batcher,
    /// The per-shard round policy (kept for durability metadata).
    base: CoordinatorConfig,
    /// Router-level metric slots: routed / rounds / shard_errors, plus
    /// the fleet recovery observations (`wal_records_replayed`,
    /// `wal_replay_skipped`, `snapshot_fallbacks`, ...) when this router
    /// came out of [`ShardRouter::recover`]. Shared with every
    /// [`RouterHandle`] so the read side can merge the fleet view.
    telemetry: Arc<Registry>,
    /// One flight-recorder dump per recovered shard — the event trail
    /// replay produced, shipped with the recovery so post-mortems can see
    /// what was rebuilt. Empty on a bootstrapped router.
    recovery_flight_dumps: Vec<FlightDump>,
}

impl ShardRouter {
    /// Partition the bootstrap set across K shards (row `i` → shard
    /// `i mod K`, so every shard sees the full data distribution — the
    /// uniform split the DC-KRR averaging argument needs) and fit one
    /// engine per shard. Space is chosen once, by the advisor on the
    /// per-shard problem size, unless the config overrides it (`D = 1`).
    pub fn bootstrap(x: &Mat, y: &[f64], cfg: ServeConfig) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::bootstrap_multi(x, &ym, cfg)
    }

    /// [`ShardRouter::bootstrap`] with a `(N, D)` target matrix: every
    /// shard engine carries all D output columns behind its one maintained
    /// inverse.
    pub fn bootstrap_multi(x: &Mat, y: &Mat, cfg: ServeConfig) -> Result<Self> {
        let k = cfg.shards;
        let n = y.rows();
        if k == 0 {
            return Err(Error::Config("ServeConfig.shards must be >= 1".into()));
        }
        if n < 4 * k {
            return Err(Error::Config(format!(
                "bootstrap set of {n} cannot seed {k} shards (need >= {})",
                4 * k
            )));
        }
        if cfg.base.batch.max_batch == 0 {
            return Err(Error::Config(
                "ServeConfig.base.batch.max_batch must be >= 1".into(),
            ));
        }
        let per_shard = n / k;
        let space = cfg.base.space.unwrap_or_else(|| {
            Advisor::default()
                .choose_space(&cfg.base.kernel, per_shard, x.cols(), 4, 2)
                .space
        });
        let mut shards = Vec::with_capacity(k);
        for s in 0..k {
            let idx: Vec<usize> = (s..n).step_by(k).collect();
            let xs = x.select_rows(&idx);
            let ys = y.select_rows(&idx);
            shards.push(Shard::bootstrap_multi(s, &xs, &ys, &cfg.base, space)?);
        }
        // the global pull batcher fills every shard's batch in one round
        let mut policy = cfg.base.batch.clone();
        policy.max_batch = policy.max_batch.saturating_mul(k);
        Ok(Self {
            shards,
            placement: cfg.placement,
            rr: 0,
            batcher: Batcher::new(policy),
            base: cfg.base,
            telemetry: Arc::new(Registry::new()),
            recovery_flight_dumps: Vec::new(),
        })
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The space every shard engine runs in.
    pub fn space(&self) -> Space {
        self.shards[0].handle().snapshot().space()
    }

    /// Borrow one shard (diagnostics).
    pub fn shard(&self, i: usize) -> &Shard {
        &self.shards[i]
    }

    /// Mutably borrow one shard (benches / explicit replay).
    pub fn shard_mut(&mut self, i: usize) -> &mut Shard {
        &mut self.shards[i]
    }

    /// Writer-side total training samples.
    pub fn n_samples(&self) -> usize {
        self.shards.iter().map(|s| s.n_samples()).sum()
    }

    /// A cloneable read front-end over all shards.
    pub fn handle(&self) -> RouterHandle {
        RouterHandle {
            shards: self.shards.iter().map(|s| s.handle()).collect(),
            router_telemetry: Arc::clone(&self.telemetry),
        }
    }

    /// The router's own metric slots (routed / rounds / shard_errors /
    /// recovery observations).
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// String-keyed compatibility view over the router's registry (the
    /// legacy `counters` field's rendering surface).
    pub fn counters(&self) -> Counters {
        self.telemetry.counters()
    }

    /// The flight-recorder dumps [`ShardRouter::recover`] shipped, one
    /// per recovered shard (empty on a bootstrapped router).
    pub fn recovery_flight_dumps(&self) -> &[FlightDump] {
        &self.recovery_flight_dumps
    }

    // ---- durability ----

    /// Make the fleet durable under `dir`: write the router metadata file,
    /// snapshot every shard's engine as generation 1, open each shard's
    /// WAL segment, and attach the stores. From here on every applied
    /// round is write-ahead logged and checkpointed on `dcfg`'s cadence,
    /// and [`ShardRouter::recover`] can rebuild the fleet from `dir` after
    /// a crash at any point.
    pub fn make_durable(&mut self, dir: &Path, dcfg: DurabilityConfig) -> Result<()> {
        if self.shards.iter().any(Shard::is_durable) {
            return Err(Error::Config("router is already durable".into()));
        }
        store::write_meta(
            dir,
            &RouterMeta {
                shards: self.shards.len(),
                hash_placement: self.placement == Placement::Hash,
                base: self.base.clone(),
                durability: dcfg,
            },
        )?;
        for shard in &mut self.shards {
            let epoch = shard.handle().epoch();
            let st = ShardStore::create(
                dir,
                shard.id(),
                shard.engine(),
                epoch,
                shard.high_seq(),
                dcfg,
            )?;
            shard.attach_store(st);
        }
        Ok(())
    }

    /// Rebuild a durable fleet from its state directory after a crash.
    ///
    /// Per shard: pick the newest snapshot generation that decodes *and*
    /// refactorizes cleanly (corrupt ones are quarantined aside and the
    /// scan falls back a generation), replay the WAL suffix idempotently
    /// by sequence number, probe-verify the recovered inverse, and resume
    /// durable logging at a generation above everything seen pre-crash. A
    /// shard whose probe breaches comes back [`ShardStatus::Quarantined`]
    /// — routed into the supervisor's quarantine/heal machinery instead of
    /// failing the fleet.
    ///
    /// Replay restores everything the WAL saw; events that were still
    /// in-flight at the crash are the caller's to re-feed, filtered per
    /// shard to `ev.seq > high_seq` ([`ShardRouter::high_seqs`]) for
    /// exactly-once application.
    pub fn recover(dir: &Path) -> Result<Self> {
        let meta = store::read_meta(dir)?;
        let telemetry = Arc::new(Registry::new());
        let mut recovery_flight_dumps = Vec::with_capacity(meta.shards);
        let mut shards = Vec::with_capacity(meta.shards);
        for id in 0..meta.shards {
            // newest snapshot that both decodes AND refactorizes: a state
            // whose rebuild fails is corruption the CRC happened to miss,
            // so quarantine it and rescan to give the fallback generation
            // its turn
            let (rec, engine) = loop {
                let rec = recover_shard(dir, id)?;
                match rec.state.rebuild() {
                    Ok(engine) => break (rec, engine),
                    Err(e) if !e.is_transient() => {
                        telemetry.inc(MetricId::SnapshotFallbacks);
                        quarantine_snapshot(&snapshot_path(dir, id, rec.state.generation))?;
                    }
                    Err(e) => return Err(e),
                }
            };
            telemetry.absorb_counters(&rec.counters);
            let mut shard =
                Shard::from_engine(id, engine, &meta.base, rec.state.epoch, rec.state.high_seq);
            let mut replayed = 0u64;
            for record in &rec.records {
                match shard.replay_record(record) {
                    Ok(true) => {
                        replayed += 1;
                        telemetry.inc(MetricId::WalRecordsReplayed);
                    }
                    Ok(false) => {}
                    // round failures are deterministic in (engine state,
                    // batch): a replay failure reproduces one the live run
                    // already resolved by quarantine or drop
                    Err(_) => telemetry.inc(MetricId::WalReplaySkipped),
                }
            }
            // probe-verify the recovered inverse before it serves reads
            let mut probe = HealthProbe::new(ProbeConfig::default());
            match probe.check(shard.engine()) {
                Ok(report) if report.verdict == HealthVerdict::Healthy => {}
                _ => {
                    telemetry.inc(MetricId::RecoveredQuarantined);
                    shard.set_status(ShardStatus::Quarantined);
                }
            }
            // the replay trail (round/WAL/publish spans) ships with the
            // recovery as a per-shard post-mortem dump
            shard.record_span(SpanKind::Recover, id as u64, replayed);
            recovery_flight_dumps.push(shard.flight_dump(format!("shard-{id} recovery")));
            let epoch = shard.handle().epoch();
            let st = ShardStore::resume(
                dir,
                id,
                shard.engine(),
                epoch,
                shard.high_seq(),
                rec.max_generation_seen + 1,
                meta.durability,
            )?;
            shard.attach_store(st);
            shards.push(shard);
        }
        let mut policy = meta.base.batch.clone();
        policy.max_batch = policy.max_batch.saturating_mul(meta.shards.max(1));
        Ok(Self {
            shards,
            placement: if meta.hash_placement {
                Placement::Hash
            } else {
                Placement::RoundRobin
            },
            rr: 0,
            batcher: Batcher::new(policy),
            base: meta.base,
            telemetry,
            recovery_flight_dumps,
        })
    }

    /// Per-shard applied-event high-water marks — the exactly-once re-feed
    /// cutoffs after [`ShardRouter::recover`].
    pub fn high_seqs(&self) -> Vec<u64> {
        self.shards.iter().map(Shard::high_seq).collect()
    }

    /// Fleet durability counters: the recovery scan's observations merged
    /// with every shard store's live counters.
    pub fn durability_counters(&self) -> Counters {
        let mut out = self.telemetry.counters_for(&store::DURABILITY_IDS);
        for shard in &self.shards {
            if let Some(c) = shard.durability_counters() {
                out.merge_from(&c);
            }
        }
        out
    }

    /// The placement policy arrivals are routed with.
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// The shard an event is placed on.
    pub fn route(&mut self, ev: &StreamEvent) -> usize {
        let k = self.shards.len();
        match self.placement.shard_of(&ev.x, k) {
            Some(s) => s,
            None => {
                let s = self.rr % k;
                self.rr = (self.rr + 1) % k;
                s
            }
        }
    }

    /// Route one arrival onto its shard's pending queue.
    pub fn ingest(&mut self, ev: StreamEvent) {
        let s = self.route(&ev);
        self.telemetry.inc(MetricId::Routed);
        self.shards[s].push(ev);
    }

    /// One router round: every shard with pending arrivals flushes one
    /// batch through its fused update and publishes a new epoch. Shards
    /// are independent — a failure on one is reported in the
    /// [`RoundReport`] (its batch requeued or dropped per
    /// [`Shard::flush`]) and never discards what the other shards already
    /// applied and published.
    pub fn update_round(&mut self) -> RoundReport {
        let mut report = RoundReport::default();
        for shard in &mut self.shards {
            match shard.flush() {
                Ok(Some(out)) => report.outcomes.push(out),
                Ok(None) => {}
                Err(e) => report.errors.push((shard.id(), e)),
            }
        }
        if !report.outcomes.is_empty() {
            self.telemetry.inc(MetricId::Rounds);
        }
        self.telemetry.add(MetricId::ShardErrors, report.errors.len() as u64);
        report
    }

    /// An explicit insertion-free eviction round on every shard.
    pub fn evict_outliers(&mut self) -> RoundReport {
        let mut report = RoundReport::default();
        for shard in &mut self.shards {
            match shard.evict_outliers() {
                Ok(out) => report.outcomes.push(out),
                Err(e) => report.errors.push((shard.id(), e)),
            }
        }
        self.telemetry.add(MetricId::ShardErrors, report.errors.len() as u64);
        report
    }

    /// Pull-route-update loop over one pooled sink until the stream goes
    /// quiet or `max_rounds` is reached (the sharded analogue of
    /// [`crate::coordinator::Coordinator::run`]). Every applied outcome
    /// and every per-shard error is in the returned report.
    pub fn run(&mut self, sink: &mut SinkNode, max_rounds: usize) -> RoundReport {
        let mut report = RoundReport::default();
        for _ in 0..max_rounds {
            let batch = self.batcher.next_batch(sink);
            if batch.is_empty() {
                break;
            }
            for ev in batch {
                self.ingest(ev);
            }
            report.merge(self.update_round());
        }
        // drain whatever is still pending (e.g. a partial final batch);
        // stop if an iteration makes no progress — a rolled-back batch
        // that keeps failing must not livelock the drain
        loop {
            let pending: usize = self.shards.iter().map(|s| s.pending()).sum();
            if pending == 0 {
                break;
            }
            let round = self.update_round();
            let after: usize = self.shards.iter().map(|s| s.pending()).sum();
            let progressed = !round.outcomes.is_empty() || after < pending;
            report.merge(round);
            if !progressed {
                break;
            }
        }
        report
    }

    /// Round-driven loop over per-shard sinks (one sink per shard, fed by
    /// [`crate::streaming::fanout`]): each round drains every shard's sink
    /// into its pending queue and flushes. Ends once every sink has
    /// disconnected and nothing is pending (or, once the sinks have
    /// disconnected, when a round stops making progress — see
    /// [`ShardRouter::run`]). `Err` only for a config mismatch.
    pub fn run_per_shard(
        &mut self,
        sinks: &mut [SinkNode],
        max_rounds: usize,
    ) -> Result<RoundReport> {
        if sinks.len() != self.shards.len() {
            return Err(Error::Config(format!(
                "{} sinks for {} shards",
                sinks.len(),
                self.shards.len()
            )));
        }
        let mut report = RoundReport::default();
        for _ in 0..max_rounds {
            for (shard, sink) in self.shards.iter_mut().zip(sinks.iter_mut()) {
                let want = shard.max_batch();
                for ev in sink.drain(want, std::time::Duration::from_millis(5)) {
                    self.telemetry.inc(MetricId::Routed);
                    shard.push(ev);
                }
            }
            let pending_before: usize = self.shards.iter().map(|s| s.pending()).sum();
            let round = self.update_round();
            let drained = sinks.iter().all(|s| s.is_disconnected());
            let pending: usize = self.shards.iter().map(|s| s.pending()).sum();
            let progressed = !round.outcomes.is_empty() || pending < pending_before;
            report.merge(round);
            if drained && (pending == 0 || !progressed) {
                break;
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn qmean(h: &RouterHandle, x: &Mat) -> Vec<f64> {
        let resp = h.query(&PredictRequest::new(x.clone(), QueryKind::Mean)).unwrap();
        resp.mean.as_slice().to_vec()
    }

    fn qvar(h: &RouterHandle, x: &Mat) -> (Vec<f64>, Vec<f64>) {
        let resp = h.query(&PredictRequest::new(x.clone(), QueryKind::MeanVar)).unwrap();
        (resp.mean.as_slice().to_vec(), resp.variance.unwrap())
    }

    fn snap_qmean(h: &SnapshotHandle, x: &Mat) -> Vec<f64> {
        let resp = h.query(&PredictRequest::new(x.clone(), QueryKind::Mean)).unwrap();
        resp.mean.as_slice().to_vec()
    }

    fn snap_qvar(h: &SnapshotHandle, x: &Mat) -> (Vec<f64>, Vec<f64>) {
        let resp = h.query(&PredictRequest::new(x.clone(), QueryKind::MeanVar)).unwrap();
        (resp.mean.as_slice().to_vec(), resp.variance.unwrap())
    }

    fn ev(x: Vec<f64>, y: f64, seq: u64) -> StreamEvent {
        StreamEvent::single(x, y, 0, seq)
    }

    #[test]
    fn bootstrap_partitions_round_robin() {
        let d = synth::ecg_like(62, 6, 1);
        let r = ShardRouter::bootstrap(
            &d.x,
            &d.y,
            ServeConfig::default_for(Kernel::poly(2, 1.0), 4),
        )
        .unwrap();
        assert_eq!(r.num_shards(), 4);
        // 62 = 16 + 16 + 15 + 15
        let sizes: Vec<usize> = (0..4).map(|i| r.shard(i).n_samples()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 62);
        assert!(sizes.iter().all(|&s| s == 15 || s == 16), "{sizes:?}");
        assert_eq!(r.n_samples(), 62);
    }

    #[test]
    fn bootstrap_rejects_degenerate_configs() {
        let d = synth::ecg_like(10, 4, 2);
        let cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 0);
        assert!(ShardRouter::bootstrap(&d.x, &d.y, cfg).is_err());
        let cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 8);
        assert!(ShardRouter::bootstrap(&d.x, &d.y, cfg).is_err(), "10 rows / 8 shards");
    }

    #[test]
    fn round_robin_and_hash_placement() {
        let d = synth::ecg_like(40, 4, 3);
        let mut r = ShardRouter::bootstrap(
            &d.x,
            &d.y,
            ServeConfig::default_for(Kernel::poly(2, 1.0), 3),
        )
        .unwrap();
        let e = ev(vec![1.0, 2.0, 3.0, 4.0], 0.5, 0);
        let s: Vec<usize> = (0..6).map(|_| r.route(&e)).collect();
        assert_eq!(s, vec![0, 1, 2, 0, 1, 2]);
        // hash placement is content-sticky
        r.placement = Placement::Hash;
        let h1 = r.route(&e);
        let h2 = r.route(&e);
        assert_eq!(h1, h2);
        assert!(h1 < 3);
    }

    #[test]
    fn ingest_and_update_round_advance_epochs() {
        let d = synth::ecg_like(48, 5, 4);
        let extra = synth::ecg_like(8, 5, 5);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.outlier = None;
        let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        for i in 0..8 {
            r.ingest(ev(extra.x.row(i).to_vec(), extra.y[i], i as u64));
        }
        let report = r.update_round();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(report.added(), 8);
        assert_eq!(r.n_samples(), 56);
        assert_eq!(r.handle().epochs(), vec![1, 1]);
        assert_eq!(r.counters().get("routed"), 8);
        assert_eq!(r.counters().get("rounds"), 1);
        let snap = r.handle().telemetry();
        assert_eq!(snap.counter(crate::telemetry::MetricId::Routed), 8);
        assert_eq!(
            snap.counter(crate::telemetry::MetricId::Added),
            8,
            "fleet view merges shard registries"
        );
        assert_eq!(snap.hist(crate::telemetry::HistId::RoundLatencyUs).count, 2);
    }

    #[test]
    fn quarantined_shard_is_skipped_and_renormalized() {
        use crate::serve::publish::ShardStatus;
        let d = synth::ecg_like(48, 5, 8);
        let q = synth::ecg_like(5, 5, 9);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = true;
        let r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        let h = r.handle();
        assert_eq!(h.num_serving(), 2);
        r.shard(1).set_status(ShardStatus::Quarantined);
        assert_eq!(h.num_serving(), 1);
        assert_eq!(
            h.statuses(),
            vec![ShardStatus::Healthy, ShardStatus::Quarantined]
        );
        // K−1 fan-in over one healthy shard == that shard's own answer
        let p = qmean(&h, &q.x);
        let p0 = snap_qmean(h.shard(0), &q.x);
        crate::testutil::assert_vec_close(&p, &p0, 1e-12);
        let (mu, var) = qvar(&h, &q.x);
        let (mu0, var0) = snap_qvar(h.shard(0), &q.x);
        crate::testutil::assert_vec_close(&mu, &mu0, 1e-12);
        crate::testutil::assert_vec_close(&var, &var0, 1e-12);
        // all-quarantined fails open to the full fan-in
        r.shard(0).set_status(ShardStatus::Quarantined);
        assert_eq!(h.num_serving(), 2);
        let p_open = qmean(&h, &q.x);
        r.shard(0).set_status(ShardStatus::Healthy);
        r.shard(1).set_status(ShardStatus::Healthy);
        let p_all = qmean(&h, &q.x);
        crate::testutil::assert_vec_close(&p_open, &p_all, 1e-12);
    }

    #[test]
    fn durable_router_round_trips_through_recovery() {
        use crate::persist::DurabilityConfig;
        use crate::testutil::ScratchDir;
        let dir = ScratchDir::new("router-durable");
        let d = synth::ecg_like(48, 5, 10);
        let extra = synth::ecg_like(8, 5, 11);
        let q = synth::ecg_like(6, 5, 12);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.placement = Placement::Hash;
        cfg.base.outlier = None;
        cfg.base.snapshot_rollback = true;
        let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        r.make_durable(
            dir.path(),
            DurabilityConfig { checkpoint_every: 2, keep_generations: 2 },
        )
        .unwrap();
        assert!(r.make_durable(dir.path(), DurabilityConfig::default()).is_err());
        for i in 0..8 {
            r.ingest(ev(extra.x.row(i).to_vec(), extra.y[i], (i + 1) as u64));
            let report = r.update_round();
            assert!(report.errors.is_empty(), "{:?}", report.errors);
        }
        let live = qmean(&r.handle(), &q.x);
        let seqs = r.high_seqs();
        drop(r);
        let mut rec = ShardRouter::recover(dir.path()).unwrap();
        assert_eq!(rec.placement(), Placement::Hash);
        assert_eq!(rec.num_shards(), 2);
        assert_eq!(rec.high_seqs(), seqs);
        assert!(rec.shard(0).is_durable() && rec.shard(1).is_durable());
        crate::testutil::assert_vec_close(&qmean(&rec.handle(), &q.x), &live, 1e-8);
        let dc = rec.durability_counters();
        assert!(dc.get("snapshots_written") >= 1, "{dc:?}");
        assert_eq!(dc.get("snapshot_fallbacks"), 0);
        // explicit updates bypass the WAL and are rejected on durable shards
        assert!(rec.shard_mut(0).apply_batch(&[]).is_err());
    }

    #[test]
    fn k1_router_is_the_single_engine() {
        use crate::coordinator::engine::Engine;
        let d = synth::ecg_like(50, 5, 6);
        let q = synth::ecg_like(7, 5, 7);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 1);
        cfg.base.with_uncertainty = true;
        let r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        let single = Engine::fit(
            &d.x,
            &d.y,
            &Kernel::poly(2, 1.0),
            0.5,
            r.space(),
            true,
        )
        .unwrap();
        let h = r.handle();
        crate::testutil::assert_vec_close(
            &qmean(&h, &q.x),
            &single.predict(&q.x).unwrap(),
            1e-12,
        );
        // precision fan-in is an exact identity at K = 1
        let (mu, var) = qvar(&h, &q.x);
        let (mu1, var1) = single.predict_with_uncertainty(&q.x).unwrap();
        crate::testutil::assert_vec_close(&mu, &mu1, 1e-12);
        crate::testutil::assert_vec_close(&var, &var1, 1e-12);
    }

    /// Every deprecated shim must be a bit-identical view of the unified
    /// query path — the contract that lets callers migrate incrementally.
    #[test]
    #[allow(deprecated)]
    fn legacy_shims_match_query_bitwise() {
        let d = synth::ecg_like(48, 5, 13);
        let q = synth::ecg_like(6, 5, 14);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.with_uncertainty = true;
        let r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        let h = r.handle();
        let mut work = RouterPredictWork::default();

        let mean = h.query(&PredictRequest::new(q.x.clone(), QueryKind::Mean)).unwrap();
        assert_eq!(h.predict(&q.x).unwrap(), mean.mean.as_slice());
        let mut out = Vec::new();
        h.predict_into(&q.x, &mut out, &mut work).unwrap();
        assert_eq!(out, mean.mean.as_slice());

        let multi = h.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanMulti)).unwrap();
        assert_eq!(h.predict_multi(&q.x).unwrap(), multi.mean);
        let mut outm = Mat::default();
        h.predict_multi_into(&q.x, &mut outm, &mut work).unwrap();
        assert_eq!(outm, multi.mean);

        let mv = h.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanVar)).unwrap();
        let (mu, var) = h.predict_with_uncertainty(&q.x).unwrap();
        assert_eq!(mu, mv.mean.as_slice());
        assert_eq!(Some(&var), mv.variance.as_ref());
        let (mut mu2, mut var2) = (Vec::new(), Vec::new());
        h.predict_with_uncertainty_into(&q.x, &mut mu2, &mut var2, &mut work).unwrap();
        assert_eq!(mu2, mv.mean.as_slice());
        assert_eq!(Some(&var2), mv.variance.as_ref());

        let mvm =
            h.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanVarMulti)).unwrap();
        let (mum, varm) = h.predict_with_uncertainty_multi(&q.x).unwrap();
        assert_eq!(mum, mvm.mean);
        assert_eq!(Some(&varm), mvm.variance.as_ref());
        let (mut mum2, mut varm2) = (Mat::default(), Vec::new());
        h.predict_with_uncertainty_multi_into(&q.x, &mut mum2, &mut varm2, &mut work)
            .unwrap();
        assert_eq!(mum2, mvm.mean);
        assert_eq!(Some(&varm2), mvm.variance.as_ref());

        // interval shim = query(MeanVar) + the interval transform
        let mut iv = Vec::new();
        h.predict_interval95_into(&q.x, &mut iv, &mut work).unwrap();
        let mut iv2 = Vec::new();
        crate::kbr::interval95_from_into(
            mv.mean.as_slice(),
            mv.variance.as_deref().unwrap(),
            &mut iv2,
        );
        assert_eq!(iv, iv2);

        // snapshot-level shims against SnapshotHandle::query
        let s = h.shard(0);
        let smean = s.query(&PredictRequest::new(q.x.clone(), QueryKind::Mean)).unwrap();
        assert_eq!(s.predict(&q.x).unwrap(), smean.mean.as_slice());
        let smulti =
            s.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanMulti)).unwrap();
        assert_eq!(s.predict_multi(&q.x).unwrap(), smulti.mean);
        let smv = s.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanVar)).unwrap();
        let (smu, svar) = s.predict_with_uncertainty(&q.x).unwrap();
        assert_eq!(smu, smv.mean.as_slice());
        assert_eq!(Some(&svar), smv.variance.as_ref());
        let smvm =
            s.query(&PredictRequest::new(q.x.clone(), QueryKind::MeanVarMulti)).unwrap();
        let (smum, svarm) = s.predict_with_uncertainty_multi(&q.x).unwrap();
        assert_eq!(smum, smvm.mean);
        assert_eq!(Some(&svarm), smvm.variance.as_ref());
    }
}
