//! The shard supervisor: bounded retry, poison-batch quarantine, shard
//! quarantine with self-heal, and periodic numerical health probes.
//!
//! One supervised round per shard runs the state machine documented in
//! [`super`]'s "Failure semantics and recovery" section:
//!
//! ```text
//!            flush Ok                      transient Err, attempt < R
//!  Healthy ───────────▶ Healthy    flush ──────────────────────────▶ retry
//!     │                              │        (backoff + jitter)
//!     │ permanent Err                │ transient Err, attempt == R
//!     ▼                              ▼
//!  batch quarantined  ◀──────────  batch quarantined
//!     │
//!     │ `quarantine_after` consecutive failed rounds
//!     ▼
//!  shard Quarantined ── heal (refit + republish) ──▶ Healthy
//! ```
//!
//! Retries are only attempted for errors where
//! [`crate::error::Error::is_transient`] is
//! true AND the shard's `snapshot_rollback` requeued the batch (without a
//! rollback the batch was dropped and a "retry" would consume the *next*
//! batch). Permanent errors skip the retry budget entirely: replaying a
//! deterministic failure R times is R−1 wasted updates.
//!
//! Durable shards fold in unchanged: a failed **write-ahead append**
//! leaves the engine untouched and always requeues, so a transient persist
//! error (`Error::Persist` with an I/O cause) rides the same bounded-retry
//! path, while persist *corruption* is permanent and quarantines like any
//! other deterministic failure. Heals on durable shards WAL-log a heal
//! record before refitting, so a crash mid-heal replays the refit on
//! recovery.
//!
//! Everything here runs on the writer side. Readers keep serving the last
//! published epoch through every retry, quarantine, and heal — the router
//! fan-ins only ever observe the [`ShardStatus`] cell flipping, which
//! drops a quarantined shard out of the average (K−1 serving) until its
//! heal republishes.

use crate::health::probe::{HealthProbe, HealthVerdict, ProbeConfig};
use crate::metrics::Counters;
use crate::streaming::StreamEvent;
use crate::telemetry::{FlightDump, HistId, MetricId, Registry, SpanKind};
use crate::util::prng::SplitMix64;
use std::sync::Arc;
use std::time::Duration;

use super::publish::ShardStatus;
use super::router::{RoundReport, ShardRouter};

#[cfg(feature = "chaos")]
use crate::health::fault::{FaultKind, FaultPlan};

/// Bounded-retry policy with deterministic exponential backoff + jitter.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts per batch (first try + retries), R ≥ 1.
    pub max_attempts: u32,
    /// Backoff before retry k is `base_backoff · 2^(k−1)`, capped below.
    pub base_backoff: Duration,
    /// Backoff cap.
    pub max_backoff: Duration,
    /// Jitter fraction in `[0, 1]`: the backoff is scaled by a
    /// deterministic factor in `[1 − jitter, 1 + jitter)`.
    pub jitter: f64,
    /// Seed for the jitter stream (same seed ⇒ same schedule).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            // retries are in-process recomputations, not network calls:
            // the backoff exists to let a transient CPU/contention blip
            // pass, so the scale is microseconds, not seconds
            base_backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(5),
            jitter: 0.5,
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// The backoff before retry `attempt` (1-based) of the work item
    /// identified by `key`. Pure function of `(seed, key, attempt)` — two
    /// runs with the same seed sleep the same schedule.
    pub fn backoff_for(&self, key: u64, attempt: u32) -> Duration {
        let exp = self
            .base_backoff
            .saturating_mul(1u32 << attempt.saturating_sub(1).min(20));
        let capped = exp.min(self.max_backoff);
        let mut sm = SplitMix64::new(
            self.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ u64::from(attempt),
        );
        let u = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let factor = 1.0 + self.jitter * (2.0 * u - 1.0);
        capped.mul_f64(factor.max(0.0))
    }
}

/// Supervisor tuning knobs.
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// Retry policy for transient flush failures.
    pub retry: RetryPolicy,
    /// Health-probe thresholds.
    pub probe: ProbeConfig,
    /// Probe cadence: check each shard every `probe_every` supervised
    /// rounds (0 disables probing).
    pub probe_every: u64,
    /// Consecutive failed rounds before the shard itself is quarantined.
    pub quarantine_after: u32,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            retry: RetryPolicy::default(),
            probe: ProbeConfig::default(),
            probe_every: 1,
            quarantine_after: 2,
        }
    }
}

/// A batch pulled out of the requeue loop for good: the events, why, and
/// how much retry budget they consumed. Inspectable evidence, never
/// re-applied.
#[derive(Debug)]
pub struct QuarantinedBatch {
    /// Shard the batch failed on.
    pub shard: usize,
    /// Supervised round it was quarantined in.
    pub round: u64,
    /// Attempts spent before quarantine (1 for permanent errors).
    pub attempts: u32,
    /// Display form of the final error.
    pub error: String,
    /// The events themselves (possibly empty if the shard's policy had
    /// already dropped them).
    pub events: Vec<StreamEvent>,
}

/// Per-shard supervisor state.
#[derive(Default)]
struct ShardState {
    probe: HealthProbe,
    consecutive_failed_rounds: u32,
}

/// Supervises a [`ShardRouter`]'s write path: drives flushes with bounded
/// retry, quarantines poison batches and failing shards, heals via refit,
/// and runs the periodic health probes.
pub struct ShardSupervisor {
    cfg: SupervisorConfig,
    states: Vec<ShardState>,
    quarantined: Vec<QuarantinedBatch>,
    /// Supervisor metric slots: retries / batches_quarantined /
    /// events_quarantined / shards_quarantined / shards_recovered /
    /// probe_breaches / probe_trips / heal_failures, plus the
    /// probe-residual trend histogram.
    telemetry: Arc<Registry>,
    /// One flight-recorder dump per shard quarantine, captured the moment
    /// the shard's status flips — the event trail leading into the
    /// failure, frozen before any heal can overwrite it.
    flight_dumps: Vec<FlightDump>,
    round: u64,
    #[cfg(feature = "chaos")]
    plan: Option<FaultPlan>,
}

impl ShardSupervisor {
    /// New supervisor for a router with `num_shards` shards.
    pub fn new(cfg: SupervisorConfig, num_shards: usize) -> Self {
        let mut states = Vec::with_capacity(num_shards);
        for _ in 0..num_shards {
            states.push(ShardState {
                probe: HealthProbe::new(cfg.probe.clone()),
                consecutive_failed_rounds: 0,
            });
        }
        Self {
            cfg,
            states,
            quarantined: Vec::new(),
            telemetry: Arc::new(Registry::new()),
            flight_dumps: Vec::new(),
            round: 0,
            #[cfg(feature = "chaos")]
            plan: None,
        }
    }

    /// Supervised rounds completed so far.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// The quarantined batches, oldest first.
    pub fn quarantined_batches(&self) -> &[QuarantinedBatch] {
        &self.quarantined
    }

    /// The supervisor-tier metrics registry.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Snapshot of the supervisor counters under their legacy string keys.
    pub fn counters(&self) -> Counters {
        self.telemetry.counters()
    }

    /// Flight-recorder dumps captured at each shard quarantine, oldest
    /// first. Each dump freezes the quarantined shard's span trail at the
    /// moment its status flipped.
    pub fn flight_dumps(&self) -> &[FlightDump] {
        &self.flight_dumps
    }

    /// Arm a deterministic fault plan: scheduled faults fire at the start
    /// of their `(shard, round)` supervised round.
    #[cfg(feature = "chaos")]
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.plan = Some(plan);
    }

    #[cfg(feature = "chaos")]
    fn inject(&mut self, router: &mut ShardRouter, si: usize) {
        let Some(plan) = &self.plan else { return };
        let round = self.round;
        // collect first: the injection needs &mut router while the plan
        // sits behind &self
        let kinds: Vec<FaultKind> = plan.firing(si, round).map(|f| f.kind).collect();
        for kind in kinds {
            let shard = router.shard_mut(si);
            match kind {
                FaultKind::NanRow => {
                    shard.chaos_mutate_front(|ev| ev.x.fill(f64::NAN));
                }
                FaultKind::InfRow => {
                    shard.chaos_mutate_front(|ev| ev.x.fill(f64::INFINITY));
                }
                FaultKind::PoisonRow => {
                    // finite, passes boundary validation, overflows the
                    // Gram matrix -> deterministic numerical failure
                    shard.chaos_mutate_front(|ev| ev.x.fill(1e200));
                }
                FaultKind::ForcedNumerical => shard.chaos_wedge(1),
                FaultKind::Wedge { rounds } => shard.chaos_wedge(rounds),
                FaultKind::CorruptInverse { factor } => {
                    shard.chaos_corrupt_inverse(factor);
                }
            }
            self.telemetry.inc(MetricId::FaultsInjected);
        }
    }

    /// One supervised round over every shard: heal quarantined shards,
    /// flush the rest with bounded retry, quarantine what can't succeed,
    /// then probe. Returns the same [`RoundReport`] shape as
    /// [`ShardRouter::update_round`]; quarantine details accumulate in
    /// [`ShardSupervisor::quarantined_batches`].
    pub fn supervise_round(&mut self, router: &mut ShardRouter) -> RoundReport {
        while self.states.len() < router.num_shards() {
            self.states.push(ShardState {
                probe: HealthProbe::new(self.cfg.probe.clone()),
                consecutive_failed_rounds: 0,
            });
        }
        let mut report = RoundReport::default();
        for si in 0..router.num_shards() {
            #[cfg(feature = "chaos")]
            self.inject(router, si);
            if router.shard(si).status() == ShardStatus::Quarantined {
                self.heal_shard(router, si);
                continue;
            }
            self.flush_with_retry(router, si, &mut report);
            self.probe_shard(router, si);
        }
        self.round += 1;
        report
    }

    /// Drive supervised rounds until every shard's pending queue is empty
    /// or quarantined away, up to `max_rounds`. The quarantine path is
    /// what makes this loop terminate on permanently failing input: every
    /// failed batch either succeeds within its retry budget or leaves the
    /// queue for good, so pending length strictly decreases.
    pub fn drain(&mut self, router: &mut ShardRouter, max_rounds: usize) -> RoundReport {
        let mut report = RoundReport::default();
        for _ in 0..max_rounds {
            let pending: usize = (0..router.num_shards())
                .map(|i| router.shard(i).pending())
                .sum();
            if pending == 0 {
                break;
            }
            report.merge(self.supervise_round(router));
        }
        report
    }

    fn heal_shard(&mut self, router: &mut ShardRouter, si: usize) {
        match router.shard_mut(si).heal() {
            Ok(_) => {
                self.states[si].consecutive_failed_rounds = 0;
                self.states[si].probe.reset();
                self.telemetry.inc(MetricId::ShardsRecovered);
            }
            Err(_) => {
                // refit itself failed: stay quarantined, try next round
                self.telemetry.inc(MetricId::HealFailures);
            }
        }
    }

    fn flush_with_retry(
        &mut self,
        router: &mut ShardRouter,
        si: usize,
        report: &mut RoundReport,
    ) {
        let max_attempts = self.cfg.retry.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            match router.shard_mut(si).flush() {
                Ok(Some(out)) => {
                    report.outcomes.push(out);
                    self.mark_round_ok(router, si);
                    return;
                }
                Ok(None) => {
                    // nothing pending (or only rejected events): a no-op
                    // round is a healthy round
                    self.mark_round_ok(router, si);
                    return;
                }
                Err(e) => {
                    let shard = router.shard_mut(si);
                    let requeued = shard.last_attempt_len() > 0
                        && shard.pending() >= shard.last_attempt_len();
                    let retryable = e.is_transient() && requeued;
                    if retryable && attempt < max_attempts {
                        self.telemetry.inc(MetricId::Retries);
                        shard.record_span(SpanKind::Retry, si as u64, u64::from(attempt));
                        let key = ((si as u64) << 32) | self.round;
                        std::thread::sleep(self.cfg.retry.backoff_for(key, attempt));
                        continue;
                    }
                    // out of budget (or unretryable): quarantine the batch
                    let n = shard.last_attempt_len();
                    let events = shard.quarantine_front(n);
                    self.telemetry.inc(MetricId::BatchesQuarantined);
                    self.telemetry.add(MetricId::EventsQuarantined, events.len() as u64);
                    self.quarantined.push(QuarantinedBatch {
                        shard: si,
                        round: self.round,
                        attempts: attempt,
                        error: e.to_string(),
                        events,
                    });
                    self.mark_round_failed(router, si);
                    report.errors.push((si, e));
                    return;
                }
            }
        }
    }

    fn mark_round_ok(&mut self, router: &ShardRouter, si: usize) {
        self.states[si].consecutive_failed_rounds = 0;
        if router.shard(si).status() == ShardStatus::Degraded {
            router.shard(si).set_status(ShardStatus::Healthy);
        }
    }

    fn mark_round_failed(&mut self, router: &mut ShardRouter, si: usize) {
        let st = &mut self.states[si];
        st.consecutive_failed_rounds += 1;
        if st.consecutive_failed_rounds >= self.cfg.quarantine_after {
            self.quarantine_shard(router, si);
        } else {
            router.shard(si).set_status(ShardStatus::Degraded);
        }
    }

    /// Flip the shard to `Quarantined` and freeze its flight recorder:
    /// the dump captures the span trail that led into the failure before
    /// any heal attempt can push it out of the ring.
    fn quarantine_shard(&mut self, router: &mut ShardRouter, si: usize) {
        router.shard(si).set_status(ShardStatus::Quarantined);
        self.telemetry.inc(MetricId::ShardsQuarantined);
        let round = self.round;
        let shard = router.shard_mut(si);
        shard.record_span(SpanKind::Quarantine, si as u64, round);
        self.flight_dumps
            .push(shard.flight_dump(format!("shard-{si} quarantine round {round}")));
    }

    fn probe_shard(&mut self, router: &mut ShardRouter, si: usize) {
        if self.cfg.probe_every == 0 || self.round % self.cfg.probe_every != 0 {
            return;
        }
        let checked = self.states[si].probe.check(router.shard(si).engine());
        let verdict = match checked {
            Ok(rep) => {
                // residual trend in pico-units: residuals near the trip
                // threshold sit around 1e-8..1e-3, far below the 1µ-unit
                // resolution the latency histograms use
                let picos = (rep.max_residual * 1e12) as u64;
                self.telemetry.record_hist(HistId::ProbeResidualPicos, picos);
                router.shard_mut(si).record_span(
                    SpanKind::Probe,
                    picos,
                    rep.consecutive_breaches as u64,
                );
                rep.verdict
            }
            // a probe that cannot even run is a critical signal
            Err(_) => HealthVerdict::Critical,
        };
        match verdict {
            HealthVerdict::Healthy => {}
            HealthVerdict::Degraded => {
                self.telemetry.inc(MetricId::ProbeBreaches);
                if router.shard(si).status() == ShardStatus::Healthy {
                    router.shard(si).set_status(ShardStatus::Degraded);
                }
            }
            HealthVerdict::Critical => {
                self.telemetry.inc(MetricId::ProbeBreaches);
                self.telemetry.inc(MetricId::ProbeTrips);
                // self-heal immediately on the writer copy; readers keep
                // serving the published epoch throughout
                match router.shard_mut(si).heal() {
                    Ok(_) => {
                        self.states[si].probe.reset();
                        self.telemetry.inc(MetricId::Heals);
                    }
                    Err(_) => {
                        self.telemetry.inc(MetricId::HealFailures);
                        self.quarantine_shard(router, si);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernels::Kernel;
    use crate::serve::router::ServeConfig;

    fn serve_cfg(shards: usize) -> ServeConfig {
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), shards);
        cfg.base.outlier = None;
        cfg.base.snapshot_rollback = true;
        cfg
    }

    fn router(shards: usize) -> ShardRouter {
        let d = synth::ecg_like(48, 5, 41);
        ShardRouter::bootstrap(&d.x, &d.y, serve_cfg(shards)).unwrap()
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_grows() {
        let p = RetryPolicy::default();
        let a = p.backoff_for(7, 1);
        let b = p.backoff_for(7, 1);
        assert_eq!(a, b, "same (seed, key, attempt) ⇒ same backoff");
        assert_ne!(p.backoff_for(8, 1), a, "different keys jitter apart");
        for attempt in 1..8 {
            assert!(p.backoff_for(7, attempt) <= p.max_backoff.mul_f64(1.0 + p.jitter));
        }
        // the exponential envelope grows until the cap
        let p0 = RetryPolicy { jitter: 0.0, ..RetryPolicy::default() };
        assert!(p0.backoff_for(1, 2) > p0.backoff_for(1, 1));
        assert_eq!(p0.backoff_for(1, 12), p0.max_backoff);
    }

    #[test]
    fn clean_traffic_supervises_like_update_round() {
        let mut r = router(2);
        let mut sup = ShardSupervisor::new(SupervisorConfig::default(), r.num_shards());
        let extra = synth::ecg_like(8, 5, 42);
        for i in 0..8 {
            r.ingest(StreamEvent::single(extra.x.row(i).to_vec(), extra.y[i], 0, i as u64));
        }
        let rep = sup.drain(&mut r, 16);
        assert!(rep.errors.is_empty(), "{:?}", rep.errors);
        assert_eq!(rep.added(), 8);
        assert!(sup.quarantined_batches().is_empty());
        assert_eq!(sup.counters().get("batches_quarantined"), 0);
        assert!(sup.flight_dumps().is_empty(), "no quarantine, no dump");
        assert!(r.handle().statuses().iter().all(|s| *s == ShardStatus::Healthy));
    }

    #[test]
    fn nonfinite_events_rejected_at_boundary_not_quarantined() {
        let mut r = router(2);
        let mut sup = ShardSupervisor::new(SupervisorConfig::default(), r.num_shards());
        r.ingest(StreamEvent::single(vec![f64::NAN; 5], 0.0, 0, 0));
        r.ingest(StreamEvent::single(vec![1.0, 2.0, f64::INFINITY, 0.0, 0.0], 0.0, 0, 1));
        let rep = sup.drain(&mut r, 8);
        assert!(rep.errors.is_empty());
        let nonfinite: u64 = (0..r.num_shards())
            .map(|i| r.shard(i).counters().get("rejected_nonfinite"))
            .sum();
        assert_eq!(nonfinite, 2, "both bad rows counted at the boundary");
        assert!(sup.quarantined_batches().is_empty(), "rejects are not quarantines");
    }

    #[test]
    fn poison_batch_quarantined_after_budget_then_shard_recovers() {
        let mut r = router(2);
        let cfg = SupervisorConfig {
            retry: RetryPolicy {
                max_attempts: 3,
                base_backoff: Duration::ZERO,
                max_backoff: Duration::ZERO,
                jitter: 0.0,
                seed: 1,
            },
            quarantine_after: 2,
            ..SupervisorConfig::default()
        };
        let mut sup = ShardSupervisor::new(cfg, r.num_shards());
        // poison: finite but overflows the poly2 Gram -> Numerical every try
        r.shard_mut(0).push(StreamEvent::single(vec![1e200; 5], 0.0, 0, 0));
        let good = synth::ecg_like(2, 5, 43);
        r.shard_mut(1).push(StreamEvent::single(good.x.row(0).to_vec(), good.y[0], 0, 1));
        let rep = sup.drain(&mut r, 8);
        assert_eq!(rep.errors.len(), 1, "poison shard reports exactly one failure");
        assert_eq!(sup.counters().get("retries"), 2, "R−1 retries before quarantine");
        assert_eq!(sup.counters().get("batches_quarantined"), 1);
        let q = &sup.quarantined_batches()[0];
        assert_eq!((q.shard, q.attempts), (0, 3));
        assert_eq!(q.events.len(), 1, "the poison event is inspectable");
        assert_eq!(r.shard(0).pending(), 0, "nothing left looping in the queue");
        // one failed round < quarantine_after=2: degraded, not quarantined
        assert_eq!(r.shard(0).status(), ShardStatus::Degraded);
        // clean traffic heals the degraded marker
        r.shard_mut(0).push(StreamEvent::single(good.x.row(1).to_vec(), good.y[1], 0, 2));
        sup.drain(&mut r, 4);
        assert_eq!(r.shard(0).status(), ShardStatus::Healthy);
    }

    #[test]
    fn dropped_batch_is_not_retried() {
        // without snapshot rollback the shard DROPS a failed batch (a
        // retry would double-apply a partially absorbed update), so the
        // supervisor must not retry — it would consume the NEXT batch
        let d = synth::ecg_like(48, 5, 45);
        let mut cfg = serve_cfg(2);
        cfg.base.snapshot_rollback = false;
        let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
        let mut sup = ShardSupervisor::new(SupervisorConfig::default(), r.num_shards());
        r.shard_mut(0).push(StreamEvent::single(vec![1e200; 5], 0.0, 0, 0));
        let rep = sup.drain(&mut r, 4);
        assert_eq!(rep.errors.len(), 1);
        assert_eq!(sup.counters().get("retries"), 0, "dropped batches never retry");
        assert_eq!(sup.counters().get("batches_quarantined"), 1);
        assert!(
            sup.quarantined_batches()[0].events.is_empty(),
            "events were already dropped by the shard's policy"
        );
        assert_eq!(r.shard(0).counters().get("dropped"), 1);
    }

    #[test]
    fn quarantined_shard_heals_and_rejoins() {
        let mut r = router(2);
        let cfg = SupervisorConfig {
            retry: RetryPolicy {
                max_attempts: 1,
                base_backoff: Duration::ZERO,
                max_backoff: Duration::ZERO,
                jitter: 0.0,
                seed: 2,
            },
            quarantine_after: 1,
            ..SupervisorConfig::default()
        };
        let mut sup = ShardSupervisor::new(cfg, r.num_shards());
        // one poison batch + quarantine_after=1 -> the shard quarantines
        r.shard_mut(0).push(StreamEvent::single(vec![1e200; 5], 0.0, 0, 0));
        sup.supervise_round(&mut r);
        assert_eq!(r.shard(0).status(), ShardStatus::Quarantined);
        assert_eq!(r.handle().num_serving(), 1);
        // the quarantine froze a flight dump with the failing round's trail
        assert_eq!(sup.flight_dumps().len(), 1);
        let dump = &sup.flight_dumps()[0];
        assert!(dump.label.contains("shard-0"), "{}", dump.label);
        assert!(
            dump.events.iter().any(|e| e.kind == crate::telemetry::SpanKind::Quarantine),
            "dump ends with the quarantine marker"
        );
        let q = synth::ecg_like(3, 5, 44);
        // reads still answered from the healthy shard
        assert_eq!(r.handle().predict(&q.x).unwrap().len(), 3);
        // next supervised round heals it (refit from retained stores)
        let e0 = r.shard(0).handle().epoch();
        sup.supervise_round(&mut r);
        assert_eq!(r.shard(0).status(), ShardStatus::Healthy);
        assert_eq!(sup.counters().get("shards_recovered"), 1);
        assert!(r.shard(0).handle().epoch() > e0, "heal republishes");
        assert_eq!(r.handle().num_serving(), 2);
    }
}
