//! Epoch publishing: wait-free-for-practical-purposes snapshot reads under
//! a continuously updating writer.
//!
//! The coordinator's [`crate::coordinator::ModelHandle`] serves reads
//! through an `RwLock<Engine>` — correct, but the write guard is held for
//! the whole O(J²H) update, so every predict issued during an update round
//! blocks until the round finishes. At serving scale (the ROADMAP's
//! millions-of-users regime) that turns each update into a latency spike
//! across the entire read fleet.
//!
//! [`Epoch`] inverts the contract: the writer mutates a **private** copy of
//! the state and, when a round completes, publishes an immutable
//! [`Arc`] snapshot with a pointer swap. Readers load the current snapshot
//! and compute against it lock-free — the only shared critical section is
//! the swap/refcount itself (a few dozen nanoseconds under a `Mutex`; the
//! offline crate set has no `arc-swap`, and a mutex held only for a
//! pointer clone never sees meaningful contention). An in-flight update
//! therefore *cannot* delay a read: readers simply keep serving the last
//! published epoch until the next one lands, which is exactly the
//! freshness semantics an incremental model update implies anyway.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Serving status of one shard, shared writer→readers the same way the
/// epoch snapshot is: the supervisor (writer side) stores it, read handles
/// load it per fan-in and skip quarantined shards (see
/// [`crate::serve::RouterHandle`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStatus {
    /// Serving and accepting updates.
    Healthy,
    /// Serving, but under observation (probe breach or recent failures).
    Degraded,
    /// Not trusted for reads: the router fans in over the other K−1 shards
    /// until a background refit republishes and the supervisor clears it.
    Quarantined,
}

/// Lock-free shared cell holding a [`ShardStatus`] (one `AtomicU8`).
#[derive(Debug, Default)]
pub struct HealthCell {
    status: AtomicU8,
}

impl HealthCell {
    const HEALTHY: u8 = 0;
    const DEGRADED: u8 = 1;
    const QUARANTINED: u8 = 2;

    /// New cell, starting [`ShardStatus::Healthy`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Current status.
    pub fn get(&self) -> ShardStatus {
        match self.status.load(Ordering::Acquire) {
            Self::HEALTHY => ShardStatus::Healthy,
            Self::DEGRADED => ShardStatus::Degraded,
            _ => ShardStatus::Quarantined,
        }
    }

    /// Store a new status.
    pub fn set(&self, s: ShardStatus) {
        let v = match s {
            ShardStatus::Healthy => Self::HEALTHY,
            ShardStatus::Degraded => Self::DEGRADED,
            ShardStatus::Quarantined => Self::QUARANTINED,
        };
        self.status.store(v, Ordering::Release);
    }

    /// True when reads may use this shard (anything but quarantined —
    /// degraded shards still serve; quarantine is the only read-side cut).
    pub fn serving(&self) -> bool {
        self.status.load(Ordering::Acquire) != Self::QUARANTINED
    }
}

/// A single-writer multi-reader epoch-published slot.
///
/// Epoch 0 is the bootstrap state; every [`Epoch::publish`] increments the
/// counter. The epoch number and the snapshot are updated together inside
/// the (pointer-swap-only) critical section, so
/// [`Epoch::load_with_epoch`] returns a consistent pair.
pub struct Epoch<T> {
    slot: Mutex<Arc<T>>,
    epoch: AtomicU64,
}

impl<T> Epoch<T> {
    /// Wrap a bootstrap state as epoch 0.
    pub fn new(initial: T) -> Self {
        Self::new_at(initial, 0)
    }

    /// Wrap a restored state at a non-zero starting epoch — recovery
    /// republishes a shard at the epoch its snapshot + WAL replay
    /// reconstructed, so sequence-based idempotency keeps working across
    /// the restart.
    pub fn new_at(initial: T, epoch: u64) -> Self {
        Self { slot: Mutex::new(Arc::new(initial)), epoch: AtomicU64::new(epoch) }
    }

    /// The most recently published snapshot. Never blocks on an in-flight
    /// update: the lock guards only the pointer clone.
    pub fn load(&self) -> Arc<T> {
        self.slot.lock().expect("epoch slot poisoned").clone()
    }

    /// Snapshot and its epoch number, read consistently.
    pub fn load_with_epoch(&self) -> (Arc<T>, u64) {
        let g = self.slot.lock().expect("epoch slot poisoned");
        (g.clone(), self.epoch.load(Ordering::Acquire))
    }

    /// Current epoch number (0 until the first publish).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Publish a new state, returning its epoch number. The value is
    /// wrapped *outside* the critical section; readers that raced the swap
    /// keep the previous snapshot (their `Arc` keeps it alive) and observe
    /// the new one on their next load.
    pub fn publish(&self, value: T) -> u64 {
        self.publish_arc(Arc::new(value))
    }

    /// [`Epoch::publish`] for a pre-wrapped snapshot.
    pub fn publish_arc(&self, value: Arc<T>) -> u64 {
        let mut g = self.slot.lock().expect("epoch slot poisoned");
        // keep the previous snapshot alive past the critical section: if
        // this was its last reference, dropping it here would free the
        // whole engine state while readers wait on the lock
        let old = std::mem::replace(&mut *g, value);
        // bumped inside the critical section so load_with_epoch is
        // consistent; Release pairs with the Acquire loads above
        let epoch = self.epoch.fetch_add(1, Ordering::Release) + 1;
        drop(g);
        drop(old);
        epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Barrier;
    use std::time::{Duration, Instant};

    #[test]
    fn health_cell_round_trips_all_statuses() {
        let c = HealthCell::new();
        assert_eq!(c.get(), ShardStatus::Healthy);
        assert!(c.serving());
        c.set(ShardStatus::Degraded);
        assert_eq!(c.get(), ShardStatus::Degraded);
        assert!(c.serving(), "degraded shards still serve");
        c.set(ShardStatus::Quarantined);
        assert_eq!(c.get(), ShardStatus::Quarantined);
        assert!(!c.serving());
        c.set(ShardStatus::Healthy);
        assert!(c.serving());
    }

    #[test]
    fn publish_bumps_epoch_and_swaps() {
        let cell = Epoch::new(10usize);
        assert_eq!(cell.epoch(), 0);
        assert_eq!(*cell.load(), 10);
        assert_eq!(cell.publish(11), 1);
        let (v, e) = cell.load_with_epoch();
        assert_eq!((*v, e), (11, 1));
    }

    #[test]
    fn readers_keep_old_snapshot_alive_across_publish() {
        let cell = Epoch::new(vec![1.0f64; 8]);
        let held = cell.load();
        cell.publish(vec![2.0; 8]);
        // the pre-publish snapshot is still fully readable
        assert_eq!(held[0], 1.0);
        assert_eq!(cell.load()[0], 2.0);
    }

    #[test]
    fn reads_are_served_while_an_update_is_in_flight() {
        // deterministic in-flight window: the writer signals through a
        // barrier right after it STARTS its (simulated, 200ms) update
        // compute; the reader then loads immediately and must get the old
        // epoch without waiting for the writer to finish.
        let cell = Arc::new(Epoch::new(0usize));
        let barrier = Arc::new(Barrier::new(2));
        let (c, b) = (Arc::clone(&cell), Arc::clone(&barrier));
        let writer = std::thread::spawn(move || {
            b.wait();
            // "the update": a long compute on the writer's private state
            std::thread::sleep(Duration::from_millis(200));
            c.publish(1)
        });
        barrier.wait();
        let t0 = Instant::now();
        let (v, e) = cell.load_with_epoch();
        let dt = t0.elapsed();
        assert_eq!((*v, e), (0, 0), "read must serve the last published epoch");
        assert!(
            dt < Duration::from_millis(100),
            "read blocked behind the in-flight update: {dt:?}"
        );
        assert_eq!(writer.join().unwrap(), 1);
        assert_eq!(*cell.load(), 1);
    }
}
