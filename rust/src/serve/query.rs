//! The unified serving query surface: one request/response pair for every
//! predict flavor the serve layer used to spell out by hand.
//!
//! Before this module the shard/router/micro-batch layers each carried the
//! `{predict, predict_multi, predict_with_uncertainty,
//! predict_with_uncertainty_multi} × {owned, _into}` explosion — 17 public
//! methods whose bodies differed only in which engine kernel they called and
//! how the DC-KRR fan-in accumulated. [`PredictRequest`] collapses the
//! *what* into a [`QueryKind`] and leaves the *how* to one `query` entry
//! point per layer; the legacy names survive as thin deprecated shims.
//!
//! The same two types are the canonical wire payloads of the network
//! serving front-end ([`crate::net`]): [`PredictRequest::encode_into`] /
//! [`PredictRequest::decode_from`] mirror
//! [`crate::streaming::StreamEvent::encode_into`] — little-endian, f64s as
//! IEEE-754 bit patterns (bit-exact round trips), every decode
//! bounds-checked against hostile lengths so a flipped or forged header can
//! reject but never panic or drive an unbounded allocation.

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::persist::codec::{put_u32, put_u8, Cursor};

/// Which estimator a query wants, and the shape of its answer.
///
/// The serving tier maintains two estimators per shard (the KRR point
/// predictor and, when configured, its KBR Bayesian twin) over a `(N, D)`
/// target matrix. The four kinds are the cross product of
/// {point, posterior} × {`D = 1` scalar surface, multi-output}:
///
/// | kind            | engine path            | `mean` shape | `variance`     |
/// |-----------------|------------------------|--------------|----------------|
/// | `Mean`          | KRR point, `D = 1`     | `(B, 1)`     | `None`         |
/// | `MeanMulti`     | KRR point, any `D`     | `(B, D)`     | `None`         |
/// | `MeanVar`       | KBR posterior, `D = 1` | `(B, 1)`     | `Some(len B)`  |
/// | `MeanVarMulti`  | KBR posterior, any `D` | `(B, D)`     | `Some(len B)`  |
///
/// The `D = 1` kinds are not redundant with the multi kinds: they run the
/// engines' GEMV surface while the multi kinds run the packed `(B, D)` GEMM,
/// and the serving tier's parity tests pin each path bitwise. Mixing them in
/// one micro-batch window is safe — execution always dispatches per-kind
/// sub-batches (see [`crate::serve::MicroBatchServer`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryKind {
    /// KRR point prediction, `D = 1` scalar surface.
    Mean,
    /// KRR point prediction, multi-output `(B, D)`.
    MeanMulti,
    /// KBR posterior mean + variance, `D = 1` scalar surface.
    MeanVar,
    /// KBR posterior `(B, D)` means + ONE shared variance per row.
    MeanVarMulti,
}

impl QueryKind {
    /// All kinds, in wire-tag order (also the micro-batch lane order).
    pub const ALL: [QueryKind; 4] =
        [QueryKind::Mean, QueryKind::MeanMulti, QueryKind::MeanVar, QueryKind::MeanVarMulti];

    /// True for the KBR posterior kinds (the response carries a variance).
    pub fn wants_variance(self) -> bool {
        matches!(self, QueryKind::MeanVar | QueryKind::MeanVarMulti)
    }

    /// True for the multi-output kinds (the `D = 1` guard is skipped).
    pub fn is_multi(self) -> bool {
        matches!(self, QueryKind::MeanMulti | QueryKind::MeanVarMulti)
    }

    /// Wire tag (`u8`) — also the lane index used by the batch executor.
    pub fn wire(self) -> u8 {
        match self {
            QueryKind::Mean => 0,
            QueryKind::MeanMulti => 1,
            QueryKind::MeanVar => 2,
            QueryKind::MeanVarMulti => 3,
        }
    }

    /// Inverse of [`QueryKind::wire`]; a hostile tag is corruption.
    pub fn from_wire(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(QueryKind::Mean),
            1 => Ok(QueryKind::MeanMulti),
            2 => Ok(QueryKind::MeanVar),
            3 => Ok(QueryKind::MeanVarMulti),
            other => Err(Error::persist_corruption(
                "QueryKind::from_wire",
                format!("unknown query kind tag {other}"),
            )),
        }
    }

    /// Lane index for per-kind sub-batch bookkeeping.
    pub(crate) fn lane(self) -> usize {
        self.wire() as usize
    }
}

/// One serving query: a `(B, dim)` batch of query rows plus the
/// [`QueryKind`] selecting estimator and output shape.
///
/// `B = 1` is the common single-row case; multi-row requests ride the same
/// path and coalesce into the same packed GEMM window.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictRequest {
    /// Query points, one per row.
    pub x: Mat,
    /// Which estimator to run and what shape to answer with.
    pub want: QueryKind,
}

impl PredictRequest {
    /// Request over a `(B, dim)` batch.
    pub fn new(x: Mat, want: QueryKind) -> Self {
        Self { x, want }
    }

    /// Single-row convenience: wraps `row` as a `(1, dim)` batch.
    pub fn single(row: &[f64], want: QueryKind) -> Self {
        let mut x = Mat::zeros(1, row.len());
        x.as_mut_slice().copy_from_slice(row);
        Self { x, want }
    }

    /// Append the wire encoding:
    ///
    /// ```text
    /// [want: u8][rows: u32][cols: u32][x: rows*cols f64 bit patterns]
    /// ```
    ///
    /// Little-endian throughout; f64s round-trip bit-exact.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        put_u8(out, self.want.wire());
        put_u32(out, self.x.rows() as u32);
        put_u32(out, self.x.cols() as u32);
        out.reserve(self.x.as_slice().len() * 8);
        for &v in self.x.as_slice() {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    /// Decode one request from `cur`, bounds-checking every read.
    ///
    /// Hostile `rows`/`cols` values are rejected against the bytes actually
    /// present before any allocation happens, so a forged header cannot
    /// drive an out-of-memory — the same standard as
    /// [`crate::persist::codec`]'s section reader.
    pub fn decode_from(cur: &mut Cursor<'_>) -> Result<Self> {
        const CTX: &str = "PredictRequest::decode_from";
        let want = QueryKind::from_wire(cur.take_u8()?)?;
        let rows = cur.take_u32()? as usize;
        let cols = cur.take_u32()? as usize;
        let n = rows.checked_mul(cols).ok_or_else(|| {
            Error::persist_corruption(CTX, format!("{rows}x{cols} overflows"))
        })?;
        if n.saturating_mul(8) > cur.remaining() {
            return Err(Error::persist_corruption(
                CTX,
                format!("{rows}x{cols} needs {n} f64s but only {} bytes remain", cur.remaining()),
            ));
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(cur.take_f64()?);
        }
        let x = Mat::from_vec(rows, cols, data)
            .map_err(|e| Error::persist_corruption(CTX, format!("bad shape: {e}")))?;
        Ok(Self { x, want })
    }
}

/// The answer to a [`PredictRequest`].
///
/// `mean` is `(B, D)` (`D = 1` for the scalar kinds); `variance` is present
/// exactly for the [`QueryKind::wants_variance`] kinds, one posterior
/// variance per query row (multi-output shards share ONE variance across
/// the `D` targets — see the engine docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PredictResponse {
    /// Predicted means, one row per query row.
    pub mean: Mat,
    /// Posterior variances (`len == mean.rows()`), KBR kinds only.
    pub variance: Option<Vec<f64>>,
}

impl PredictResponse {
    /// The single scalar answer of a 1-row `D = 1` response.
    pub fn scalar(&self) -> f64 {
        self.mean[(0, 0)]
    }

    /// The variance of query row `r` (panics if this response has none).
    pub fn variance_at(&self, r: usize) -> f64 {
        self.variance.as_ref().expect("response carries no variance")[r]
    }

    /// Append the wire encoding:
    ///
    /// ```text
    /// [has_var: u8][rows: u32][cols: u32]
    /// [mean: rows*cols f64 bit patterns][variance: rows f64s if has_var]
    /// ```
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.encode_rows_into(out, 0, self.mean.rows());
    }

    /// Encode the row window `[start, start + rows)` as a standalone
    /// response — the reactor's way of slicing one client's answer out of
    /// a batched window without materializing a sub-matrix.
    pub fn encode_rows_into(&self, out: &mut Vec<u8>, start: usize, rows: usize) {
        debug_assert!(start + rows <= self.mean.rows());
        let cols = self.mean.cols();
        put_u8(out, u8::from(self.variance.is_some()));
        put_u32(out, rows as u32);
        put_u32(out, cols as u32);
        let m = &self.mean.as_slice()[start * cols..(start + rows) * cols];
        out.reserve((m.len() + rows) * 8);
        for &v in m {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        if let Some(var) = &self.variance {
            for &v in &var[start..start + rows] {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
    }

    /// Decode one response, bounds-checked like
    /// [`PredictRequest::decode_from`].
    pub fn decode_from(cur: &mut Cursor<'_>) -> Result<Self> {
        const CTX: &str = "PredictResponse::decode_from";
        let has_var = match cur.take_u8()? {
            0 => false,
            1 => true,
            other => {
                return Err(Error::persist_corruption(
                    CTX,
                    format!("bad has_var flag {other}"),
                ))
            }
        };
        let rows = cur.take_u32()? as usize;
        let cols = cur.take_u32()? as usize;
        let n = rows.checked_mul(cols).ok_or_else(|| {
            Error::persist_corruption(CTX, format!("{rows}x{cols} overflows"))
        })?;
        let total = n + if has_var { rows } else { 0 };
        if total.saturating_mul(8) > cur.remaining() {
            return Err(Error::persist_corruption(
                CTX,
                format!("{rows}x{cols} needs {total} f64s but only {} bytes remain", cur.remaining()),
            ));
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(cur.take_f64()?);
        }
        let mean = Mat::from_vec(rows, cols, data)
            .map_err(|e| Error::persist_corruption(CTX, format!("bad shape: {e}")))?;
        let variance = if has_var {
            let mut v = Vec::with_capacity(rows);
            for _ in 0..rows {
                v.push(cur.take_f64()?);
            }
            Some(v)
        } else {
            None
        };
        Ok(Self { mean, variance })
    }

    /// Reset to an empty-but-warm state, parking any variance buffer in
    /// `spare` so alternating variance/no-variance queries stay
    /// allocation-free.
    pub(crate) fn clear_into_spare(&mut self, spare: &mut Vec<f64>) {
        if let Some(mut v) = self.variance.take() {
            if v.capacity() > spare.capacity() {
                v.clear();
                *spare = v;
            }
        }
    }

    /// Take (or revive from `spare`) the variance buffer for writing.
    pub(crate) fn take_variance_buf(&mut self, spare: &mut Vec<f64>) -> Vec<f64> {
        let mut v = self.variance.take().unwrap_or_else(|| std::mem::take(spare));
        v.clear();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_req(want: QueryKind) -> PredictRequest {
        let x = Mat::from_fn(3, 4, |r, c| (r * 4 + c) as f64 * 0.25 - 1.0);
        PredictRequest::new(x, want)
    }

    #[test]
    fn kind_wire_round_trips() {
        for k in QueryKind::ALL {
            assert_eq!(QueryKind::from_wire(k.wire()).unwrap(), k);
            assert_eq!(k.lane(), k.wire() as usize);
        }
        assert!(QueryKind::from_wire(4).is_err());
        assert!(QueryKind::MeanVar.wants_variance() && !QueryKind::MeanVar.is_multi());
        assert!(QueryKind::MeanVarMulti.wants_variance() && QueryKind::MeanVarMulti.is_multi());
        assert!(!QueryKind::Mean.wants_variance());
        assert!(QueryKind::MeanMulti.is_multi());
    }

    #[test]
    fn request_round_trips_bit_exact() {
        for k in QueryKind::ALL {
            let mut req = sample_req(k);
            // NaN payloads and signed zeros must survive
            req.x[(0, 0)] = f64::from_bits(0x7FF8_0000_0000_1234);
            req.x[(1, 1)] = -0.0;
            let mut buf = Vec::new();
            req.encode_into(&mut buf);
            let mut cur = Cursor::new(&buf, "test");
            let back = PredictRequest::decode_from(&mut cur).unwrap();
            assert!(cur.is_empty());
            assert_eq!(back.want, k);
            assert_eq!(back.x.shape(), req.x.shape());
            for (a, b) in back.x.as_slice().iter().zip(req.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn response_round_trips_and_row_slicing_matches() {
        let mean = Mat::from_fn(4, 2, |r, c| (r as f64) * 10.0 + c as f64);
        let resp =
            PredictResponse { mean, variance: Some(vec![0.1, 0.2, 0.3, 0.4]) };
        let mut buf = Vec::new();
        resp.encode_into(&mut buf);
        let mut cur = Cursor::new(&buf, "test");
        let back = PredictResponse::decode_from(&mut cur).unwrap();
        assert!(cur.is_empty());
        assert_eq!(back, resp);

        // a row-window encoding decodes to exactly that sub-response
        let mut win = Vec::new();
        resp.encode_rows_into(&mut win, 1, 2);
        let mut cur = Cursor::new(&win, "test");
        let sub = PredictResponse::decode_from(&mut cur).unwrap();
        assert_eq!(sub.mean, resp.mean.block(1, 3, 0, 2));
        assert_eq!(sub.variance.unwrap(), vec![0.2, 0.3]);

        // no-variance responses omit the tail
        let novar = PredictResponse { mean: Mat::zeros(2, 1), variance: None };
        let mut buf2 = Vec::new();
        novar.encode_into(&mut buf2);
        let mut cur = Cursor::new(&buf2, "test");
        assert_eq!(PredictResponse::decode_from(&mut cur).unwrap(), novar);
    }

    #[test]
    fn request_rejects_truncation_and_bit_flips() {
        let req = sample_req(QueryKind::MeanVarMulti);
        let mut buf = Vec::new();
        req.encode_into(&mut buf);
        for cut in 0..buf.len() {
            let mut cur = Cursor::new(&buf[..cut], "test");
            let r = PredictRequest::decode_from(&mut cur);
            // every strict prefix must fail or decode fewer bytes than sent
            if let Ok(back) = r {
                assert!(back.x.as_slice().len() < req.x.as_slice().len());
            }
        }
        // header flips either fail or change the decoded value — never panic
        for i in 0..9.min(buf.len()) {
            let mut bad = buf.clone();
            bad[i] ^= 0xFF;
            let mut cur = Cursor::new(&bad, "test");
            let _ = PredictRequest::decode_from(&mut cur);
        }
    }

    #[test]
    fn hostile_lengths_reject_before_allocating() {
        // rows*cols chosen to overflow or vastly exceed the buffer
        let mut buf = Vec::new();
        put_u8(&mut buf, 0);
        put_u32(&mut buf, u32::MAX);
        put_u32(&mut buf, u32::MAX);
        let mut cur = Cursor::new(&buf, "test");
        let e = PredictRequest::decode_from(&mut cur).unwrap_err();
        assert!(!e.is_transient(), "hostile header is corruption, not retryable");

        let mut buf = Vec::new();
        put_u8(&mut buf, 1); // has_var
        put_u32(&mut buf, 1_000_000);
        put_u32(&mut buf, 1_000_000);
        let mut cur = Cursor::new(&buf, "test");
        assert!(PredictResponse::decode_from(&mut cur).is_err());

        // bad has_var flag and bad kind tag are corruption too
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        let mut cur = Cursor::new(&buf, "test");
        assert!(PredictResponse::decode_from(&mut cur).is_err());
    }
}
