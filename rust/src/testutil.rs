//! Test support: tolerance assertions and a seeded property-testing harness
//! (proptest-lite — the offline crate set has no proptest).
//!
//! The harness runs a property over many seeded random cases; on failure it
//! reports the failing case number and seed so the case can be replayed
//! deterministically with `Cases::only(seed)`.

use crate::linalg::Mat;
use crate::util::prng::Rng;

/// Assert two scalars are close (absolute + relative tolerance).
#[track_caller]
pub fn assert_close(got: f64, want: f64, tol: f64) {
    let denom = 1.0_f64.max(want.abs());
    assert!(
        (got - want).abs() <= tol * denom,
        "assert_close failed: got {got}, want {want}, tol {tol} (denom {denom})"
    );
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_vec_close(got: &[f64], want: &[f64], tol: f64) {
    assert_eq!(got.len(), want.len(), "length mismatch: {} vs {}", got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let denom = 1.0_f64.max(w.abs());
        assert!(
            (g - w).abs() <= tol * denom,
            "assert_vec_close failed at index {i}: got {g}, want {w}, tol {tol}"
        );
    }
}

/// Assert two matrices are elementwise close.
#[track_caller]
pub fn assert_mat_close(got: &Mat, want: &Mat, tol: f64) {
    assert_eq!(got.shape(), want.shape(), "shape mismatch");
    let scale = 1.0_f64.max(want.fro_norm() / (want.rows().max(1) as f64));
    let diff = got.max_abs_diff(want);
    assert!(
        diff <= tol * scale,
        "assert_mat_close failed: max |diff| = {diff:.3e} > {tol:.1e} * {scale:.3e}"
    );
}

/// Property-test case generator/driver.
pub struct Cases {
    n_cases: usize,
    base_seed: u64,
    only: Option<u64>,
}

impl Cases {
    /// Run `n_cases` cases derived from `base_seed`.
    pub fn new(n_cases: usize, base_seed: u64) -> Self {
        Self { n_cases, base_seed, only: None }
    }

    /// Replay a single failing seed.
    pub fn only(seed: u64) -> Self {
        Self { n_cases: 1, base_seed: 0, only: Some(seed) }
    }

    /// Run the property.  The closure gets a per-case RNG; panic = failure.
    #[track_caller]
    pub fn run(&self, mut prop: impl FnMut(&mut Rng)) {
        if let Some(seed) = self.only {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
        for case in 0..self.n_cases {
            let seed = self
                .base_seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(case as u64);
            let mut rng = Rng::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng)
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property failed at case {case}/{} (replay with \
                     Cases::only({seed})): {msg}",
                    self.n_cases
                );
            }
        }
    }
}

/// Unique on-disk scratch directory for persistence tests, removed on
/// drop. Uniqueness is three-layer so parallel test binaries (and the CI
/// seed-matrix lanes, which each set their own `TMPDIR`) can never share a
/// state directory: the OS temp root, the process id, and a process-local
/// counter.
pub struct ScratchDir {
    path: std::path::PathBuf,
}

impl ScratchDir {
    /// Create `$TMPDIR/mikrr-<label>-<pid>-<n>`.
    pub fn new(label: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("mikrr-{label}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&path).expect("create scratch dir");
        Self { path }
    }

    /// The directory path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Random SPD matrix of size n with given diagonal dominance.
pub fn random_spd(rng: &mut Rng, n: usize, jitter: f64) -> Mat {
    let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
    let mut s = crate::linalg::gemm::syrk(&a).unwrap();
    s.scale(1.0 / n.max(1) as f64);
    s.add_diag(jitter).unwrap();
    s
}

/// Random general matrix.
pub fn random_mat(rng: &mut Rng, r: usize, c: usize, scale: f64) -> Mat {
    Mat::from_fn(r, c, |_, _| scale * rng.gaussian())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_assertions_pass() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert_vec_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9);
    }

    #[test]
    #[should_panic(expected = "assert_close failed")]
    fn close_assertions_fail() {
        assert_close(1.0, 2.0, 1e-9);
    }

    #[test]
    fn cases_run_deterministic() {
        let mut sum1 = 0u64;
        Cases::new(10, 5).run(|rng| {
            sum1 = sum1.wrapping_add(rng.next_u64());
        });
        let mut sum2 = 0u64;
        Cases::new(10, 5).run(|rng| {
            sum2 = sum2.wrapping_add(rng.next_u64());
        });
        assert_eq!(sum1, sum2);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn cases_report_failure() {
        Cases::new(5, 1).run(|rng| {
            // fail on some case
            assert!(rng.uniform() < -1.0, "always fails");
        });
    }

    #[test]
    fn random_spd_is_spd() {
        let mut rng = Rng::new(3);
        let s = random_spd(&mut rng, 12, 1.0);
        assert!(crate::linalg::solve::cholesky(&s).is_ok());
    }
}
