//! # mikrr — Multiple Incremental/Decremental Kernel Ridge Regression
//!
//! A production-oriented reproduction of
//! *"Efficient Multiple Incremental Computation for Kernel Ridge Regression
//! with Bayesian Uncertainty Modeling"* (Chen, Abdullah, Park — FGCS 2017),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the streaming coordinator: sensor sources, sink-node
//!   pooling, batching with backpressure, outlier-driven decremental learning,
//!   and the incremental KRR/KBR engines themselves (intrinsic and empirical
//!   space), all in pure Rust on the request path. The [`serve`] layer scales
//!   this to serving traffic: K sharded engine replicas, epoch-published read
//!   snapshots, and micro-batched prediction execution — made crash-safe by
//!   the [`persist`] layer's engine snapshots and per-shard write-ahead logs.
//! * **L2** — the paper's update equations as JAX graphs
//!   (`python/compile/model.py`), AOT-lowered to HLO text at build time.
//! * **L1** — Pallas kernels for the compute hot-spots
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU client
//! (`xla` crate) and transparently falls back to the native [`linalg`]
//! implementations when shapes do not match the canonical artifact shapes.
//!
//! See `examples/` for full workloads and `rust/benches/paper_tables.rs` for
//! the reproduction of every table and figure in the paper's evaluation.

pub mod benchlib;
pub mod cli;
pub mod config;
pub mod error;
pub mod par;
pub mod util;

pub mod linalg;

pub mod baselines;
pub mod kbr;
pub mod kernels;
pub mod krr;

pub mod coordinator;
pub mod data;
pub mod health;
pub mod metrics;
pub mod net;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod streaming;
pub mod telemetry;

pub mod testutil;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
