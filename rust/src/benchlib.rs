//! Criterion-lite benchmark harness (the offline crate set has no criterion).
//!
//! Provides warmup + adaptive iteration-count measurement with summary
//! statistics, a `black_box` sink, simple CLI filtering (`cargo bench --
//! --filter <substr>`), and a renderer for the paper-style tables used by
//! `rust/benches/paper_tables.rs`.

use crate::util::stats;
use std::time::Instant;

/// Prevent the optimizer from eliding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark's summary statistics, in seconds.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Benchmark id.
    pub name: String,
    /// Measured per-iteration times.
    pub samples: Vec<f64>,
}

impl Summary {
    /// Mean seconds/iteration.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    /// Minimum.
    pub fn min(&self) -> f64 {
        stats::min(&self.samples)
    }

    /// Percentile `p` in [0, 100] (nearest-rank over sorted samples).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    /// Median round latency (p50), seconds.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Tail round latency (p99), seconds.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// One-line human rendering.
    pub fn render(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (median {:>12}, sd {:>10}, n={})",
            self.name,
            crate::util::fmt_secs(self.mean()),
            crate::util::fmt_secs(self.median()),
            crate::util::fmt_secs(self.stddev()),
            self.samples.len(),
        )
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Warmup wall-clock budget.
    pub warmup_secs: f64,
    /// Measurement wall-clock budget.
    pub measure_secs: f64,
    /// Minimum sample count.
    pub min_samples: usize,
    /// Maximum sample count.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_secs: 0.3, measure_secs: 1.5, min_samples: 5, max_samples: 200 }
    }
}

/// The bench runner: owns filtering and collected results.
pub struct Bencher {
    cfg: BenchConfig,
    filter: Option<String>,
    /// All summaries collected so far.
    pub results: Vec<Summary>,
    quiet: bool,
    /// Target dimension D of the multi-output workloads in this run
    /// (1 = scalar targets), recorded in the report's `env` block.
    target_dim: usize,
    /// Fraction of streamed rows that repeat a stored input (the
    /// duplicate-folding workload knob), recorded in the `env` block.
    fold_ratio: f64,
}

impl Bencher {
    /// Build from CLI args (supports `--filter <substr>`, `--quick`).
    pub fn from_args<I: IntoIterator<Item = String>>(args: I) -> Self {
        let args: Vec<String> = args.into_iter().collect();
        let mut filter = None;
        let mut cfg = BenchConfig::default();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--filter" => {
                    if i + 1 < args.len() {
                        filter = Some(args[i + 1].clone());
                        i += 1;
                    }
                }
                "--quick" => {
                    cfg.warmup_secs = 0.05;
                    cfg.measure_secs = 0.2;
                    cfg.min_samples = 3;
                }
                // ignore cargo-bench builtins like --bench
                _ => {}
            }
            i += 1;
        }
        Self { cfg, filter, results: Vec::new(), quiet: false, target_dim: 1, fold_ratio: 0.0 }
    }

    /// New with explicit config.
    pub fn new(cfg: BenchConfig) -> Self {
        Self { cfg, filter: None, results: Vec::new(), quiet: false, target_dim: 1, fold_ratio: 0.0 }
    }

    /// Suppress per-bench output.
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Record the target dimension D of this run's multi-output workloads
    /// (written to the report's `env` block).
    pub fn set_target_dim(&mut self, d: usize) {
        self.target_dim = d;
    }

    /// Record the duplicate-input fold ratio of this run's streaming
    /// workloads (written to the report's `env` block).
    pub fn set_fold_ratio(&mut self, r: f64) {
        self.fold_ratio = r;
    }

    /// Should this benchmark run under the current filter?
    pub fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().is_none_or(|f| name.contains(f))
    }

    /// Measure `f` (one call = one iteration).  Returns None if filtered out.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<&Summary> {
        if !self.enabled(name) {
            return None;
        }
        // warmup + per-iteration cost estimate
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.cfg.warmup_secs || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est = t0.elapsed().as_secs_f64() / warm_iters as f64;
        // choose sample count within the measurement budget
        let n = ((self.cfg.measure_secs / est.max(1e-9)) as usize)
            .clamp(self.cfg.min_samples, self.cfg.max_samples);
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let summary = Summary { name: name.to_string(), samples };
        if !self.quiet {
            println!("{}", summary.render());
        }
        self.results.push(summary);
        self.results.last()
    }

    /// Time a single invocation (for expensive end-to-end cells where the
    /// paper itself reports one round).  Records a 1-sample summary.
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> Option<&Summary> {
        if !self.enabled(name) {
            return None;
        }
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        let summary = Summary { name: name.to_string(), samples: vec![dt] };
        if !self.quiet {
            println!("{}", summary.render());
        }
        self.results.push(summary);
        self.results.last()
    }

    /// Look up a collected summary by exact name.
    pub fn summary(&self, name: &str) -> Option<&Summary> {
        self.results.iter().find(|s| s.name == name)
    }

    /// Write every collected result — plus free-form top-level numeric
    /// `extra` fields — as machine-readable JSON, so the perf trajectory
    /// (round latency p50/p99, allocations per round, speedups) is tracked
    /// across PRs in versioned `BENCH_*.json` files. Hand-rolled writer:
    /// the offline crate set has no serde.
    ///
    /// Every report carries an `env` block (worker-pool lane count, the raw
    /// `MIKRR_THREADS` override if any, the number of pinned worker lanes,
    /// the dispatch-tuning source, the multi-output target dimension D and
    /// the duplicate-input fold ratio of the run's workloads, and the build
    /// profile) so entries from different runs are comparable across the
    /// perf trajectory.
    pub fn write_json(&self, path: &str, extra: &[(&str, f64)]) -> std::io::Result<()> {
        let mut out = String::from("{\n  \"benchmarks\": [");
        for (i, s) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"samples\": {}, \"mean_s\": {}, \
                 \"p50_s\": {}, \"p99_s\": {}, \"min_s\": {}, \"stddev_s\": {}}}",
                json_escape(&s.name),
                s.samples.len(),
                json_f64(s.mean()),
                json_f64(s.p50()),
                json_f64(s.p99()),
                json_f64(s.min()),
                json_f64(s.stddev()),
            ));
        }
        out.push_str("\n  ],\n  \"env\": {");
        out.push_str(&format!("\n    \"threads\": {},", crate::par::num_threads()));
        match std::env::var("MIKRR_THREADS") {
            Ok(v) => out.push_str(&format!(
                "\n    \"mikrr_threads\": \"{}\",",
                json_escape(&v)
            )),
            Err(_) => out.push_str("\n    \"mikrr_threads\": null,"),
        }
        out.push_str(&format!(
            "\n    \"max_threads_cap\": {},",
            crate::par::MAX_THREADS
        ));
        out.push_str(&format!(
            "\n    \"pinned_lanes\": {},",
            crate::par::pinned_lanes()
        ));
        out.push_str(&format!(
            "\n    \"tuning\": \"{}\",",
            json_escape(crate::linalg::gemm::dispatch::tune::source())
        ));
        out.push_str(&format!("\n    \"target_dim\": {},", self.target_dim));
        out.push_str(&format!(
            "\n    \"fold_ratio\": {},",
            json_f64(self.fold_ratio)
        ));
        out.push_str(&format!(
            "\n    \"profile\": \"{}\"",
            if cfg!(debug_assertions) { "debug" } else { "release" }
        ));
        out.push_str("\n  },\n  \"extra\": {");
        for (i, (k, v)) in extra.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{}\": {}", json_escape(k), json_f64(*v)));
        }
        out.push_str("\n  }\n}\n");
        std::fs::write(path, out)
    }
}

/// Minimal JSON string escaping (our bench ids only need quotes/backslash,
/// but be safe about control characters too).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON-safe float rendering (JSON has no NaN/Inf literals).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// Paper-style table renderer: a header row of column labels and named rows
/// of f64 cells, printed with fixed precision (the paper reports log10
/// seconds to 6 decimals).
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
    precision: usize,
}

impl Table {
    /// New table with a title and column labels.
    pub fn new(title: impl Into<String>, columns: Vec<String>) -> Self {
        Self { title: title.into(), columns, rows: Vec::new(), precision: 6 }
    }

    /// Set cell precision.
    pub fn precision(mut self, p: usize) -> Self {
        self.precision = p;
        self
    }

    /// Add a named row.
    pub fn row(&mut self, name: impl Into<String>, cells: Vec<f64>) {
        self.rows.push((name.into(), cells));
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let p = self.precision;
        let w = (p + 6).max(10);
        let mut out = format!("\n=== {} ===\n", self.title);
        out.push_str(&format!("{:<12}", ""));
        for c in &self.columns {
            out.push_str(&format!("{c:>w$}"));
        }
        out.push('\n');
        for (name, cells) in &self.rows {
            out.push_str(&format!("{name:<12}"));
            for v in cells {
                out.push_str(&format!("{v:>w$.p$}"));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::new(BenchConfig {
            warmup_secs: 0.001,
            measure_secs: 0.01,
            min_samples: 3,
            max_samples: 10,
        })
        .quiet();
        let mut acc = 0u64;
        b.bench("tiny", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let s = &b.results[0];
        assert!(s.samples.len() >= 3);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher::from_args(vec!["--filter".into(), "yes".into()]).quiet();
        assert!(b.bench("no_match", || {}).is_none());
        assert!(b.bench("yes_match", || {}).is_some());
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn quick_mode() {
        let b = Bencher::from_args(vec!["--quick".into()]);
        assert!(b.cfg.measure_secs < 0.5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Table IV", vec!["83226".into(), "83228".into()]);
        t.row("Multiple", vec![-0.5375, -0.6652]);
        t.row("Single", vec![0.0477, 0.0437]);
        let s = t.render();
        assert!(s.contains("Table IV"));
        assert!(s.contains("Multiple"));
        assert!(s.contains("-0.537500"));
    }

    #[test]
    fn bench_once_records() {
        let mut b = Bencher::new(BenchConfig::default()).quiet();
        b.bench_once("one", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(b.results[0].samples.len(), 1);
        assert!(b.results[0].mean() >= 0.001);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let s = Summary {
            name: "p".into(),
            samples: (1..=100).map(|i| i as f64).collect(),
        };
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!(s.p99() >= 98.0);
        let empty = Summary { name: "e".into(), samples: vec![] };
        assert_eq!(empty.p50(), 0.0);
    }

    #[test]
    fn write_json_emits_machine_readable_report() {
        let mut b = Bencher::new(BenchConfig::default()).quiet();
        b.set_target_dim(8);
        b.set_fold_ratio(0.5);
        b.results.push(Summary {
            name: "alpha/one \"quoted\"".into(),
            samples: vec![0.001, 0.002, 0.003],
        });
        let path = std::env::temp_dir().join("mikrr_bench_test.json");
        let path = path.to_str().unwrap();
        b.write_json(path, &[("allocs_per_round", 0.0), ("speedup", 2.5)])
            .unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("\"benchmarks\""));
        assert!(text.contains("alpha/one \\\"quoted\\\""));
        assert!(text.contains("\"p50_s\""));
        assert!(text.contains("\"p99_s\""));
        assert!(text.contains("\"allocs_per_round\": 0e0"));
        assert!(text.contains("\"speedup\": 2.5e0"));
        // env block: thread count, override, build profile — the fields
        // that make BENCH_*.json entries comparable across the trajectory
        assert!(text.contains("\"env\""));
        assert!(text.contains("\"threads\": "));
        assert!(text.contains("\"mikrr_threads\""));
        assert!(text.contains("\"max_threads_cap\""));
        assert!(text.contains("\"pinned_lanes\": "));
        assert!(text.contains("\"tuning\": \""));
        assert!(text.contains("\"target_dim\": 8"));
        assert!(text.contains("\"fold_ratio\": 5e-1"));
        let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
        assert!(text.contains(&format!("\"profile\": \"{profile}\"")));
        std::fs::remove_file(path).ok();
    }
}
