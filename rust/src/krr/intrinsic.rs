//! Intrinsic-space KRR (paper Section II).
//!
//! Maintains the inverse regularized scatter matrix `S^-1` (J x J), the
//! mapped feature store `Φ` (N x J, row per sample — needed to build the
//! decremental columns), and the running sums that recover the `(u, b)`
//! head from the bordered system of eq. (5) in O(J^2):
//!
//! ```text
//! psum = Φ^T e   (J,)      py = Φ^T y   (J,)      sy = e.y      n = N
//! b = (sy − psum.S^-1 py) / (n − psum.S^-1 psum)
//! u = S^-1 (py − psum b)
//! ```
//!
//! A `+|C|/−|R|` round is ONE rank-(|C|+|R|) Woodbury update (eq. 15) plus
//! one head refresh — the "multiple incremental" strategy whose cost the
//! paper's evaluation compares against single-instance updates and full
//! retraining.

use crate::error::{Error, Result};
use crate::kernels::{Kernel, MonomialTable};
use crate::linalg::gemm::{gemv, gemv_into};
use crate::linalg::matrix::dot;
use crate::linalg::solve::spd_inverse;
use crate::linalg::woodbury::{incdec_into, IncDecWork};
use crate::linalg::Mat;
use crate::{ensure_shape, krr::KrrModel};

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state update performs zero heap
/// allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct IntrinsicWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Mapped insertion block Φ_C (C, J).
    phi_c: Mat,
    /// Update columns Φ_H (J, C + R).
    phi_h: Mat,
    /// Column signs (+1 insert / −1 remove).
    signs: Vec<f64>,
    /// Woodbury scratch.
    incdec: IncDecWork,
    /// Head refresh: S^-1 psum.
    sp: Vec<f64>,
    /// Head refresh: S^-1 py.
    spy: Vec<f64>,
}

/// Caller-owned workspace for [`IntrinsicKrr::predict_into`]: the mapped
/// query block, kept warm so steady-state serving performs zero heap
/// allocations (measured in `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct IntrinsicPredictWork {
    /// Mapped query features Φ* (B, J).
    phi_star: Mat,
}

/// Intrinsic-space incremental KRR engine.
#[derive(Clone)]
pub struct IntrinsicKrr {
    kernel: Kernel,
    table: MonomialTable,
    rho: f64,
    /// Maintained (Φ Φ^T + ρI)^-1, (J, J).
    s_inv: Mat,
    /// Mapped training features, one row per sample (N, J).
    phi: Mat,
    /// Training targets.
    y: Vec<f64>,
    /// Φ^T e (J,).
    psum: Vec<f64>,
    /// Φ^T y (J,).
    py: Vec<f64>,
    /// e.y
    sy: f64,
    /// Weight vector u (J,).
    u: Vec<f64>,
    /// Bias b.
    b: f64,
    work: IntrinsicWork,
}

impl IntrinsicKrr {
    /// Fit from scratch: O(N J^2 + J^3).  This is also what the
    /// nonincremental baseline pays every round.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.len(),
            "IntrinsicKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.len()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        let table = kernel.feature_table(x.cols()).ok_or_else(|| {
            Error::Config(format!(
                "kernel {kernel:?} has infinite intrinsic dimension; \
                 use empirical space (paper §III)"
            ))
        })?;
        let phi = table.map(x); // (N, J)
        let j = table.j();
        // S = Φ^T Φ + ρI — transpose-side SYRK straight off the row-major
        // store (half the flops of the general product, no materialized
        // Φ^T: the packed engine reads Φ transpose-aware above the
        // dispatch crossover, and the blocked-parallel Cholesky + TRSM
        // behind spd_inverse take it from there)
        let mut s = Mat::default();
        crate::linalg::gemm::syrk_t_into(1.0, &phi, 0.0, &mut s)?;
        s.add_diag(rho)?;
        let s_inv = spd_inverse(&s)?;
        let psum = phi.col_sums();
        let py = {
            let mut v = vec![0.0; j];
            for (r, &yr) in y.iter().enumerate() {
                crate::linalg::matrix::axpy_slice(yr, phi.row(r), &mut v);
            }
            v
        };
        let sy = y.iter().sum();
        let mut model = Self {
            kernel: kernel.clone(),
            table,
            rho,
            s_inv,
            phi,
            y: y.to_vec(),
            psum,
            py,
            sy,
            u: vec![0.0; j],
            b: 0.0,
            work: IntrinsicWork::default(),
        };
        model.refresh_head()?;
        Ok(model)
    }

    /// Recover (u, b) from the maintained state — O(J^2), allocation-free
    /// with a warm workspace.
    fn refresh_head(&mut self) -> Result<()> {
        let n = self.y.len() as f64;
        gemv_into(&self.s_inv, &self.psum, &mut self.work.sp)?; // S^-1 psum
        let denom = n - dot(&self.psum, &self.work.sp);
        if denom.abs() < 1e-12 {
            return Err(Error::numerical("refresh_head", format!("denom {denom:.3e}")));
        }
        self.b = (self.sy - dot(&self.work.sp, &self.py)) / denom;
        gemv_into(&self.s_inv, &self.py, &mut self.work.spy)?;
        let b = self.b;
        self.u.clear();
        self.u
            .extend(self.work.spy.iter().zip(&self.work.sp).map(|(a, s)| a - s * b));
        Ok(())
    }

    /// The ridge parameter.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// Intrinsic dimension J.
    pub fn j(&self) -> usize {
        self.table.j()
    }

    /// Weight vector (J,).
    pub fn weights(&self) -> &[f64] {
        &self.u
    }

    /// Bias.
    pub fn bias(&self) -> f64 {
        self.b
    }

    /// Kernel in use.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Borrow the maintained inverse (tests / diagnostics).
    pub fn s_inv(&self) -> &Mat {
        &self.s_inv
    }

    /// Training targets.
    pub fn targets(&self) -> &[f64] {
        &self.y
    }

    /// Single-sample incremental update (paper eq. 11) — used by the
    /// single-instance baseline. Internally a rank-1 `inc_dec`.
    pub fn inc_one(&mut self, x_new: &[f64], y_new: f64) -> Result<()> {
        let x = Mat::from_vec(1, x_new.len(), x_new.to_vec())?;
        self.inc_dec(&x, &[y_new], &[])
    }

    /// Single-sample decremental update (paper eq. 12).
    pub fn dec_one(&mut self, remove_idx: usize) -> Result<()> {
        self.inc_dec(&Mat::zeros(0, self.table.m), &[], &[remove_idx])
    }

    /// Batched prediction written into a caller-provided buffer, drawing
    /// the mapped query block from `work` — allocation-free once warm (the
    /// serving layer's micro-batch loop runs on this). One round is ONE
    /// feature map over the batch plus one GEMV, instead of B per-request
    /// map + dot passes.
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut IntrinsicPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.table.m,
            "IntrinsicKrr::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        gemv_into(&work.phi_star, &self.u, out)?;
        for v in out.iter_mut() {
            *v += self.b;
        }
        Ok(())
    }
}

impl KrrModel for IntrinsicKrr {
    fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.predict_into(x, &mut out, &mut IntrinsicPredictWork::default())?;
        Ok(out)
    }

    /// One batched `+|C|/−|R|` round. Steady state performs zero heap
    /// allocations: Φ_C/Φ_H/signs live in the per-model workspace, the
    /// Woodbury update is in place, and the stores shrink and grow inside
    /// their reserved capacity.
    fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.len(),
            "IntrinsicKrr::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.len()
        );
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.len() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.len()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        if self.y.len() + c <= r {
            return Err(Error::InvalidUpdate(
                "update would leave an empty training set".into(),
            ));
        }
        let j = self.table.j();
        // build Φ_H: (J, C + R) — new mapped rows then removed stored rows
        self.table.map_into_mat(x_new, &mut self.work.phi_c); // (C, J)
        self.work.phi_h.resize_scratch(j, c + r);
        for row in 0..c {
            for jj in 0..j {
                self.work.phi_h[(jj, row)] = self.work.phi_c[(row, jj)];
            }
        }
        for col in 0..r {
            let ri = self.work.rem[col];
            for jj in 0..j {
                self.work.phi_h[(jj, c + col)] = self.phi[(ri, jj)];
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, c));
        self.work.signs.extend(std::iter::repeat_n(-1.0, r));
        // ONE batched Woodbury update (paper eq. 15), in place
        incdec_into(
            &mut self.s_inv,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        // maintain the sums
        for row in 0..c {
            crate::linalg::matrix::axpy_slice(1.0, self.work.phi_c.row(row), &mut self.psum);
            crate::linalg::matrix::axpy_slice(
                y_new[row],
                self.work.phi_c.row(row),
                &mut self.py,
            );
        }
        for &ri in &self.work.rem {
            crate::linalg::matrix::axpy_slice(-1.0, self.phi.row(ri), &mut self.psum);
            crate::linalg::matrix::axpy_slice(-self.y[ri], self.phi.row(ri), &mut self.py);
        }
        self.sy += y_new.iter().sum::<f64>()
            - self.work.rem.iter().map(|&i| self.y[i]).sum::<f64>();
        // edit the stores: compact out removed rows, then append new ones
        self.phi.drop_rows_sorted(&self.work.rem)?;
        for (i, &ri) in self.work.rem.iter().enumerate() {
            // remove from y by index, adjusting for prior removals
            self.y.remove(ri - i);
        }
        for row in 0..c {
            self.phi.push_row(self.work.phi_c.row(row))?;
            self.y.push(y_new[row]);
        }
        self.refresh_head()
    }

    fn n_samples(&self) -> usize {
        self.y.len()
    }

    fn predict_training(&self) -> Result<Vec<f64>> {
        // stored mapped features make this O(N J) with no re-mapping
        let mut out = gemv(&self.phi, &self.u)?;
        for v in &mut out {
            *v += self.b;
        }
        Ok(out)
    }

    fn mode(&self) -> &'static str {
        "intrinsic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn fit_matches_normal_equations() {
        let (x, y) = data(60, 4, 1);
        let kernel = Kernel::poly(2, 1.0);
        let model = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        // residual check: predictions should fit training targets well
        let pred = model.predict(&x).unwrap();
        let r = crate::krr::rmse(&pred, &y);
        assert!(r < 0.2, "training rmse {r}");
    }

    #[test]
    fn inc_dec_equals_retrain() {
        let (x, y) = data(50, 5, 2);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(4, 5, 3);
        let rem = [3usize, 17];
        inc.inc_dec(&xc, &yc, &rem).unwrap();

        // retrain from scratch on the edited dataset
        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&rem).unwrap();
        y2.remove(17);
        y2.remove(3);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = IntrinsicKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();

        assert_vec_close(inc.weights(), fresh.weights(), 1e-7);
        assert_close(inc.bias(), fresh.bias(), 1e-7);
        assert_eq!(inc.n_samples(), 52);
    }

    #[test]
    fn sequence_of_rounds_stays_exact() {
        let (x, y) = data(40, 3, 4);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut x_cur = x.clone();
        let mut y_cur = y.clone();
        let mut rng = Rng::new(5);
        for round in 0..6 {
            let (xc, yc) = data(4, 3, 100 + round);
            let rem = rng.sample_indices(y_cur.len(), 2);
            inc.inc_dec(&xc, &yc, &rem).unwrap();
            let mut sorted = rem.clone();
            sorted.sort_unstable();
            x_cur.remove_rows(&sorted).unwrap();
            for (i, &ri) in sorted.iter().enumerate() {
                y_cur.remove(ri - i);
            }
            x_cur = x_cur.vcat(&xc).unwrap();
            y_cur.extend_from_slice(&yc);
        }
        let fresh = IntrinsicKrr::fit(&x_cur, &y_cur, &kernel, 0.5).unwrap();
        assert_vec_close(inc.weights(), fresh.weights(), 1e-6);
        assert_close(inc.bias(), fresh.bias(), 1e-6);
    }

    #[test]
    fn single_ops_match_batch() {
        let (x, y) = data(30, 3, 6);
        let kernel = Kernel::poly(2, 1.0);
        let mut single = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut multi = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(3, 3, 7);
        // batch path
        multi.inc_dec(&xc, &yc, &[]).unwrap();
        // one-at-a-time path
        for i in 0..3 {
            single.inc_one(xc.row(i), yc[i]).unwrap();
        }
        assert_vec_close(single.weights(), multi.weights(), 1e-8);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(10, 3, 8);
        let kernel = Kernel::poly(2, 1.0);
        let mut m = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[99]).is_err());
        assert!(IntrinsicKrr::fit(&x, &y, &Kernel::rbf_radius(50.0), 0.5).is_err());
        assert!(IntrinsicKrr::fit(&x, &y, &kernel, 0.0).is_err());
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &(0..10).collect::<Vec<_>>()).is_err());
    }

    #[test]
    fn noop_round_is_identity() {
        let (x, y) = data(12, 3, 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut m = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let u0 = m.weights().to_vec();
        m.inc_dec(&Mat::zeros(0, 3), &[], &[]).unwrap();
        assert_vec_close(m.weights(), &u0, 1e-15);
    }
}
