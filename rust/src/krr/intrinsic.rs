//! Intrinsic-space KRR (paper Section II).
//!
//! Maintains the inverse regularized scatter matrix `S^-1` (J x J), the
//! mapped feature store `Φ` (N x J, row per sample — needed to build the
//! decremental columns), and the running sums that recover the `(U, b)`
//! head from the bordered system of eq. (5) in O(J^2 D):
//!
//! ```text
//! psum = Φ^T C e  (J,)     PY = Φ^T C Ȳ  (J, D)     sy = e.C ȳ_d  (D,)
//! w = Σ c_i
//! b_d = (sy_d − psum.S^-1 PY_d) / (w − psum.S^-1 psum)
//! U_d = S^-1 (PY_d − psum b_d)
//! ```
//!
//! `C = diag(c_i)` carries duplicate-fold multiplicities (all 1 until a
//! fold; then `S = Φ^T C Φ + ρI`, identical to the unfolded stream's
//! scatter).  All `D` target columns share the ONE maintained inverse:
//! fits pay one factorization plus `D` right-hand sides, and a
//! `+|C|/−|R|` round is ONE rank-(|C|+|R|) Woodbury update (eq. 15) plus
//! one head refresh — the "multiple incremental" strategy whose cost the
//! paper's evaluation compares against single-instance updates and full
//! retraining.  A weighted row is removed by scaling its update column
//! with `√c_i` (the rank-1 term it contributed to the scatter), and a
//! fold is a rank-1 *increment* with the unscaled stored row.

use crate::error::{Error, Result};
use crate::kernels::{Kernel, MonomialTable};
use crate::linalg::gemm::{gemm_tn_acc, gemv, gemv_into, ger, matmul_into};
use crate::linalg::matrix::dot;
use crate::linalg::solve::spd_inverse;
use crate::linalg::woodbury::{incdec_into, IncDecWork};
use crate::linalg::Mat;
use crate::{ensure_shape, krr::KrrModel};

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state update performs zero heap
/// allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct IntrinsicWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Mapped insertion block Φ_C (C, J).
    phi_c: Mat,
    /// Update columns Φ_H (J, C + R).
    phi_h: Mat,
    /// Column signs (+1 insert / −1 remove).
    signs: Vec<f64>,
    /// Woodbury scratch.
    incdec: IncDecWork,
    /// Head refresh: S^-1 psum.
    sp: Vec<f64>,
    /// Head refresh: S^-1 PY, (J, D).
    spy: Mat,
    /// D=1 shim scratch: `y_new` as a (B, 1) column.
    y_shim: Mat,
}

/// Caller-owned workspace for [`IntrinsicKrr::predict_into`]: the mapped
/// query block, kept warm so steady-state serving performs zero heap
/// allocations (measured in `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct IntrinsicPredictWork {
    /// Mapped query features Φ* (B, J).
    phi_star: Mat,
}

/// Intrinsic-space incremental KRR engine.
#[derive(Clone)]
pub struct IntrinsicKrr {
    kernel: Kernel,
    table: MonomialTable,
    rho: f64,
    /// Maintained (Φ^T C Φ + ρI)^-1, (J, J).
    s_inv: Mat,
    /// Mapped training features, one row per sample (N, J).
    phi: Mat,
    /// Training targets, multiplicity-averaged, (N, D).
    y: Mat,
    /// Per-row duplicate multiplicities c_i (all 1.0 until a fold).
    mult: Vec<f64>,
    /// Total observation weight Σ c_i (= unfolded sample count).
    w_total: f64,
    /// Φ^T C e (J,).
    psum: Vec<f64>,
    /// Φ^T C Ȳ (J, D).
    py: Mat,
    /// e.C ȳ per output (D,).
    sy: Vec<f64>,
    /// Weight matrix U (J, D) — one column per output.
    u: Mat,
    /// Per-output bias (D,).
    b: Vec<f64>,
    work: IntrinsicWork,
}

impl IntrinsicKrr {
    /// Fit from scratch: O(N J^2 + J^3), `D = 1`.  This is also what the
    /// nonincremental baseline pays every round.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::fit_multi(x, &ym, kernel, rho)
    }

    /// Fit from scratch with a `(N, D)` target matrix: one factorization,
    /// `D` right-hand sides.
    pub fn fit_multi(x: &Mat, y: &Mat, kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.rows(),
            "IntrinsicKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.rows()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        if y.cols() == 0 {
            return Err(Error::Config("target matrix needs >= 1 column".into()));
        }
        let table = kernel.feature_table(x.cols()).ok_or_else(|| {
            Error::Config(format!(
                "kernel {kernel:?} has infinite intrinsic dimension; \
                 use empirical space (paper §III)"
            ))
        })?;
        let phi = table.map(x); // (N, J)
        let j = table.j();
        let d = y.cols();
        // S = Φ^T Φ + ρI — transpose-side SYRK straight off the row-major
        // store (half the flops of the general product, no materialized
        // Φ^T: the packed engine reads Φ transpose-aware above the
        // dispatch crossover, and the blocked-parallel Cholesky + TRSM
        // behind spd_inverse take it from there)
        let mut s = Mat::default();
        crate::linalg::gemm::syrk_t_into(1.0, &phi, 0.0, &mut s)?;
        s.add_diag(rho)?;
        let s_inv = spd_inverse(&s)?;
        let psum = phi.col_sums();
        // PY = Φ^T Y: all D right-hand sides in one TN product
        let mut py = Mat::zeros(j, d);
        gemm_tn_acc(1.0, &phi, y, &mut py)?;
        let sy = y.col_sums();
        let mut model = Self {
            kernel: kernel.clone(),
            table,
            rho,
            s_inv,
            phi,
            y: y.clone(),
            mult: vec![1.0; y.rows()],
            w_total: y.rows() as f64,
            psum,
            py,
            sy,
            u: Mat::zeros(j, d),
            b: vec![0.0; d],
            work: IntrinsicWork::default(),
        };
        model.refresh_head()?;
        Ok(model)
    }

    /// Recover (U, b) from the maintained state — O(J^2 D),
    /// allocation-free with a warm workspace.
    fn refresh_head(&mut self) -> Result<()> {
        let d = self.y.cols();
        gemv_into(&self.s_inv, &self.psum, &mut self.work.sp)?; // S^-1 psum
        let denom = self.w_total - dot(&self.psum, &self.work.sp);
        if denom.abs() < 1e-12 {
            return Err(Error::numerical("refresh_head", format!("denom {denom:.3e}")));
        }
        // b_d = (sy_d − sp.PY_d) / denom, accumulated column-wise
        self.b.clear();
        self.b.resize(d, 0.0);
        for (jj, &spj) in self.work.sp.iter().enumerate() {
            for (bd, &pyv) in self.b.iter_mut().zip(self.py.row(jj)) {
                *bd += spj * pyv;
            }
        }
        for (bd, &syd) in self.b.iter_mut().zip(&self.sy) {
            *bd = (syd - *bd) / denom;
        }
        matmul_into(&self.s_inv, &self.py, &mut self.work.spy)?; // (J, D)
        let j = self.work.sp.len();
        self.u.resize_scratch(j, d);
        for jj in 0..j {
            let spj = self.work.sp[jj];
            for dc in 0..d {
                self.u[(jj, dc)] = self.work.spy[(jj, dc)] - spj * self.b[dc];
            }
        }
        Ok(())
    }

    /// The ridge parameter.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// Intrinsic dimension J.
    pub fn j(&self) -> usize {
        self.table.j()
    }

    /// Weight vector (J,) (`D = 1` view; see [`Self::weights_multi`]).
    pub fn weights(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "weights is the D=1 view");
        self.u.as_slice()
    }

    /// Weight matrix, (J, D).
    pub fn weights_multi(&self) -> &Mat {
        &self.u
    }

    /// Bias (`D = 1` view).
    pub fn bias(&self) -> f64 {
        self.b[0]
    }

    /// Per-output biases (D,).
    pub fn bias_multi(&self) -> &[f64] {
        &self.b
    }

    /// Kernel in use.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Borrow the maintained inverse (tests / diagnostics).
    pub fn s_inv(&self) -> &Mat {
        &self.s_inv
    }

    /// Training targets, multiplicity-averaged, (N, D).
    pub fn targets_multi(&self) -> &Mat {
        &self.y
    }

    /// Training targets (`D = 1` view).
    pub fn targets(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "targets is the D=1 view");
        self.y.as_slice()
    }

    /// Per-row duplicate multiplicities (all 1.0 unless folds happened).
    pub fn multiplicities(&self) -> &[f64] {
        &self.mult
    }

    /// Numerical health probe: ∞-norm of row `i` of the residual operator
    /// `S·S⁻¹ − I` where `S = ΦᵀCΦ + ρI` is rebuilt exactly from the
    /// retained feature store. Since both `S` and the maintained `S⁻¹`
    /// are symmetric, the probed *row* of the residual equals the probed
    /// *column* `S⁻¹·s_i − e_i`, so one probe costs one scatter row
    /// (O(N·J)) plus one GEMV (O(J²)) — no full scatter rebuild.
    /// Allocation-free once `g`/`r` are warm (length J).
    pub fn probe_residual_into(
        &self,
        i: usize,
        g: &mut Vec<f64>,
        r: &mut Vec<f64>,
    ) -> Result<f64> {
        let j = self.phi.cols();
        ensure_shape!(i < j, "IntrinsicKrr::probe_residual", "probe index {i} >= J {j}");
        g.clear();
        g.resize(j, 0.0);
        for n in 0..self.phi.rows() {
            let row = self.phi.row(n);
            let w = self.mult[n] * row[i];
            if w != 0.0 {
                for (gj, &pj) in g.iter_mut().zip(row.iter()) {
                    *gj += w * pj;
                }
            }
        }
        g[i] += self.rho;
        gemv_into(&self.s_inv, g, r)?;
        r[i] -= 1.0;
        Ok(r.iter().fold(0.0f64, |m, &v| m.max(v.abs())))
    }

    /// Chaos-only hook: multiplicatively corrupt one entry of the
    /// maintained inverse so health probes have real drift to detect.
    #[cfg(feature = "chaos")]
    pub fn chaos_scale_inverse(&mut self, factor: f64) {
        if self.s_inv.rows() > 0 {
            self.s_inv[(0, 0)] *= factor;
        }
    }

    /// Single-sample incremental update (paper eq. 11) — used by the
    /// single-instance baseline. Internally a rank-1 `inc_dec`.
    pub fn inc_one(&mut self, x_new: &[f64], y_new: f64) -> Result<()> {
        let x = Mat::from_vec(1, x_new.len(), x_new.to_vec())?;
        self.inc_dec(&x, &[y_new], &[])
    }

    /// Single-sample decremental update (paper eq. 12).
    pub fn dec_one(&mut self, remove_idx: usize) -> Result<()> {
        self.inc_dec(&Mat::zeros(0, self.table.m), &[], &[remove_idx])
    }

    /// Batched prediction written into a caller-provided buffer, drawing
    /// the mapped query block from `work` — allocation-free once warm (the
    /// serving layer's micro-batch loop runs on this). One round is ONE
    /// feature map over the batch plus one GEMV, instead of B per-request
    /// map + dot passes. `D = 1` only.
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut IntrinsicPredictWork,
    ) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "predict_into is the D=1 surface; use predict_multi_into".into(),
            ));
        }
        ensure_shape!(
            x.cols() == self.table.m,
            "IntrinsicKrr::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        gemv_into(&work.phi_star, self.u.as_slice(), out)?;
        for v in out.iter_mut() {
            *v += self.b[0];
        }
        Ok(())
    }

    /// Multi-output batched prediction: `out` becomes `(B, D)`. The weight
    /// application is ONE packed `(B, J)·(J, D)` GEMM over all outputs —
    /// allocation-free once `out`/`work` are warm.
    pub fn predict_multi_into(
        &self,
        x: &Mat,
        out: &mut Mat,
        work: &mut IntrinsicPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.table.m,
            "IntrinsicKrr::predict_multi",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        matmul_into(&work.phi_star, &self.u, out)?; // (B, D)
        let d = self.b.len();
        for row in out.as_mut_slice().chunks_exact_mut(d) {
            for (v, &bd) in row.iter_mut().zip(&self.b) {
                *v += bd;
            }
        }
        Ok(())
    }
}

impl KrrModel for IntrinsicKrr {
    fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.predict_into(x, &mut out, &mut IntrinsicPredictWork::default())?;
        Ok(out)
    }

    fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "inc_dec is the D=1 surface; use inc_dec_multi".into(),
            ));
        }
        let mut shim = std::mem::take(&mut self.work.y_shim);
        shim.resize_scratch(y_new.len(), 1);
        shim.as_mut_slice().copy_from_slice(y_new);
        let out = self.inc_dec_multi(x_new, &shim, remove_idx);
        self.work.y_shim = shim;
        out
    }

    /// One batched `+|C|/−|R|` round, all `D` coefficient columns riding
    /// the one Woodbury update. Steady state performs zero heap
    /// allocations: Φ_C/Φ_H/signs live in the per-model workspace, the
    /// Woodbury update is in place, and the stores shrink and grow inside
    /// their reserved capacity. A multiplicity-`c` row leaves through a
    /// `√c`-scaled update column (the rank-1 scatter term it contributed).
    fn inc_dec_multi(&mut self, x_new: &Mat, y_new: &Mat, remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.rows(),
            "IntrinsicKrr::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.rows()
        );
        if x_new.rows() > 0 {
            ensure_shape!(
                y_new.cols() == self.y.cols(),
                "IntrinsicKrr::inc_dec",
                "y_new has {} cols, engine carries D = {}",
                y_new.cols(),
                self.y.cols()
            );
        }
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.rows() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.rows()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        if self.y.rows() + c <= r {
            return Err(Error::InvalidUpdate(
                "update would leave an empty training set".into(),
            ));
        }
        let j = self.table.j();
        // build Φ_H: (J, C + R) — new mapped rows then removed stored rows
        // (each removal column scaled by √c_i so ONE ±1-signed rank-1 term
        // removes the row's whole multiplicity-weighted scatter share)
        self.table.map_into_mat(x_new, &mut self.work.phi_c); // (C, J)
        self.work.phi_h.resize_scratch(j, c + r);
        for row in 0..c {
            for jj in 0..j {
                self.work.phi_h[(jj, row)] = self.work.phi_c[(row, jj)];
            }
        }
        for col in 0..r {
            let ri = self.work.rem[col];
            let w = self.mult[ri].sqrt();
            for jj in 0..j {
                self.work.phi_h[(jj, c + col)] = w * self.phi[(ri, jj)];
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, c));
        self.work.signs.extend(std::iter::repeat_n(-1.0, r));
        // ONE batched Woodbury update (paper eq. 15), in place
        incdec_into(
            &mut self.s_inv,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        // maintain the sums (before the store edits below invalidate rows)
        for row in 0..c {
            crate::linalg::matrix::axpy_slice(1.0, self.work.phi_c.row(row), &mut self.psum);
            ger(&mut self.py, 1.0, self.work.phi_c.row(row), y_new.row(row))?;
            for (s, &yv) in self.sy.iter_mut().zip(y_new.row(row)) {
                *s += yv;
            }
        }
        for &ri in &self.work.rem {
            let ci = self.mult[ri];
            crate::linalg::matrix::axpy_slice(-ci, self.phi.row(ri), &mut self.psum);
            ger(&mut self.py, -ci, self.phi.row(ri), self.y.row(ri))?;
            for (s, &yv) in self.sy.iter_mut().zip(self.y.row(ri)) {
                *s -= ci * yv;
            }
        }
        self.w_total += c as f64
            - self.work.rem.iter().map(|&i| self.mult[i]).sum::<f64>();
        // edit the stores: compact out removed rows, then append new ones
        self.phi.drop_rows_sorted(&self.work.rem)?;
        self.y.drop_rows_sorted(&self.work.rem)?;
        for (i, &ri) in self.work.rem.iter().enumerate() {
            self.mult.remove(ri - i);
        }
        for row in 0..c {
            self.phi.push_row(self.work.phi_c.row(row))?;
            self.y.push_row(y_new.row(row))?;
            self.mult.push(1.0);
        }
        self.refresh_head()
    }

    fn n_samples(&self) -> usize {
        self.y.rows()
    }

    fn n_outputs(&self) -> usize {
        self.y.cols()
    }

    fn predict_training(&self) -> Result<Vec<f64>> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "predict_training is the D=1 surface; use predict_training_multi".into(),
            ));
        }
        // stored mapped features make this O(N J) with no re-mapping
        let mut out = gemv(&self.phi, self.u.as_slice())?;
        for v in &mut out {
            *v += self.b[0];
        }
        Ok(out)
    }

    fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        let mut out = Mat::default();
        self.predict_multi_into(x, &mut out, &mut IntrinsicPredictWork::default())?;
        Ok(out)
    }

    fn predict_training_multi(&self) -> Result<Mat> {
        // stored mapped features: one (N, J)·(J, D) GEMM, no re-mapping
        let mut out = Mat::default();
        matmul_into(&self.phi, &self.u, &mut out)?;
        let d = self.b.len();
        for row in out.as_mut_slice().chunks_exact_mut(d) {
            for (v, &bd) in row.iter_mut().zip(&self.b) {
                *v += bd;
            }
        }
        Ok(out)
    }

    /// Fold duplicates: the target row's scatter share grows by exactly
    /// one more `φ_i φ_iᵀ`, so the whole round is ONE batched rank-|F|
    /// Woodbury *increment* with the unscaled stored rows, plus the sum /
    /// multiplicity / running-average maintenance — identical state to the
    /// unfolded insert, at O(J^2 |F|) instead of store growth.
    fn apply_folds(&mut self, folds: &[(usize, usize)], _x_new: &Mat, y_new: &Mat) -> Result<()> {
        if folds.is_empty() {
            return Ok(());
        }
        let n = self.y.rows();
        let d = self.y.cols();
        let j = self.table.j();
        self.work.phi_h.resize_scratch(j, folds.len());
        for (k, &(i, br)) in folds.iter().enumerate() {
            ensure_shape!(
                i < n && br < y_new.rows(),
                "IntrinsicKrr::apply_folds",
                "fold ({i}, {br}) out of range (n = {n}, batch = {})",
                y_new.rows()
            );
            ensure_shape!(
                y_new.cols() == d,
                "IntrinsicKrr::apply_folds",
                "y_new has {} cols, engine carries D = {d}",
                y_new.cols()
            );
            for jj in 0..j {
                self.work.phi_h[(jj, k)] = self.phi[(i, jj)];
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, folds.len()));
        incdec_into(
            &mut self.s_inv,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        for &(i, br) in folds {
            let c = self.mult[i];
            crate::linalg::matrix::axpy_slice(1.0, self.phi.row(i), &mut self.psum);
            ger(&mut self.py, 1.0, self.phi.row(i), y_new.row(br))?;
            for (s, &yv) in self.sy.iter_mut().zip(y_new.row(br)) {
                *s += yv;
            }
            for dc in 0..d {
                self.y[(i, dc)] = (c * self.y[(i, dc)] + y_new[(br, dc)]) / (c + 1.0);
            }
            self.mult[i] = c + 1.0;
            self.w_total += 1.0;
        }
        self.refresh_head()
    }

    fn mode(&self) -> &'static str {
        "intrinsic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn fit_matches_normal_equations() {
        let (x, y) = data(60, 4, 1);
        let kernel = Kernel::poly(2, 1.0);
        let model = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        // residual check: predictions should fit training targets well
        let pred = model.predict(&x).unwrap();
        let r = crate::krr::rmse(&pred, &y);
        assert!(r < 0.2, "training rmse {r}");
    }

    #[test]
    fn inc_dec_equals_retrain() {
        let (x, y) = data(50, 5, 2);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(4, 5, 3);
        let rem = [3usize, 17];
        inc.inc_dec(&xc, &yc, &rem).unwrap();

        // retrain from scratch on the edited dataset
        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&rem).unwrap();
        y2.remove(17);
        y2.remove(3);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = IntrinsicKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();

        assert_vec_close(inc.weights(), fresh.weights(), 1e-7);
        assert_close(inc.bias(), fresh.bias(), 1e-7);
        assert_eq!(inc.n_samples(), 52);
    }

    #[test]
    fn sequence_of_rounds_stays_exact() {
        let (x, y) = data(40, 3, 4);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut x_cur = x.clone();
        let mut y_cur = y.clone();
        let mut rng = Rng::new(5);
        for round in 0..6 {
            let (xc, yc) = data(4, 3, 100 + round);
            let rem = rng.sample_indices(y_cur.len(), 2);
            inc.inc_dec(&xc, &yc, &rem).unwrap();
            let mut sorted = rem.clone();
            sorted.sort_unstable();
            x_cur.remove_rows(&sorted).unwrap();
            for (i, &ri) in sorted.iter().enumerate() {
                y_cur.remove(ri - i);
            }
            x_cur = x_cur.vcat(&xc).unwrap();
            y_cur.extend_from_slice(&yc);
        }
        let fresh = IntrinsicKrr::fit(&x_cur, &y_cur, &kernel, 0.5).unwrap();
        assert_vec_close(inc.weights(), fresh.weights(), 1e-6);
        assert_close(inc.bias(), fresh.bias(), 1e-6);
    }

    #[test]
    fn single_ops_match_batch() {
        let (x, y) = data(30, 3, 6);
        let kernel = Kernel::poly(2, 1.0);
        let mut single = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut multi = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(3, 3, 7);
        // batch path
        multi.inc_dec(&xc, &yc, &[]).unwrap();
        // one-at-a-time path
        for i in 0..3 {
            single.inc_one(xc.row(i), yc[i]).unwrap();
        }
        assert_vec_close(single.weights(), multi.weights(), 1e-8);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(10, 3, 8);
        let kernel = Kernel::poly(2, 1.0);
        let mut m = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[99]).is_err());
        assert!(IntrinsicKrr::fit(&x, &y, &Kernel::rbf_radius(50.0), 0.5).is_err());
        assert!(IntrinsicKrr::fit(&x, &y, &kernel, 0.0).is_err());
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &(0..10).collect::<Vec<_>>()).is_err());
    }

    #[test]
    fn noop_round_is_identity() {
        let (x, y) = data(12, 3, 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut m = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let u0 = m.weights().to_vec();
        m.inc_dec(&Mat::zeros(0, 3), &[], &[]).unwrap();
        assert_vec_close(m.weights(), &u0, 1e-15);
    }

    #[test]
    fn multi_output_columns_match_independent_engines() {
        let (x, y0) = data(30, 4, 10);
        let (_, y1) = data(30, 4, 11);
        let kernel = Kernel::poly(2, 1.0);
        let ym = Mat::from_fn(30, 2, |r, c| if c == 0 { y0[r] } else { y1[r] });
        let multi = IntrinsicKrr::fit_multi(&x, &ym, &kernel, 0.5).unwrap();
        let e0 = IntrinsicKrr::fit(&x, &y0, &kernel, 0.5).unwrap();
        let e1 = IntrinsicKrr::fit(&x, &y1, &kernel, 0.5).unwrap();
        let (xt, _) = data(7, 4, 12);
        let pm = multi.predict_multi(&xt).unwrap();
        let p0 = e0.predict(&xt).unwrap();
        let p1 = e1.predict(&xt).unwrap();
        for r in 0..7 {
            assert_close(pm[(r, 0)], p0[r], 1e-10);
            assert_close(pm[(r, 1)], p1[r], 1e-10);
        }
    }

    #[test]
    fn fold_equals_unfolded_duplicate_insert() {
        let (x, y) = data(24, 3, 13);
        let kernel = Kernel::poly(2, 1.0);
        let mut folded = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let xdup = Mat::from_fn(1, 3, |_, c| x[(5, c)]);
        let ydup = Mat::from_vec(1, 1, vec![0.33]).unwrap();
        folded.apply_folds(&[(5, 0)], &xdup, &ydup).unwrap();
        assert_eq!(folded.n_samples(), 24, "folding must not grow N");

        let x_ref = x.vcat(&xdup).unwrap();
        let mut y_ref = y.clone();
        y_ref.push(0.33);
        let unfolded = IntrinsicKrr::fit(&x_ref, &y_ref, &kernel, 0.5).unwrap();
        assert_vec_close(folded.weights(), unfolded.weights(), 1e-10);
        assert_close(folded.bias(), unfolded.bias(), 1e-10);
    }
}
