//! The batch-size / operating-space cost model (paper §II.B, §III.B, §VI).
//!
//! The paper derives three rules:
//!
//! 1. **Space selection**: intrinsic-space maintenance costs O(J^2) per
//!    rank-1 (J = C(M+d, d)); empirical costs O(N^2). Pick intrinsic when
//!    J < N (i.e. N ≫ M regime), empirical when N < J or the kernel has
//!    infinite intrinsic dimension (RBF).
//! 2. **Intrinsic batch bound**: a batched update with |H| = |C| + |R| is
//!    profitable vs a fresh O(J^3) inverse only while |H| < J.
//! 3. **Empirical shrink bound**: removing |R| samples by eq. (29) is
//!    profitable only while |R| < residual N − |R|; otherwise recompute the
//!    kept block directly.
//!
//! [`Advisor`] encodes these with explicit flop models so the coordinator's
//! routing decisions are auditable (and benchable — see the ablation bench).

use crate::config::Space;
use crate::kernels::Kernel;

/// Cost-model-driven routing decisions.
#[derive(Clone, Debug)]
pub struct Advisor {
    /// Relative cost of a kernel evaluation vs a multiply-add (used to
    /// weight Gram-construction terms; ~1 for poly, ~4 for RBF exp).
    pub kernel_eval_cost: f64,
}

impl Default for Advisor {
    fn default() -> Self {
        Self { kernel_eval_cost: 1.0 }
    }
}

/// A space recommendation with its predicted per-round flop counts.
#[derive(Clone, Debug)]
pub struct SpaceChoice {
    /// The recommended space.
    pub space: Space,
    /// Predicted flops for one +|C|/−|R| round in intrinsic space
    /// (None when inapplicable, e.g. RBF).
    pub intrinsic_flops: Option<f64>,
    /// Predicted flops for one round in empirical space.
    pub empirical_flops: f64,
}

impl Advisor {
    /// Flops for one batched intrinsic round (eq. 15 + head refresh):
    /// feature-map of |C| rows + rank-H update O(J^2 H) + head O(J^2).
    pub fn intrinsic_round_flops(&self, j: usize, c: usize, r: usize) -> f64 {
        let j = j as f64;
        let h = (c + r) as f64;
        let map = (c as f64) * j; // monomial products
        2.0 * j * j * h + h * h * h + 3.0 * j * j + map
    }

    /// Flops for one batched empirical round (eq. 29 shrink + eq. 28 grow +
    /// head refresh), including Gram-construction against M features.
    pub fn empirical_round_flops(&self, n: usize, m: usize, c: usize, r: usize) -> f64 {
        let n = n as f64;
        let m = m as f64;
        let c_ = c as f64;
        let r_ = r as f64;
        let gram = self.kernel_eval_cost * (n * c_ + c_ * c_) * m;
        let shrink = 2.0 * n * n * r_;
        let grow = 2.0 * n * n * c_ + c_ * c_ * c_;
        let head = 3.0 * n * n;
        gram + shrink + grow + head
    }

    /// Pick an operating space for a dataset/kernel/batch profile.
    pub fn choose_space(
        &self,
        kernel: &Kernel,
        n: usize,
        m: usize,
        c: usize,
        r: usize,
    ) -> SpaceChoice {
        let empirical = self.empirical_round_flops(n, m, c, r);
        match kernel.intrinsic_dim(m) {
            None => SpaceChoice {
                space: Space::Empirical,
                intrinsic_flops: None,
                empirical_flops: empirical,
            },
            Some(j) => {
                let intrinsic = self.intrinsic_round_flops(j, c, r);
                let space = if intrinsic <= empirical {
                    Space::Intrinsic
                } else {
                    Space::Empirical
                };
                SpaceChoice {
                    space,
                    intrinsic_flops: Some(intrinsic),
                    empirical_flops: empirical,
                }
            }
        }
    }

    /// §II.B: largest profitable batch size |H| for intrinsic space
    /// (strictly below J; beyond that a fresh inverse wins).
    pub fn max_intrinsic_batch(&self, j: usize) -> usize {
        j.saturating_sub(1).max(1)
    }

    /// §III.B: is the eq. (29) shrink profitable for removing |r| of n?
    /// (|R| must be smaller than the residual set.)
    pub fn shrink_is_profitable(&self, n: usize, r: usize) -> bool {
        r < n.saturating_sub(r)
    }

    /// Recommended flush threshold for the stream batcher: collect up to
    /// this many pending ops before issuing one multiple update.  Chosen as
    /// the batch size where the per-sample cost of the batched update stops
    /// improving materially (diminishing returns past ~sqrt(J), capped by
    /// the §II.B bound).  For tiny J the §II.B cap can fall below the
    /// batching floor of 2, so the floor yields to the cap — `clamp` panics
    /// on unordered bounds.
    pub fn recommended_flush(&self, j: usize) -> usize {
        let cap = self.max_intrinsic_batch(j);
        let floor = 2.min(cap);
        ((j as f64).sqrt() as usize).clamp(floor, cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_forces_empirical() {
        let adv = Advisor::default();
        let c = adv.choose_space(&Kernel::rbf_radius(50.0), 1000, 21, 4, 2);
        assert_eq!(c.space, Space::Empirical);
        assert!(c.intrinsic_flops.is_none());
    }

    #[test]
    fn ecg_regime_prefers_intrinsic() {
        // N=83226, M=21, poly2 (J=253): intrinsic must win by a mile
        let adv = Advisor::default();
        let c = adv.choose_space(&Kernel::poly(2, 1.0), 83_226, 21, 4, 2);
        assert_eq!(c.space, Space::Intrinsic);
        assert!(c.intrinsic_flops.unwrap() < c.empirical_flops / 100.0);
    }

    #[test]
    fn drt_regime_prefers_empirical() {
        // N=640, M=1e6, poly2: J = C(M+2,2) is astronomically large
        let adv = Advisor::default();
        let c = adv.choose_space(&Kernel::poly(2, 1.0), 640, 1_000_000, 4, 2);
        assert_eq!(c.space, Space::Empirical);
    }

    #[test]
    fn shrink_bound_matches_paper() {
        let adv = Advisor::default();
        assert!(adv.shrink_is_profitable(100, 2));
        assert!(!adv.shrink_is_profitable(10, 5)); // residual == |R|
        assert!(!adv.shrink_is_profitable(10, 8));
    }

    #[test]
    fn intrinsic_batch_bound() {
        let adv = Advisor::default();
        assert_eq!(adv.max_intrinsic_batch(253), 252);
        assert_eq!(adv.max_intrinsic_batch(1), 1);
        let f = adv.recommended_flush(253);
        assert!((2..=252).contains(&f));
    }

    #[test]
    fn recommended_flush_tiny_j_does_not_panic() {
        // regression: j <= 2 gives max_intrinsic_batch(j) == 1 < 2, which
        // used to panic clamp() with "min > max"
        let adv = Advisor::default();
        for j in [1usize, 2, 3] {
            let f = adv.recommended_flush(j);
            assert!(
                f >= 1 && f <= adv.max_intrinsic_batch(j),
                "j={j}: flush {f} outside [1, {}]",
                adv.max_intrinsic_batch(j)
            );
        }
        assert_eq!(adv.recommended_flush(1), 1);
        assert_eq!(adv.recommended_flush(2), 1);
        assert_eq!(adv.recommended_flush(3), 2);
    }

    #[test]
    fn batched_beats_singles_in_model() {
        // the whole point: one rank-6 update cheaper than six rank-1s
        let adv = Advisor::default();
        let j = 253;
        let batched = adv.intrinsic_round_flops(j, 4, 2);
        let singles: f64 = (0..6).map(|_| adv.intrinsic_round_flops(j, 1, 0)).sum();
        assert!(batched < singles);
    }
}
