//! Empirical-space KRR (paper Section III).
//!
//! Maintains `Q^-1 = (K + ρI)^-1` (N x N) over the raw training samples.
//! A `+|C|/−|R|` round removes first (eq. 29, block Schur shrink), then
//! grows by the new block (eq. 28, bordered inverse) — the paper's eq. (30)
//! fused ordering.  The `(a, b)` head follows eq. (18)–(19) from `Q^-1`
//! directly in O(N^2).
//!
//! This is the only mode applicable to RBF kernels (infinite intrinsic
//! dimension) and the right choice when M ≫ N (e.g. Dorothea: N=800,
//! M=10^6).

use crate::error::{Error, Result};
use crate::kernels::gram::{gram_into, gram_symmetric_into, GramWork};
use crate::kernels::Kernel;
use crate::linalg::gemm::gemv_into;
use crate::linalg::matrix::dot;
use crate::linalg::solve::{spd_inverse, spd_inverse_into};
use crate::linalg::woodbury::{bordered_grow_into, bordered_shrink_into, BorderWork};
use crate::linalg::Mat;
use crate::{ensure_shape, krr::KrrModel};

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state update performs zero heap
/// allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct EmpiricalWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Bordered grow/shrink scratch.
    border: BorderWork,
    /// Gram-row-norm scratch (RBF path).
    gram: GramWork,
    /// Cross-kernel block η = K(X, X_C) (N, C).
    eta: Mat,
    /// New-block kernel K(X_C, X_C) + ρI (C, C).
    q_cc: Mat,
    /// Head refresh: v = Q^-1 e.
    v: Vec<f64>,
    /// Head refresh: Q^-1 y.
    qy: Vec<f64>,
    /// §III.B direct-recompute scratch: the kept-block Gram.
    q_kept: Mat,
    /// §III.B direct-recompute scratch: Cholesky factor for the inverse.
    l: Mat,
    /// §III.B direct-recompute scratch: one solve column.
    col: Vec<f64>,
}

/// Caller-owned workspace for [`EmpiricalKrr::predict_into`]: the cross
/// Gram block and its norm scratch, kept warm so steady-state serving
/// performs zero heap allocations (measured in `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct EmpiricalPredictWork {
    /// Query cross-kernel K(X*, X) (B, N).
    k_star: Mat,
    /// Gram row-norm scratch (RBF path).
    gram: GramWork,
}

/// Empirical-space incremental KRR engine.
#[derive(Clone)]
pub struct EmpiricalKrr {
    kernel: Kernel,
    rho: f64,
    /// Raw training samples (N, M) — needed for cross-kernels of new data.
    x: Mat,
    /// Training targets.
    y: Vec<f64>,
    /// Maintained (K + ρI)^-1, (N, N).
    q_inv: Mat,
    /// Dual weights a (N,).
    a: Vec<f64>,
    /// Bias b.
    b: f64,
    work: EmpiricalWork,
}

impl EmpiricalKrr {
    /// Fit from scratch: O(N^2 M + N^3).
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.len(),
            "EmpiricalKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.len()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        let mut q = kernel.gram_symmetric(x);
        q.add_diag(rho)?;
        let q_inv = spd_inverse(&q)?;
        let mut model = Self {
            kernel: kernel.clone(),
            rho,
            x: x.clone(),
            y: y.to_vec(),
            q_inv,
            a: vec![0.0; y.len()],
            b: 0.0,
            work: EmpiricalWork::default(),
        };
        model.refresh_head()?;
        Ok(model)
    }

    /// (a, b) from Q^-1 (paper eq. 18-19) — O(N^2), allocation-free with a
    /// warm workspace.
    fn refresh_head(&mut self) -> Result<()> {
        let n = self.y.len();
        ensure_shape!(
            self.q_inv.rows() == n,
            "refresh_head",
            "q_inv {:?} vs n {}",
            self.q_inv.shape(),
            n
        );
        // v = Q^-1 e ; b = (y.v) / (e.v) ; a = Q^-1 y - b v
        self.q_inv.row_sums_into(&mut self.work.v);
        let ev: f64 = self.work.v.iter().sum();
        if ev.abs() < 1e-14 {
            return Err(Error::numerical("refresh_head", format!("e Q^-1 e = {ev:.3e}")));
        }
        self.b = dot(&self.y, &self.work.v) / ev;
        gemv_into(&self.q_inv, &self.y, &mut self.work.qy)?;
        let b = self.b;
        self.a.clear();
        self.a.extend(
            self.work
                .qy
                .iter()
                .zip(&self.work.v)
                .map(|(q, vi)| q - b * vi),
        );
        Ok(())
    }

    /// Dual weights.
    pub fn dual_weights(&self) -> &[f64] {
        &self.a
    }

    /// Bias.
    pub fn bias(&self) -> f64 {
        self.b
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Maintained inverse (tests/diagnostics).
    pub fn q_inv(&self) -> &Mat {
        &self.q_inv
    }

    /// Training targets.
    pub fn targets(&self) -> &[f64] {
        &self.y
    }

    /// Single incremental update (paper eq. 20-23 path).
    pub fn inc_one(&mut self, x_new: &[f64], y_new: f64) -> Result<()> {
        let x = Mat::from_vec(1, x_new.len(), x_new.to_vec())?;
        self.inc_dec(&x, &[y_new], &[])
    }

    /// Single decremental update (paper eq. 26-27 path).
    pub fn dec_one(&mut self, remove_idx: usize) -> Result<()> {
        self.inc_dec(&Mat::zeros(0, self.x.cols()), &[], &[remove_idx])
    }

    /// Batched prediction written into a caller-provided buffer, drawing
    /// every intermediate from `work` — allocation-free once warm, which is
    /// what the serving layer's micro-batch loop runs on. One round is ONE
    /// cross-Gram build (a packed GEMM above the dispatch crossover) plus
    /// one GEMV, instead of B per-request kernel-row sweeps.
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut EmpiricalPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.x.cols(),
            "EmpiricalKrr::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.x.cols()
        );
        gram_into(&self.kernel, x, &self.x, &mut work.k_star, &mut work.gram); // (B, N)
        gemv_into(&work.k_star, &self.a, out)?;
        for v in out.iter_mut() {
            *v += self.b;
        }
        Ok(())
    }
}

impl KrrModel for EmpiricalKrr {
    fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.predict_into(x, &mut out, &mut EmpiricalPredictWork::default())?;
        Ok(out)
    }

    /// One batched `+|C|/−|R|` round: eq. (29) shrink then eq. (28) grow,
    /// both written into the maintained buffer. Steady state performs zero
    /// heap allocations — the Gram blocks, Schur scratch and head buffers
    /// all live in the per-model workspace, and `q_inv` shrinks and regrows
    /// inside its reserved capacity.
    fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.len(),
            "EmpiricalKrr::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.len()
        );
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.len() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.len()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        if self.y.len() + c <= r {
            return Err(Error::InvalidUpdate(
                "update would leave an empty training set".into(),
            ));
        }
        // 1) decremental shrink first (paper's eq. 30 ordering)
        if r > 0 {
            // §III.B guard: shrinking needs |R| < residual size; otherwise a
            // fresh inverse of the kept block is cheaper AND always valid.
            let residual = self.y.len() - r;
            if r >= residual {
                // direct recompute path (rare; the row gather may allocate)
                // — symmetric Gram through the SYRK route and an in-place
                // fresh inverse, reusing the model's scratch buffers; the
                // maintained buffer keeps its reserved capacity for the
                // regrowth that follows
                let keep: Vec<usize> = (0..self.y.len())
                    .filter(|i| !self.work.rem.contains(i))
                    .collect();
                let xk = self.x.select_rows(&keep);
                gram_symmetric_into(
                    &self.kernel,
                    &xk,
                    &mut self.work.q_kept,
                    &mut self.work.gram,
                );
                self.work.q_kept.add_diag(self.rho)?;
                spd_inverse_into(
                    &self.work.q_kept,
                    &mut self.q_inv,
                    &mut self.work.l,
                    &mut self.work.col,
                )?;
            } else {
                bordered_shrink_into(&mut self.q_inv, &self.work.rem, &mut self.work.border)?;
            }
            self.x.drop_rows_sorted(&self.work.rem)?;
            for (i, &ri) in self.work.rem.iter().enumerate() {
                self.y.remove(ri - i);
            }
        }
        // 2) incremental grow by the new block (eq. 28)
        if c > 0 {
            gram_into(&self.kernel, &self.x, x_new, &mut self.work.eta, &mut self.work.gram);
            gram_symmetric_into(&self.kernel, x_new, &mut self.work.q_cc, &mut self.work.gram);
            self.work.q_cc.add_diag(self.rho)?;
            bordered_grow_into(
                &mut self.q_inv,
                &self.work.eta,
                &self.work.q_cc,
                &mut self.work.border,
            )?;
            self.x.push_rows(x_new)?;
            self.y.extend_from_slice(y_new);
        }
        self.refresh_head()
    }

    fn n_samples(&self) -> usize {
        self.y.len()
    }

    fn predict_training(&self) -> Result<Vec<f64>> {
        self.predict(&self.x)
    }

    fn mode(&self) -> &'static str {
        "empirical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn inc_dec_equals_retrain_poly() {
        let (x, y) = data(40, 6, 1);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(4, 6, 2);
        inc.inc_dec(&xc, &yc, &[5, 11]).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[5, 11]).unwrap();
        y2.remove(11);
        y2.remove(5);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = EmpiricalKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-7);
        assert_close(inc.bias(), fresh.bias(), 1e-7);
    }

    #[test]
    fn inc_dec_equals_retrain_rbf() {
        let (x, y) = data(35, 5, 3);
        let kernel = Kernel::rbf_radius(2.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(3, 5, 4);
        inc.inc_dec(&xc, &yc, &[0, 34]).unwrap();
        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[0, 34]).unwrap();
        y2.remove(34);
        y2.remove(0);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = EmpiricalKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-6);
        assert_close(inc.bias(), fresh.bias(), 1e-6);
    }

    #[test]
    fn predictions_match_intrinsic_for_poly() {
        // the two operating modes are the same estimator (paper §III via
        // the Learning Subspace Property)
        use crate::krr::intrinsic::IntrinsicKrr;
        let (x, y) = data(30, 4, 5);
        let (xt, _) = data(8, 4, 6);
        let kernel = Kernel::poly(2, 1.0);
        let emp = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let intr = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let pe = emp.predict(&xt).unwrap();
        let pi = intr.predict(&xt).unwrap();
        assert_vec_close(&pe, &pi, 1e-6);
    }

    #[test]
    fn sequence_of_rounds_rbf() {
        let (x, y) = data(25, 4, 7);
        let kernel = Kernel::rbf_radius(2.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut x_cur = x;
        let mut y_cur = y;
        let mut rng = Rng::new(8);
        for round in 0..5 {
            let (xc, yc) = data(4, 4, 200 + round);
            let mut rem = rng.sample_indices(y_cur.len(), 2);
            rem.sort_unstable();
            inc.inc_dec(&xc, &yc, &rem).unwrap();
            x_cur.remove_rows(&rem).unwrap();
            for (i, &ri) in rem.iter().enumerate() {
                y_cur.remove(ri - i);
            }
            x_cur = x_cur.vcat(&xc).unwrap();
            y_cur.extend_from_slice(&yc);
        }
        let fresh = EmpiricalKrr::fit(&x_cur, &y_cur, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-6);
    }

    #[test]
    fn large_removal_uses_direct_path() {
        let (x, y) = data(12, 3, 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        // remove 8 of 12 -> residual 4 < |R| = 8 -> direct recompute branch
        let rem: Vec<usize> = (0..8).collect();
        inc.inc_dec(&Mat::zeros(0, 3), &[], &rem).unwrap();
        assert_eq!(inc.n_samples(), 4);
        let keep: Vec<usize> = (8..12).collect();
        let xk = x.select_rows(&keep);
        let yk: Vec<f64> = keep.iter().map(|&i| y[i]).collect();
        let fresh = EmpiricalKrr::fit(&xk, &yk, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-7);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(6, 3, 10);
        let kernel = Kernel::rbf_radius(1.0);
        let mut m = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[6]).is_err());
        assert!(m
            .inc_dec(&Mat::zeros(0, 3), &[], &(0..6).collect::<Vec<_>>())
            .is_err());
    }
}
