//! Empirical-space KRR (paper Section III).
//!
//! Maintains `Q^-1 = (K + ρ C^-1)^-1` (N x N) over the raw training
//! samples, where `C = diag(c_i)` carries per-row multiplicities from
//! duplicate-input folding (`C = I` until a fold happens — the paper's
//! `K + ρI` exactly).  A `+|C|/−|R|` round removes first (eq. 29, block
//! Schur shrink), then grows by the new block (eq. 28, bordered inverse)
//! — the paper's eq. (30) fused ordering.  The `(A, b)` head follows
//! eq. (18)–(19) from `Q^-1` directly in O(N^2 D): all `D` target columns
//! share the one maintained inverse, so the per-round factorization work
//! amortizes across outputs and multi-output predicts run as one packed
//! GEMM.
//!
//! Duplicate folding: a repeated input row bumps `c_i` instead of growing
//! N. Per the weighted normal equations the only state change is the
//! ridge diagonal `ρ/c_i` and the multiplicity-averaged target `ȳ_i`, so
//! a fold is ONE rank-1 Sherman–Morrison update of the maintained inverse
//! — numerically equivalent to having inserted the duplicate row.
//!
//! This is the only mode applicable to RBF kernels (infinite intrinsic
//! dimension) and the right choice when M ≫ N (e.g. Dorothea: N=800,
//! M=10^6).

use crate::error::{Error, Result};
use crate::kernels::gram::{gram_into, gram_row, gram_symmetric_into, GramWork};
use crate::kernels::Kernel;
use crate::linalg::gemm::{gemv_into, ger, matmul_into};
use crate::linalg::matrix::dot;
use crate::linalg::solve::{spd_inverse, spd_inverse_into};
use crate::linalg::woodbury::{bordered_grow_into, bordered_shrink_into, BorderWork};
use crate::linalg::Mat;
use crate::{ensure_shape, krr::KrrModel};

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state update performs zero heap
/// allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct EmpiricalWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Bordered grow/shrink scratch.
    border: BorderWork,
    /// Gram-row-norm scratch (RBF path).
    gram: GramWork,
    /// Cross-kernel block η = K(X, X_C) (N, C).
    eta: Mat,
    /// New-block kernel K(X_C, X_C) + ρI (C, C).
    q_cc: Mat,
    /// Head refresh: v = Q^-1 e.
    v: Vec<f64>,
    /// Head refresh: Q^-1 Y, (N, D).
    qy: Mat,
    /// §III.B direct-recompute scratch: the kept-block Gram.
    q_kept: Mat,
    /// §III.B direct-recompute scratch: Cholesky factor for the inverse.
    l: Mat,
    /// §III.B direct-recompute scratch: one solve column.
    col: Vec<f64>,
    /// Fold scratch: the touched Q^-1 column (rank-1 update input).
    fold_col: Vec<f64>,
    /// D=1 shim scratch: `y_new` as an (B, 1) column (taken/restored
    /// around the `_multi` call so the slice API stays allocation-free).
    y_shim: Mat,
}

/// Caller-owned workspace for [`EmpiricalKrr::predict_into`]: the cross
/// Gram block and its norm scratch, kept warm so steady-state serving
/// performs zero heap allocations (measured in `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct EmpiricalPredictWork {
    /// Query cross-kernel K(X*, X) (B, N).
    k_star: Mat,
    /// Gram row-norm scratch (RBF path).
    gram: GramWork,
}

/// Empirical-space incremental KRR engine.
#[derive(Clone)]
pub struct EmpiricalKrr {
    kernel: Kernel,
    rho: f64,
    /// Raw training samples (N, M) — needed for cross-kernels of new data.
    x: Mat,
    /// Training targets, multiplicity-averaged, (N, D).
    y: Mat,
    /// Per-row duplicate multiplicities c_i (all 1.0 until a fold).
    mult: Vec<f64>,
    /// Maintained (K + ρ C^-1)^-1, (N, N).
    q_inv: Mat,
    /// Dual weights, (N, D) — one column per output, one shared inverse.
    a: Mat,
    /// Per-output bias (D,).
    b: Vec<f64>,
    work: EmpiricalWork,
}

impl EmpiricalKrr {
    /// Fit from scratch: O(N^2 M + N^3), `D = 1`.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::fit_multi(x, &ym, kernel, rho)
    }

    /// Fit from scratch with a `(N, D)` target matrix: one factorization,
    /// `D` right-hand sides.
    pub fn fit_multi(x: &Mat, y: &Mat, kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.rows(),
            "EmpiricalKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.rows()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        if y.cols() == 0 {
            return Err(Error::Config("target matrix needs >= 1 column".into()));
        }
        let mut q = kernel.gram_symmetric(x);
        q.add_diag(rho)?;
        let q_inv = spd_inverse(&q)?;
        let mut model = Self {
            kernel: kernel.clone(),
            rho,
            x: x.clone(),
            y: y.clone(),
            mult: vec![1.0; y.rows()],
            q_inv,
            a: Mat::zeros(y.rows(), y.cols()),
            b: vec![0.0; y.cols()],
            work: EmpiricalWork::default(),
        };
        model.refresh_head()?;
        Ok(model)
    }

    /// (A, b) from Q^-1 (paper eq. 18-19, one column per output) —
    /// O(N^2 D), allocation-free with a warm workspace.
    fn refresh_head(&mut self) -> Result<()> {
        let n = self.y.rows();
        let d = self.y.cols();
        ensure_shape!(
            self.q_inv.rows() == n,
            "refresh_head",
            "q_inv {:?} vs n {}",
            self.q_inv.shape(),
            n
        );
        // v = Q^-1 e ; b_d = (y_d.v) / (e.v) ; a_d = (Q^-1 Y)_d - b_d v
        self.q_inv.row_sums_into(&mut self.work.v);
        let ev: f64 = self.work.v.iter().sum();
        if ev.abs() < 1e-14 {
            return Err(Error::numerical("refresh_head", format!("e Q^-1 e = {ev:.3e}")));
        }
        self.b.clear();
        self.b.resize(d, 0.0);
        for i in 0..n {
            let vi = self.work.v[i];
            for (bd, &yv) in self.b.iter_mut().zip(self.y.row(i)) {
                *bd += yv * vi;
            }
        }
        for bd in self.b.iter_mut() {
            *bd /= ev;
        }
        matmul_into(&self.q_inv, &self.y, &mut self.work.qy)?;
        self.a.resize_scratch(n, d);
        for i in 0..n {
            let vi = self.work.v[i];
            for dc in 0..d {
                self.a[(i, dc)] = self.work.qy[(i, dc)] - self.b[dc] * vi;
            }
        }
        Ok(())
    }

    /// Dual weights (`D = 1` view; see [`Self::dual_weights_multi`]).
    pub fn dual_weights(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "dual_weights is the D=1 view");
        self.a.as_slice()
    }

    /// Dual weight matrix, (N, D).
    pub fn dual_weights_multi(&self) -> &Mat {
        &self.a
    }

    /// Bias (`D = 1` view).
    pub fn bias(&self) -> f64 {
        self.b[0]
    }

    /// Per-output biases (D,).
    pub fn bias_multi(&self) -> &[f64] {
        &self.b
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Maintained inverse (tests/diagnostics).
    pub fn q_inv(&self) -> &Mat {
        &self.q_inv
    }

    /// Training targets, multiplicity-averaged, (N, D).
    pub fn targets_multi(&self) -> &Mat {
        &self.y
    }

    /// Training targets (`D = 1` view; the (N, 1) row-major buffer is the
    /// target column).
    pub fn targets(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "targets is the D=1 view");
        self.y.as_slice()
    }

    /// Per-row duplicate multiplicities (all 1.0 unless folds happened).
    pub fn multiplicities(&self) -> &[f64] {
        &self.mult
    }

    /// Numerical health probe: the ∞-norm residual of the maintained
    /// inverse on probe column `i`,
    /// `‖(K + ρC⁻¹) Q⁻¹ eᵢ − eᵢ‖∞` — exactly 0 in exact arithmetic, and a
    /// direct measure of how far floating-point drift has pushed `Q⁻¹`
    /// from the true inverse after thousands of incremental rounds.
    ///
    /// Cost is ONE kernel row (O(N M)) plus one symmetric mat-vec (O(N²)):
    /// by symmetry of `K + ρC⁻¹` and `Q⁻¹`, the probed *row* of the
    /// residual operator equals the probed column, so only row `i` of the
    /// regularized Gram is ever formed. `g`/`r` are caller scratch —
    /// allocation-free once warm (asserted in `rust/tests/alloc_count.rs`).
    pub fn probe_residual_into(
        &self,
        i: usize,
        g: &mut Vec<f64>,
        r: &mut Vec<f64>,
    ) -> Result<f64> {
        let n = self.y.rows();
        ensure_shape!(i < n, "EmpiricalKrr::probe_residual", "probe index {i} >= n {n}");
        g.clear();
        g.resize(n, 0.0);
        gram_row(&self.kernel, &self.x, self.x.row(i), g);
        g[i] += self.rho / self.mult[i];
        // r = Q⁻¹ (K + ρC⁻¹) eᵢ-row — the symmetric twin of the column residual
        gemv_into(&self.q_inv, g, r)?;
        r[i] -= 1.0;
        Ok(r.iter().fold(0.0f64, |m, &v| m.max(v.abs())))
    }

    /// Chaos hook: multiply one maintained-inverse entry by `factor`,
    /// simulating accumulated floating-point drift. Only compiled in
    /// fault-injection builds — see [`crate::health::fault`].
    #[cfg(feature = "chaos")]
    pub fn chaos_scale_inverse(&mut self, factor: f64) {
        if self.q_inv.rows() > 0 {
            self.q_inv[(0, 0)] *= factor;
        }
    }

    /// Single incremental update (paper eq. 20-23 path).
    pub fn inc_one(&mut self, x_new: &[f64], y_new: f64) -> Result<()> {
        let x = Mat::from_vec(1, x_new.len(), x_new.to_vec())?;
        self.inc_dec(&x, &[y_new], &[])
    }

    /// Single decremental update (paper eq. 26-27 path).
    pub fn dec_one(&mut self, remove_idx: usize) -> Result<()> {
        self.inc_dec(&Mat::zeros(0, self.x.cols()), &[], &[remove_idx])
    }

    /// Batched prediction written into a caller-provided buffer, drawing
    /// every intermediate from `work` — allocation-free once warm, which is
    /// what the serving layer's micro-batch loop runs on. One round is ONE
    /// cross-Gram build (a packed GEMM above the dispatch crossover) plus
    /// one GEMV, instead of B per-request kernel-row sweeps. `D = 1` only.
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut EmpiricalPredictWork,
    ) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "predict_into is the D=1 surface; use predict_multi_into".into(),
            ));
        }
        ensure_shape!(
            x.cols() == self.x.cols(),
            "EmpiricalKrr::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.x.cols()
        );
        gram_into(&self.kernel, x, &self.x, &mut work.k_star, &mut work.gram); // (B, N)
        gemv_into(&work.k_star, self.a.as_slice(), out)?;
        for v in out.iter_mut() {
            *v += self.b[0];
        }
        Ok(())
    }

    /// Multi-output batched prediction: `out` becomes `(B, D)`. The dual
    /// application is ONE packed `(B, N)·(N, D)` GEMM over all outputs —
    /// allocation-free once `out`/`work` are warm.
    pub fn predict_multi_into(
        &self,
        x: &Mat,
        out: &mut Mat,
        work: &mut EmpiricalPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.x.cols(),
            "EmpiricalKrr::predict_multi",
            "x has {} cols, expected {}",
            x.cols(),
            self.x.cols()
        );
        gram_into(&self.kernel, x, &self.x, &mut work.k_star, &mut work.gram); // (B, N)
        matmul_into(&work.k_star, &self.a, out)?; // (B, D)
        let d = self.b.len();
        for row in out.as_mut_slice().chunks_exact_mut(d) {
            for (v, &bd) in row.iter_mut().zip(&self.b) {
                *v += bd;
            }
        }
        Ok(())
    }
}

impl KrrModel for EmpiricalKrr {
    fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.predict_into(x, &mut out, &mut EmpiricalPredictWork::default())?;
        Ok(out)
    }

    fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "inc_dec is the D=1 surface; use inc_dec_multi".into(),
            ));
        }
        // route the slice through the (B, 1) scratch column; take/restore
        // keeps the shim allocation-free once warm
        let mut shim = std::mem::take(&mut self.work.y_shim);
        shim.resize_scratch(y_new.len(), 1);
        shim.as_mut_slice().copy_from_slice(y_new);
        let out = self.inc_dec_multi(x_new, &shim, remove_idx);
        self.work.y_shim = shim;
        out
    }

    /// One batched `+|C|/−|R|` round: eq. (29) shrink then eq. (28) grow,
    /// both written into the maintained buffer, all `D` target columns
    /// riding the one inverse. Steady state performs zero heap allocations
    /// — the Gram blocks, Schur scratch and head buffers all live in the
    /// per-model workspace, and `q_inv` shrinks and regrows inside its
    /// reserved capacity.
    fn inc_dec_multi(&mut self, x_new: &Mat, y_new: &Mat, remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.rows(),
            "EmpiricalKrr::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.rows()
        );
        if x_new.rows() > 0 {
            ensure_shape!(
                y_new.cols() == self.y.cols(),
                "EmpiricalKrr::inc_dec",
                "y_new has {} cols, engine carries D = {}",
                y_new.cols(),
                self.y.cols()
            );
        }
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.rows() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.rows()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        if self.y.rows() + c <= r {
            return Err(Error::InvalidUpdate(
                "update would leave an empty training set".into(),
            ));
        }
        // 1) decremental shrink first (paper's eq. 30 ordering)
        if r > 0 {
            // §III.B guard: shrinking needs |R| < residual size; otherwise a
            // fresh inverse of the kept block is cheaper AND always valid.
            let residual = self.y.rows() - r;
            if r >= residual {
                // direct recompute path (rare; the row gather may allocate)
                // — symmetric Gram through the SYRK route and an in-place
                // fresh inverse, reusing the model's scratch buffers; the
                // maintained buffer keeps its reserved capacity for the
                // regrowth that follows
                let keep: Vec<usize> = (0..self.y.rows())
                    .filter(|i| !self.work.rem.contains(i))
                    .collect();
                let xk = self.x.select_rows(&keep);
                gram_symmetric_into(
                    &self.kernel,
                    &xk,
                    &mut self.work.q_kept,
                    &mut self.work.gram,
                );
                // the ridge diagonal is ρ/c_i for multiplicity-weighted rows
                for (knew, &kold) in keep.iter().enumerate() {
                    self.work.q_kept[(knew, knew)] += self.rho / self.mult[kold];
                }
                spd_inverse_into(
                    &self.work.q_kept,
                    &mut self.q_inv,
                    &mut self.work.l,
                    &mut self.work.col,
                )?;
            } else {
                bordered_shrink_into(&mut self.q_inv, &self.work.rem, &mut self.work.border)?;
            }
            self.x.drop_rows_sorted(&self.work.rem)?;
            self.y.drop_rows_sorted(&self.work.rem)?;
            for (i, &ri) in self.work.rem.iter().enumerate() {
                self.mult.remove(ri - i);
            }
        }
        // 2) incremental grow by the new block (eq. 28); fresh rows enter
        // with multiplicity 1, so the new diagonal block gets the plain ρ
        if c > 0 {
            gram_into(&self.kernel, &self.x, x_new, &mut self.work.eta, &mut self.work.gram);
            gram_symmetric_into(&self.kernel, x_new, &mut self.work.q_cc, &mut self.work.gram);
            self.work.q_cc.add_diag(self.rho)?;
            bordered_grow_into(
                &mut self.q_inv,
                &self.work.eta,
                &self.work.q_cc,
                &mut self.work.border,
            )?;
            self.x.push_rows(x_new)?;
            self.y.push_rows(y_new)?;
            self.mult.resize(self.mult.len() + c, 1.0);
        }
        self.refresh_head()
    }

    fn n_samples(&self) -> usize {
        self.y.rows()
    }

    fn n_outputs(&self) -> usize {
        self.y.cols()
    }

    fn predict_training(&self) -> Result<Vec<f64>> {
        self.predict(&self.x)
    }

    fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        let mut out = Mat::default();
        self.predict_multi_into(x, &mut out, &mut EmpiricalPredictWork::default())?;
        Ok(out)
    }

    fn predict_training_multi(&self) -> Result<Mat> {
        self.predict_multi(&self.x)
    }

    /// Fold duplicates: bumping `c_i -> c_i + 1` changes ONE ridge
    /// diagonal entry by `δ = ρ/(c+1) − ρ/c`, so the maintained inverse
    /// takes a rank-1 Sherman–Morrison update
    /// `Q^-1 ← Q^-1 − (δ / (1 + δ q_ii)) q_i q_iᵀ` (q_i = i-th column of
    /// Q^-1), and the stored target becomes the running average
    /// `ȳ_i ← (c ȳ_i + y_new)/(c+1)`. Exactly the weighted normal
    /// equations of the unfolded stream; allocation-free once warm.
    fn apply_folds(&mut self, folds: &[(usize, usize)], _x_new: &Mat, y_new: &Mat) -> Result<()> {
        if folds.is_empty() {
            return Ok(());
        }
        let n = self.y.rows();
        let d = self.y.cols();
        for &(i, br) in folds {
            ensure_shape!(
                i < n && br < y_new.rows(),
                "EmpiricalKrr::apply_folds",
                "fold ({i}, {br}) out of range (n = {n}, batch = {})",
                y_new.rows()
            );
            ensure_shape!(
                y_new.cols() == d,
                "EmpiricalKrr::apply_folds",
                "y_new has {} cols, engine carries D = {d}",
                y_new.cols()
            );
            let c = self.mult[i];
            let delta = self.rho / (c + 1.0) - self.rho / c;
            self.work.fold_col.clear();
            self.work.fold_col.extend_from_slice(self.q_inv.row(i));
            let denom = 1.0 + delta * self.work.fold_col[i];
            if denom <= 1e-14 {
                return Err(Error::numerical(
                    "apply_folds",
                    format!("Sherman-Morrison denominator {denom:.3e}"),
                ));
            }
            let coef = delta / denom;
            ger(&mut self.q_inv, -coef, &self.work.fold_col, &self.work.fold_col)?;
            for dc in 0..d {
                self.y[(i, dc)] = (c * self.y[(i, dc)] + y_new[(br, dc)]) / (c + 1.0);
            }
            self.mult[i] = c + 1.0;
        }
        self.refresh_head()
    }

    fn mode(&self) -> &'static str {
        "empirical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn inc_dec_equals_retrain_poly() {
        let (x, y) = data(40, 6, 1);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(4, 6, 2);
        inc.inc_dec(&xc, &yc, &[5, 11]).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[5, 11]).unwrap();
        y2.remove(11);
        y2.remove(5);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = EmpiricalKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-7);
        assert_close(inc.bias(), fresh.bias(), 1e-7);
    }

    #[test]
    fn inc_dec_equals_retrain_rbf() {
        let (x, y) = data(35, 5, 3);
        let kernel = Kernel::rbf_radius(2.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = data(3, 5, 4);
        inc.inc_dec(&xc, &yc, &[0, 34]).unwrap();
        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[0, 34]).unwrap();
        y2.remove(34);
        y2.remove(0);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = EmpiricalKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-6);
        assert_close(inc.bias(), fresh.bias(), 1e-6);
    }

    #[test]
    fn predictions_match_intrinsic_for_poly() {
        // the two operating modes are the same estimator (paper §III via
        // the Learning Subspace Property)
        use crate::krr::intrinsic::IntrinsicKrr;
        let (x, y) = data(30, 4, 5);
        let (xt, _) = data(8, 4, 6);
        let kernel = Kernel::poly(2, 1.0);
        let emp = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let intr = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let pe = emp.predict(&xt).unwrap();
        let pi = intr.predict(&xt).unwrap();
        assert_vec_close(&pe, &pi, 1e-6);
    }

    #[test]
    fn sequence_of_rounds_rbf() {
        let (x, y) = data(25, 4, 7);
        let kernel = Kernel::rbf_radius(2.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut x_cur = x;
        let mut y_cur = y;
        let mut rng = Rng::new(8);
        for round in 0..5 {
            let (xc, yc) = data(4, 4, 200 + round);
            let mut rem = rng.sample_indices(y_cur.len(), 2);
            rem.sort_unstable();
            inc.inc_dec(&xc, &yc, &rem).unwrap();
            x_cur.remove_rows(&rem).unwrap();
            for (i, &ri) in rem.iter().enumerate() {
                y_cur.remove(ri - i);
            }
            x_cur = x_cur.vcat(&xc).unwrap();
            y_cur.extend_from_slice(&yc);
        }
        let fresh = EmpiricalKrr::fit(&x_cur, &y_cur, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-6);
    }

    #[test]
    fn large_removal_uses_direct_path() {
        let (x, y) = data(12, 3, 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut inc = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        // remove 8 of 12 -> residual 4 < |R| = 8 -> direct recompute branch
        let rem: Vec<usize> = (0..8).collect();
        inc.inc_dec(&Mat::zeros(0, 3), &[], &rem).unwrap();
        assert_eq!(inc.n_samples(), 4);
        let keep: Vec<usize> = (8..12).collect();
        let xk = x.select_rows(&keep);
        let yk: Vec<f64> = keep.iter().map(|&i| y[i]).collect();
        let fresh = EmpiricalKrr::fit(&xk, &yk, &kernel, 0.5).unwrap();
        assert_vec_close(inc.dual_weights(), fresh.dual_weights(), 1e-7);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(6, 3, 10);
        let kernel = Kernel::rbf_radius(1.0);
        let mut m = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[6]).is_err());
        assert!(m
            .inc_dec(&Mat::zeros(0, 3), &[], &(0..6).collect::<Vec<_>>())
            .is_err());
    }

    #[test]
    fn multi_output_columns_match_independent_engines() {
        let (x, y0) = data(30, 4, 11);
        let (_, y1) = data(30, 4, 12);
        let kernel = Kernel::rbf_radius(2.0);
        let ym = Mat::from_fn(30, 2, |r, c| if c == 0 { y0[r] } else { y1[r] });
        let multi = EmpiricalKrr::fit_multi(&x, &ym, &kernel, 0.5).unwrap();
        let e0 = EmpiricalKrr::fit(&x, &y0, &kernel, 0.5).unwrap();
        let e1 = EmpiricalKrr::fit(&x, &y1, &kernel, 0.5).unwrap();
        let (xt, _) = data(9, 4, 13);
        let pm = multi.predict_multi(&xt).unwrap();
        let p0 = e0.predict(&xt).unwrap();
        let p1 = e1.predict(&xt).unwrap();
        for r in 0..9 {
            assert_close(pm[(r, 0)], p0[r], 1e-10);
            assert_close(pm[(r, 1)], p1[r], 1e-10);
        }
    }

    #[test]
    fn fold_equals_unfolded_duplicate_insert() {
        let (x, y) = data(20, 4, 14);
        let kernel = Kernel::rbf_radius(2.0);
        let mut folded = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        // fold two repeats of stored row 3 (fresh targets) into the store
        let xdup = Mat::from_fn(2, 4, |_, c| x[(3, c)]);
        let ydup = Mat::from_vec(2, 1, vec![0.7, -0.4]).unwrap();
        folded.apply_folds(&[(3, 0), (3, 1)], &xdup, &ydup).unwrap();
        assert_eq!(folded.n_samples(), 20, "folding must not grow N");
        assert!((folded.multiplicities()[3] - 3.0).abs() < 1e-12);

        // unfolded reference: the duplicates inserted as literal rows
        let x_ref = x.vcat(&xdup).unwrap();
        let mut y_ref = y.clone();
        y_ref.extend_from_slice(&[0.7, -0.4]);
        let unfolded = EmpiricalKrr::fit(&x_ref, &y_ref, &kernel, 0.5).unwrap();
        let (xt, _) = data(8, 4, 15);
        let pf = folded.predict(&xt).unwrap();
        let pu = unfolded.predict(&xt).unwrap();
        assert_vec_close(&pf, &pu, 1e-10);
        assert_close(folded.bias(), unfolded.bias(), 1e-10);
    }
}
