//! Duplicate-input fold planning: decide which incoming rows are
//! (ε-near) repeats of rows the store already holds, so the engines can
//! fold them into a multiplicity-weighted existing row instead of growing
//! J — the incremental-GP idiom for hot-sensor traffic.
//!
//! The plan is computed once per round at the coordinator level so the
//! KRR engine and its KBR twin apply the *same* fold decision; the
//! engines then consume it through their `apply_folds` entry points.
//! Planning is a dense scan (O(B·N·m)) against the pre-update store, and
//! the plan's target indices are expressed in **post-update** coordinates
//! (after the round's removals and insertions) so `apply_folds` can index
//! the store directly.

use crate::linalg::Mat;

/// One round's fold decision, split into rows that enter the store fresh
/// and rows that fold into an existing (or just-inserted) row.
///
/// Both vectors are reusable scratch: `plan_folds_into` clears them and
/// refills without reallocating once warm.
#[derive(Clone, Debug, Default)]
pub struct FoldPlan {
    /// Batch-row indices (into the incoming batch) inserted as new rows,
    /// in batch order.
    pub fresh: Vec<usize>,
    /// `(store_index, batch_row)` pairs: `batch_row` folds into the row at
    /// `store_index`, where `store_index` is the row's position *after*
    /// this round's removals and fresh insertions have been applied.
    pub folds: Vec<(usize, usize)>,
}

impl FoldPlan {
    /// True when every incoming row enters fresh (folding is a no-op and
    /// the round can take the plain `inc_dec` path).
    pub fn is_trivial(&self) -> bool {
        self.folds.is_empty()
    }
}

/// Squared Euclidean distance between two equal-length rows.
#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (&ai, &bi) in a.iter().zip(b) {
        let d = ai - bi;
        s += d * d;
    }
    s
}

/// Plan this round's folds.
///
/// * `x_store` — the engine's current (pre-update) training rows.
/// * `rem` — sorted, deduplicated indices being removed this round; a
///   removed row can never be a fold target.
/// * `x_new` — the incoming batch.
/// * `eps` — fold radius: a batch row within `eps` (Euclidean) of a
///   surviving stored row (or of an earlier fresh row from the same
///   batch) folds instead of inserting. `eps = 0.0` folds exact repeats
///   only.
///
/// Matching is first-hit: stored rows are scanned in index order, then
/// earlier fresh rows of the same batch. Fold targets are reported in
/// post-update coordinates: a surviving stored row `i` lands at
/// `i - |{r in rem : r < i}|`, and fresh row `k` of the batch lands at
/// `(n - |rem|) + k`.
pub fn plan_folds_into(
    plan: &mut FoldPlan,
    x_store: &Mat,
    rem: &[usize],
    x_new: &Mat,
    eps: f64,
) {
    plan.fresh.clear();
    plan.folds.clear();
    let n = x_store.rows();
    let survivors_base = n - rem.len();
    let eps2 = eps * eps;
    'rows: for b in 0..x_new.rows() {
        let row = x_new.row(b);
        for i in 0..n {
            if rem.binary_search(&i).is_ok() {
                continue;
            }
            if dist2(row, x_store.row(i)) <= eps2 {
                let post = i - rem.partition_point(|&r| r < i);
                plan.folds.push((post, b));
                continue 'rows;
            }
        }
        // within-batch repeats: match against already-accepted fresh rows
        for (k, &fb) in plan.fresh.iter().enumerate() {
            if dist2(row, x_new.row(fb)) <= eps2 {
                plan.folds.push((survivors_base + k, b));
                continue 'rows;
            }
        }
        plan.fresh.push(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: &[&[f64]]) -> Mat {
        let m = rows[0].len();
        Mat::from_fn(rows.len(), m, |r, c| rows[r][c])
    }

    #[test]
    fn exact_repeat_folds_into_store() {
        let store = mat(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let batch = mat(&[&[3.0, 4.0], &[9.0, 9.0]]);
        let mut plan = FoldPlan::default();
        plan_folds_into(&mut plan, &store, &[], &batch, 0.0);
        assert_eq!(plan.folds, vec![(1, 0)]);
        assert_eq!(plan.fresh, vec![1]);
    }

    #[test]
    fn removed_rows_are_not_targets_and_indices_shift() {
        let store = mat(&[&[1.0, 0.0], &[2.0, 0.0], &[3.0, 0.0], &[4.0, 0.0]]);
        // remove rows 0 and 2; batch row 0 repeats stored row 3 which
        // lands at post-update index 3 - 2 = 1
        let batch = mat(&[&[4.0, 0.0], &[2.0, 0.0]]);
        let mut plan = FoldPlan::default();
        plan_folds_into(&mut plan, &store, &[0, 2], &batch, 0.0);
        assert_eq!(plan.folds, vec![(1, 0), (0, 1)]);
        assert!(plan.fresh.is_empty());
    }

    #[test]
    fn within_batch_repeat_folds_into_fresh_row() {
        let store = mat(&[&[1.0, 0.0]]);
        let batch = mat(&[&[7.0, 7.0], &[7.0, 7.0]]);
        let mut plan = FoldPlan::default();
        plan_folds_into(&mut plan, &store, &[], &batch, 0.0);
        // fresh row 0 lands at (1 - 0) + 0 = 1; batch row 1 folds there
        assert_eq!(plan.fresh, vec![0]);
        assert_eq!(plan.folds, vec![(1, 1)]);
    }

    #[test]
    fn eps_near_rows_fold_exact_only_at_zero() {
        let store = mat(&[&[1.0, 1.0]]);
        let batch = mat(&[&[1.0, 1.0 + 1e-7]]);
        let mut plan = FoldPlan::default();
        plan_folds_into(&mut plan, &store, &[], &batch, 0.0);
        assert!(plan.folds.is_empty(), "not an exact repeat");
        plan_folds_into(&mut plan, &store, &[], &batch, 1e-6);
        assert_eq!(plan.folds, vec![(0, 0)]);
    }
}
