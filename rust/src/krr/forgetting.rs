//! Forgetting-factor incremental KRR (extension; paper §I cites the
//! recursive-KRR variant of [1] where "old and new training samples have
//! different weights").
//!
//! Maintains `S[l+1] = lambda * S[l] + Phi_C Phi_C^T` with `0 < lambda <= 1`
//! so old evidence decays geometrically — the right behaviour for
//! non-stationary streams (concept drift), where plain incremental KRR
//! keeps stale samples at full weight forever.
//!
//! The inverse is maintained without refactorization:
//!
//! ```text
//! S' = lambda S + Phi_C Phi_C^T
//! S'^-1 = (1/lambda) * woodbury_incdec(S^-1, Phi_C / sqrt(lambda), +1...)
//! ```
//!
//! The bias is implicit: polynomial feature maps include the constant
//! monomial, so the affine term lives inside `u` (no separate `b` — the
//! decayed bordered system would otherwise mix decayed and undecayed
//! blocks).  `lambda = 1` reduces exactly to [`super::intrinsic`] without
//! the explicit intercept.

use crate::error::{Error, Result};
use crate::kernels::{Kernel, MonomialTable};
use crate::linalg::gemm::gemv;
use crate::linalg::matrix::axpy_slice;
use crate::linalg::solve::spd_inverse;
use crate::linalg::woodbury::{incdec_into, IncDecWork};
use crate::linalg::Mat;
use crate::ensure_shape;

/// Exponentially-weighted incremental KRR.
pub struct ForgettingKrr {
    table: MonomialTable,
    lambda: f64,
    /// Maintained S^-1 with S = sum lambda^age phi phi^T + lambda^rounds rho I.
    s_inv: Mat,
    /// Decayed Phi y^T running sum.
    py: Vec<f64>,
    /// Weight vector (bias folded into the constant feature).
    u: Vec<f64>,
    rounds: usize,
    work: IncDecWork,
}

impl ForgettingKrr {
    /// Fit on the initial window.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64, lambda: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.len(),
            "ForgettingKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.len()
        );
        if !(0.0 < lambda && lambda <= 1.0) {
            return Err(Error::Config(format!("lambda {lambda} not in (0, 1]")));
        }
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        let table = kernel.feature_table(x.cols()).ok_or_else(|| {
            Error::Config("forgetting KRR needs a finite intrinsic dimension".into())
        })?;
        let phi = table.map(x);
        // transpose-side SYRK: S = Φ^T Φ straight off the row-major store
        let mut s = crate::linalg::matrix::Mat::default();
        crate::linalg::gemm::syrk_t_into(1.0, &phi, 0.0, &mut s)?;
        s.add_diag(rho)?;
        let s_inv = spd_inverse(&s)?;
        let mut py = vec![0.0; table.j()];
        for (r, &yr) in y.iter().enumerate() {
            axpy_slice(yr, phi.row(r), &mut py);
        }
        let u = gemv(&s_inv, &py)?;
        Ok(Self { table, lambda, s_inv, py, u, rounds: 0, work: IncDecWork::default() })
    }

    /// One decayed incremental round: `S <- lambda S + Phi_C Phi_C^T`.
    pub fn step(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.len() && x_new.cols() == self.table.m,
            "ForgettingKrr::step",
            "x_new {:?}, y_new {}",
            x_new.shape(),
            y_new.len()
        );
        let c = x_new.rows();
        let j = self.table.j();
        if c > 0 {
            let phi_c = self.table.map(x_new); // (C, J)
            // scaled columns: Phi_C / sqrt(lambda)
            let inv_sqrt = 1.0 / self.lambda.sqrt();
            let mut cols = Mat::zeros(j, c);
            for r in 0..c {
                let src = phi_c.row(r);
                for jj in 0..j {
                    cols[(jj, r)] = src[jj] * inv_sqrt;
                }
            }
            let signs = vec![1.0; c];
            incdec_into(&mut self.s_inv, &cols, &signs, &mut self.work)?;
            self.s_inv.scale(1.0 / self.lambda);
            // py <- lambda py + Phi_C^T y
            for v in &mut self.py {
                *v *= self.lambda;
            }
            for (r, &yr) in y_new.iter().enumerate() {
                axpy_slice(yr, phi_c.row(r), &mut self.py);
            }
        } else {
            // pure decay round
            self.s_inv.scale(1.0 / self.lambda);
            for v in &mut self.py {
                *v *= self.lambda;
            }
        }
        self.rounds += 1;
        self.u = gemv(&self.s_inv, &self.py)?;
        Ok(())
    }

    /// Predict.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        let phi = self.table.map(x);
        gemv(&phi, &self.u)
    }

    /// Forgetting factor.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Rounds applied.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Weight vector.
    pub fn weights(&self) -> &[f64] {
        &self.u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::dot;
    use crate::testutil::assert_vec_close;
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, w: &[f64], seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), w) + 0.02 * rng.gaussian())
            .collect();
        (x, y)
    }

    /// lambda decay must match the direct weighted solve.
    #[test]
    fn matches_direct_weighted_solve() {
        let m = 3;
        let mut rng = Rng::new(1);
        let w = rng.gaussian_vec(m);
        let (x0, y0) = data(30, m, &w, 2);
        let kernel = Kernel::poly(2, 1.0);
        let (rho, lambda) = (0.5, 0.9);
        let mut model = ForgettingKrr::fit(&x0, &y0, &kernel, rho, lambda).unwrap();
        let mut batches = vec![(x0.clone(), y0.clone())];
        for k in 0..4 {
            let (xc, yc) = data(4, m, &w, 10 + k);
            model.step(&xc, &yc).unwrap();
            batches.push((xc, yc));
        }
        // direct: S = sum_k lambda^{age} Phi_k Phi_k^T + lambda^{rounds} rho I
        let table = kernel.feature_table(m).unwrap();
        let j = table.j();
        let rounds = batches.len() - 1;
        let mut s = Mat::zeros(j, j);
        let mut py = vec![0.0; j];
        for (k, (xb, yb)) in batches.iter().enumerate() {
            let age = rounds - if k == 0 { 0 } else { k };
            let wgt = lambda.powi(age as i32);
            let phi = table.map(xb);
            for r in 0..phi.rows() {
                let row = phi.row(r).to_vec();
                crate::linalg::gemm::ger(&mut s, wgt, &row, &row).unwrap();
                axpy_slice(wgt * yb[r], &row, &mut py);
            }
        }
        s.add_diag(rho * lambda.powi(rounds as i32)).unwrap();
        let u_direct = crate::linalg::solve::solve_spd(&s, &py).unwrap();
        assert_vec_close(model.weights(), &u_direct, 1e-6);
    }

    /// lambda = 1 tracks plain (bias-free) incremental KRR.
    #[test]
    fn lambda_one_is_plain_incremental() {
        let m = 3;
        let mut rng = Rng::new(3);
        let w = rng.gaussian_vec(m);
        let (x0, y0) = data(25, m, &w, 4);
        let kernel = Kernel::poly(2, 1.0);
        let mut model = ForgettingKrr::fit(&x0, &y0, &kernel, 0.5, 1.0).unwrap();
        let (xc, yc) = data(5, m, &w, 5);
        model.step(&xc, &yc).unwrap();
        // direct on the union
        let x_all = x0.vcat(&xc).unwrap();
        let mut y_all = y0.clone();
        y_all.extend_from_slice(&yc);
        let fresh = ForgettingKrr::fit(&x_all, &y_all, &kernel, 0.5, 1.0).unwrap();
        assert_vec_close(model.weights(), fresh.weights(), 1e-7);
    }

    /// Under concept drift, forgetting adapts while lambda=1 lags.
    #[test]
    fn adapts_to_drift() {
        let m = 4;
        let mut rng = Rng::new(6);
        let w_old = rng.gaussian_vec(m);
        let w_new: Vec<f64> = w_old.iter().map(|v| -v).collect(); // hard flip
        let (x0, y0) = data(60, m, &w_old, 7);
        let kernel = Kernel::poly(2, 1.0);
        let mut forgetful = ForgettingKrr::fit(&x0, &y0, &kernel, 0.5, 0.6).unwrap();
        let mut sticky = ForgettingKrr::fit(&x0, &y0, &kernel, 0.5, 1.0).unwrap();
        for k in 0..12 {
            let (xc, yc) = data(8, m, &w_new, 20 + k);
            forgetful.step(&xc, &yc).unwrap();
            sticky.step(&xc, &yc).unwrap();
        }
        let (xt, yt) = data(50, m, &w_new, 99);
        let rmse = |p: &[f64]| crate::krr::rmse(p, &yt);
        let rf = rmse(&forgetful.predict(&xt).unwrap());
        let rs = rmse(&sticky.predict(&xt).unwrap());
        assert!(rf < rs, "forgetting ({rf:.4}) must beat sticky ({rs:.4}) under drift");
    }

    #[test]
    fn rejects_bad_params() {
        let (x, y) = data(10, 3, &[1.0, 0.0, 0.0], 8);
        let kernel = Kernel::poly(2, 1.0);
        assert!(ForgettingKrr::fit(&x, &y, &kernel, 0.5, 0.0).is_err());
        assert!(ForgettingKrr::fit(&x, &y, &kernel, 0.5, 1.5).is_err());
        assert!(ForgettingKrr::fit(&x, &y, &Kernel::rbf_radius(1.0), 0.5, 0.9).is_err());
    }

    #[test]
    fn pure_decay_round() {
        let (x, y) = data(20, 3, &[1.0, -1.0, 0.5], 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut model = ForgettingKrr::fit(&x, &y, &kernel, 0.5, 0.8).unwrap();
        let u_before = model.weights().to_vec();
        model.step(&Mat::zeros(0, 3), &[]).unwrap();
        // decaying S and py by the same factor leaves u unchanged
        assert_vec_close(model.weights(), &u_before, 1e-9);
        assert_eq!(model.rounds(), 1);
    }
}
