//! Empirical-space incremental KRR over **sparse** sample stores — the
//! engine that runs the paper's Dorothea experiment at TRUE scale
//! (N=800, M=10^6): Gram construction is O(nnz) per pair instead of O(M),
//! and no dense (N, M) store ever exists.
//!
//! Same math as [`super::empirical`] (eq. 18–30) — the maintained `Q^-1`,
//! bordered grow/shrink, and head refresh are shared through
//! [`crate::linalg::woodbury`]; only the kernel evaluations differ. Like
//! the dense engines, the coefficient path carries `D` target columns
//! behind the ONE maintained inverse: `fit_multi` solves all `D`
//! right-hand sides from one factorization, and the slice-based methods
//! are thin `D = 1` shims.

use crate::error::{Error, Result};
use crate::kernels::Kernel;
use crate::linalg::gemm::matmul_into;
use crate::linalg::matrix::dot;
use crate::linalg::solve::spd_inverse;
use crate::linalg::sparse::SparseMat;
use crate::linalg::woodbury::{bordered_grow, bordered_shrink};
use crate::linalg::Mat;
use crate::ensure_shape;

/// Sparse-store empirical-space incremental KRR.
pub struct SparseEmpiricalKrr {
    kernel: Kernel,
    rho: f64,
    /// Sparse training samples, engine order.
    x: SparseMat,
    /// Targets, (N, D).
    y: Mat,
    /// Maintained (K + rho I)^-1 — shared by all D output columns.
    q_inv: Mat,
    /// Dual coefficients, one column per output (N, D).
    a: Mat,
    /// Per-output bias (D,).
    b: Vec<f64>,
}

impl SparseEmpiricalKrr {
    /// Fit from scratch: O(N^2 nnz/row + N^3), `D = 1`.
    pub fn fit(x: &SparseMat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::fit_multi(x, &ym, kernel, rho)
    }

    /// Fit with a `(N, D)` target matrix: one factorization, `D`
    /// right-hand sides.
    pub fn fit_multi(x: &SparseMat, y: &Mat, kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.rows(),
            "SparseEmpiricalKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.rows()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        if y.cols() == 0 {
            return Err(Error::Config("target matrix needs >= 1 column".into()));
        }
        let mut q = x.gram(x, kernel)?;
        q.symmetrize();
        q.add_diag(rho)?;
        let q_inv = spd_inverse(&q)?;
        let mut model = Self {
            kernel: kernel.clone(),
            rho,
            x: x.clone(),
            y: y.clone(),
            q_inv,
            a: Mat::zeros(y.rows(), y.cols()),
            b: vec![0.0; y.cols()],
        };
        model.refresh_head()?;
        Ok(model)
    }

    /// Head refresh over all D columns: eq. 21-22 with the shared
    /// `v = Q^-1 e`.
    fn refresh_head(&mut self) -> Result<()> {
        let v = self.q_inv.row_sums();
        let ev: f64 = v.iter().sum();
        if ev.abs() < 1e-14 {
            return Err(Error::numerical("refresh_head", format!("e Q^-1 e = {ev:.3e}")));
        }
        let d = self.y.cols();
        for bd in self.b.iter_mut() {
            *bd = 0.0;
        }
        for (i, &vi) in v.iter().enumerate() {
            for (bd, &yv) in self.b.iter_mut().zip(self.y.row(i)) {
                *bd += vi * yv;
            }
        }
        for bd in self.b.iter_mut() {
            *bd /= ev;
        }
        let mut qy = Mat::default();
        matmul_into(&self.q_inv, &self.y, &mut qy)?; // (N, D)
        self.a.resize_scratch(self.y.rows(), d);
        for (i, &vi) in v.iter().enumerate() {
            for dc in 0..d {
                self.a[(i, dc)] = qy[(i, dc)] - self.b[dc] * vi;
            }
        }
        Ok(())
    }

    /// One batched +|C|/−|R| round (eq. 30 ordering: shrink then grow),
    /// `D = 1`.
    pub fn inc_dec(
        &mut self,
        x_new: &SparseMat,
        y_new: &[f64],
        remove_idx: &[usize],
    ) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "inc_dec is the D=1 surface; use inc_dec_multi".into(),
            ));
        }
        let ym = Mat::from_vec(y_new.len(), 1, y_new.to_vec())?;
        self.inc_dec_multi(x_new, &ym, remove_idx)
    }

    /// One batched +|C|/−|R| round over all `D` output columns.
    pub fn inc_dec_multi(
        &mut self,
        x_new: &SparseMat,
        y_new: &Mat,
        remove_idx: &[usize],
    ) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.rows() && x_new.cols() == self.x.cols(),
            "SparseEmpiricalKrr::inc_dec",
            "x_new {}x{}, y_new {} rows",
            x_new.rows(),
            x_new.cols(),
            y_new.rows()
        );
        if x_new.rows() > 0 {
            ensure_shape!(
                y_new.cols() == self.y.cols(),
                "SparseEmpiricalKrr::inc_dec",
                "y_new has {} cols, engine carries D = {}",
                y_new.cols(),
                self.y.cols()
            );
        }
        let mut rem: Vec<usize> = remove_idx.to_vec();
        rem.sort_unstable();
        rem.dedup();
        if let Some(&mx) = rem.last() {
            if mx >= self.y.rows() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.rows()
                )));
            }
        }
        if x_new.rows() + rem.len() == 0 {
            return Ok(());
        }
        if self.y.rows() + x_new.rows() <= rem.len() {
            return Err(Error::InvalidUpdate("update would empty the training set".into()));
        }
        // shrink
        if !rem.is_empty() {
            self.q_inv = bordered_shrink(&self.q_inv, &rem)?;
            let keep: Vec<usize> = (0..self.y.rows()).filter(|i| !rem.contains(i)).collect();
            self.x = select_sparse_rows(&self.x, &keep)?;
            self.y.drop_rows_sorted(&rem)?;
        }
        // grow
        if x_new.rows() > 0 {
            let eta = self.x.gram(x_new, &self.kernel)?; // (N, C)
            let mut q_cc = x_new.gram(x_new, &self.kernel)?;
            q_cc.symmetrize();
            q_cc.add_diag(self.rho)?;
            self.q_inv = bordered_grow(&self.q_inv, &eta, &q_cc)?;
            self.x = vcat_sparse(&self.x, x_new)?;
            self.y.push_rows(y_new)?;
        }
        self.refresh_head()
    }

    /// Predict for sparse query rows, `D = 1`.
    pub fn predict(&self, x: &SparseMat) -> Result<Vec<f64>> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "predict is the D=1 surface; use predict_multi".into(),
            ));
        }
        let out = self.predict_multi(x)?;
        Ok(out.as_slice().to_vec())
    }

    /// Predict all D output columns for sparse query rows: ONE packed
    /// `(B, N)·(N, D)` GEMM instead of D GEMVs.
    pub fn predict_multi(&self, x: &SparseMat) -> Result<Mat> {
        let k_star = x.gram(&self.x, &self.kernel)?; // (B, N)
        let mut out = Mat::default();
        matmul_into(&k_star, &self.a, &mut out)?;
        let d = self.y.cols();
        for row in out.as_mut_slice().chunks_exact_mut(d) {
            for (v, &bd) in row.iter_mut().zip(&self.b) {
                *v += bd;
            }
        }
        Ok(out)
    }

    /// Dual weights (`D = 1` view).
    pub fn dual_weights(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "dual_weights is the D=1 view");
        self.a.as_slice()
    }

    /// Dual weight matrix, (N, D).
    pub fn dual_weights_multi(&self) -> &Mat {
        &self.a
    }

    /// Bias (`D = 1` view).
    pub fn bias(&self) -> f64 {
        debug_assert_eq!(self.y.cols(), 1, "bias is the D=1 view");
        self.b[0]
    }

    /// Per-output biases (D,).
    pub fn bias_multi(&self) -> &[f64] {
        &self.b
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.rows()
    }

    /// Number of target columns D.
    pub fn n_outputs(&self) -> usize {
        self.y.cols()
    }
}

fn select_sparse_rows(x: &SparseMat, keep: &[usize]) -> Result<SparseMat> {
    let entries = keep
        .iter()
        .map(|&r| {
            let (ix, vx) = x.row(r);
            ix.iter().copied().zip(vx.iter().copied()).collect()
        })
        .collect();
    SparseMat::from_rows(keep.len(), x.cols(), entries)
}

fn vcat_sparse(a: &SparseMat, b: &SparseMat) -> Result<SparseMat> {
    let mut entries: Vec<Vec<(u32, f64)>> = Vec::with_capacity(a.rows() + b.rows());
    for r in 0..a.rows() {
        let (ix, vx) = a.row(r);
        entries.push(ix.iter().copied().zip(vx.iter().copied()).collect());
    }
    for r in 0..b.rows() {
        let (ix, vx) = b.row(r);
        entries.push(ix.iter().copied().zip(vx.iter().copied()).collect());
    }
    SparseMat::from_rows(a.rows() + b.rows(), a.cols(), entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::drt_like_sparse;
    use crate::krr::empirical::EmpiricalKrr;
    use crate::krr::KrrModel;
    use crate::testutil::{assert_close, assert_vec_close};

    #[test]
    fn matches_dense_engine() {
        let (xs, y) = drt_like_sparse(40, 500, 0.05, 1);
        let xd = xs.to_dense();
        let kernel = Kernel::poly(2, 1.0);
        let sparse = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let dense = EmpiricalKrr::fit(&xd, &y, &kernel, 0.5).unwrap();
        assert_vec_close(sparse.dual_weights(), dense.dual_weights(), 1e-8);
        assert_close(sparse.bias(), dense.bias(), 1e-8);
    }

    #[test]
    fn inc_dec_matches_dense_engine() {
        let (xs, y) = drt_like_sparse(30, 400, 0.05, 2);
        let (xc, yc) = drt_like_sparse(4, 400, 0.05, 3);
        let kernel = Kernel::rbf_radius(5.0);
        let mut sparse = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let mut dense = EmpiricalKrr::fit(&xs.to_dense(), &y, &kernel, 0.5).unwrap();
        sparse.inc_dec(&xc, &yc, &[1, 7]).unwrap();
        dense.inc_dec(&xc.to_dense(), &yc, &[1, 7]).unwrap();
        assert_vec_close(sparse.dual_weights(), dense.dual_weights(), 1e-7);
        assert_eq!(sparse.n_samples(), 32);
        // predictions agree too
        let (xt, _) = drt_like_sparse(6, 400, 0.05, 4);
        let ps = sparse.predict(&xt).unwrap();
        let pd = dense.predict(&xt.to_dense()).unwrap();
        assert_vec_close(&ps, &pd, 1e-7);
    }

    #[test]
    fn paper_scale_dims_run() {
        // N=120 @ M=1e6: impossible dense (1 GB+), comfortable sparse.
        let (xs, y) = drt_like_sparse(120, 1_000_000, 0.002, 5);
        let kernel = Kernel::poly(2, 1.0);
        let mut model = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = drt_like_sparse(4, 1_000_000, 0.002, 6);
        model.inc_dec(&xc, &yc, &[0, 1]).unwrap();
        assert_eq!(model.n_samples(), 122);
        let p = model.predict(&xs).unwrap();
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn multi_output_columns_match_independent_engines() {
        let (xs, y0) = drt_like_sparse(25, 300, 0.08, 7);
        let (_, y1) = drt_like_sparse(25, 300, 0.08, 8);
        let ym = Mat::from_fn(25, 2, |r, c| if c == 0 { y0[r] } else { y1[r] });
        let kernel = Kernel::poly(2, 1.0);
        let multi = SparseEmpiricalKrr::fit_multi(&xs, &ym, &kernel, 0.5).unwrap();
        let e0 = SparseEmpiricalKrr::fit(&xs, &y0, &kernel, 0.5).unwrap();
        let e1 = SparseEmpiricalKrr::fit(&xs, &y1, &kernel, 0.5).unwrap();
        let (xt, _) = drt_like_sparse(5, 300, 0.08, 9);
        let pm = multi.predict_multi(&xt).unwrap();
        let p0 = e0.predict(&xt).unwrap();
        let p1 = e1.predict(&xt).unwrap();
        for r in 0..5 {
            assert!((pm[(r, 0)] - p0[r]).abs() < 1e-10);
            assert!((pm[(r, 1)] - p1[r]).abs() < 1e-10);
        }
        assert_eq!(multi.n_outputs(), 2);
        assert!((multi.bias_multi()[0] - e0.bias()).abs() < 1e-10);
        assert!((multi.bias_multi()[1] - e1.bias()).abs() < 1e-10);
    }
}
