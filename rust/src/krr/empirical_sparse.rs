//! Empirical-space incremental KRR over **sparse** sample stores — the
//! engine that runs the paper's Dorothea experiment at TRUE scale
//! (N=800, M=10^6): Gram construction is O(nnz) per pair instead of O(M),
//! and no dense (N, M) store ever exists.
//!
//! Same math as [`super::empirical`] (eq. 18–30) — the maintained `Q^-1`,
//! bordered grow/shrink, and head refresh are shared through
//! [`crate::linalg::woodbury`]; only the kernel evaluations differ.

use crate::error::{Error, Result};
use crate::kernels::Kernel;
use crate::linalg::gemm::gemv;
use crate::linalg::matrix::dot;
use crate::linalg::solve::spd_inverse;
use crate::linalg::sparse::SparseMat;
use crate::linalg::woodbury::{bordered_grow, bordered_shrink};
use crate::linalg::Mat;
use crate::ensure_shape;

/// Sparse-store empirical-space incremental KRR.
pub struct SparseEmpiricalKrr {
    kernel: Kernel,
    rho: f64,
    /// Sparse training samples, engine order.
    x: SparseMat,
    y: Vec<f64>,
    /// Maintained (K + rho I)^-1.
    q_inv: Mat,
    a: Vec<f64>,
    b: f64,
}

impl SparseEmpiricalKrr {
    /// Fit from scratch: O(N^2 nnz/row + N^3).
    pub fn fit(x: &SparseMat, y: &[f64], kernel: &Kernel, rho: f64) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.len(),
            "SparseEmpiricalKrr::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.len()
        );
        if rho <= 0.0 {
            return Err(Error::Config("ridge rho must be > 0".into()));
        }
        let mut q = x.gram(x, kernel)?;
        q.symmetrize();
        q.add_diag(rho)?;
        let q_inv = spd_inverse(&q)?;
        let mut model = Self {
            kernel: kernel.clone(),
            rho,
            x: x.clone(),
            y: y.to_vec(),
            q_inv,
            a: vec![0.0; y.len()],
            b: 0.0,
        };
        model.refresh_head()?;
        Ok(model)
    }

    fn refresh_head(&mut self) -> Result<()> {
        let v = self.q_inv.row_sums();
        let ev: f64 = v.iter().sum();
        if ev.abs() < 1e-14 {
            return Err(Error::numerical("refresh_head", format!("e Q^-1 e = {ev:.3e}")));
        }
        self.b = dot(&self.y, &v) / ev;
        let qy = gemv(&self.q_inv, &self.y)?;
        self.a = qy.iter().zip(&v).map(|(q, vi)| q - self.b * vi).collect();
        Ok(())
    }

    /// One batched +|C|/−|R| round (eq. 30 ordering: shrink then grow).
    pub fn inc_dec(
        &mut self,
        x_new: &SparseMat,
        y_new: &[f64],
        remove_idx: &[usize],
    ) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.len() && x_new.cols() == self.x.cols(),
            "SparseEmpiricalKrr::inc_dec",
            "x_new {}x{}, y_new {}",
            x_new.rows(),
            x_new.cols(),
            y_new.len()
        );
        let mut rem: Vec<usize> = remove_idx.to_vec();
        rem.sort_unstable();
        rem.dedup();
        if let Some(&mx) = rem.last() {
            if mx >= self.y.len() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.len()
                )));
            }
        }
        if x_new.rows() + rem.len() == 0 {
            return Ok(());
        }
        if self.y.len() + x_new.rows() <= rem.len() {
            return Err(Error::InvalidUpdate("update would empty the training set".into()));
        }
        // shrink
        if !rem.is_empty() {
            self.q_inv = bordered_shrink(&self.q_inv, &rem)?;
            let keep: Vec<usize> = (0..self.y.len()).filter(|i| !rem.contains(i)).collect();
            self.x = select_sparse_rows(&self.x, &keep)?;
            for (i, &ri) in rem.iter().enumerate() {
                self.y.remove(ri - i);
            }
        }
        // grow
        if x_new.rows() > 0 {
            let eta = self.x.gram(x_new, &self.kernel)?; // (N, C)
            let mut q_cc = x_new.gram(x_new, &self.kernel)?;
            q_cc.symmetrize();
            q_cc.add_diag(self.rho)?;
            self.q_inv = bordered_grow(&self.q_inv, &eta, &q_cc)?;
            self.x = vcat_sparse(&self.x, x_new)?;
            self.y.extend_from_slice(y_new);
        }
        self.refresh_head()
    }

    /// Predict for sparse query rows.
    pub fn predict(&self, x: &SparseMat) -> Result<Vec<f64>> {
        let k_star = x.gram(&self.x, &self.kernel)?; // (B, N)
        let mut out = gemv(&k_star, &self.a)?;
        for v in &mut out {
            *v += self.b;
        }
        Ok(out)
    }

    /// Dual weights.
    pub fn dual_weights(&self) -> &[f64] {
        &self.a
    }

    /// Bias.
    pub fn bias(&self) -> f64 {
        self.b
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }
}

fn select_sparse_rows(x: &SparseMat, keep: &[usize]) -> Result<SparseMat> {
    let entries = keep
        .iter()
        .map(|&r| {
            let (ix, vx) = x.row(r);
            ix.iter().copied().zip(vx.iter().copied()).collect()
        })
        .collect();
    SparseMat::from_rows(keep.len(), x.cols(), entries)
}

fn vcat_sparse(a: &SparseMat, b: &SparseMat) -> Result<SparseMat> {
    let mut entries: Vec<Vec<(u32, f64)>> = Vec::with_capacity(a.rows() + b.rows());
    for r in 0..a.rows() {
        let (ix, vx) = a.row(r);
        entries.push(ix.iter().copied().zip(vx.iter().copied()).collect());
    }
    for r in 0..b.rows() {
        let (ix, vx) = b.row(r);
        entries.push(ix.iter().copied().zip(vx.iter().copied()).collect());
    }
    SparseMat::from_rows(a.rows() + b.rows(), a.cols(), entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::drt_like_sparse;
    use crate::krr::empirical::EmpiricalKrr;
    use crate::krr::KrrModel;
    use crate::testutil::{assert_close, assert_vec_close};

    #[test]
    fn matches_dense_engine() {
        let (xs, y) = drt_like_sparse(40, 500, 0.05, 1);
        let xd = xs.to_dense();
        let kernel = Kernel::poly(2, 1.0);
        let sparse = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let dense = EmpiricalKrr::fit(&xd, &y, &kernel, 0.5).unwrap();
        assert_vec_close(sparse.dual_weights(), dense.dual_weights(), 1e-8);
        assert_close(sparse.bias(), dense.bias(), 1e-8);
    }

    #[test]
    fn inc_dec_matches_dense_engine() {
        let (xs, y) = drt_like_sparse(30, 400, 0.05, 2);
        let (xc, yc) = drt_like_sparse(4, 400, 0.05, 3);
        let kernel = Kernel::rbf_radius(5.0);
        let mut sparse = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let mut dense = EmpiricalKrr::fit(&xs.to_dense(), &y, &kernel, 0.5).unwrap();
        sparse.inc_dec(&xc, &yc, &[1, 7]).unwrap();
        dense.inc_dec(&xc.to_dense(), &yc, &[1, 7]).unwrap();
        assert_vec_close(sparse.dual_weights(), dense.dual_weights(), 1e-7);
        assert_eq!(sparse.n_samples(), 32);
        // predictions agree too
        let (xt, _) = drt_like_sparse(6, 400, 0.05, 4);
        let ps = sparse.predict(&xt).unwrap();
        let pd = dense.predict(&xt.to_dense()).unwrap();
        assert_vec_close(&ps, &pd, 1e-7);
    }

    #[test]
    fn paper_scale_dims_run() {
        // N=120 @ M=1e6: impossible dense (1 GB+), comfortable sparse.
        let (xs, y) = drt_like_sparse(120, 1_000_000, 0.002, 5);
        let kernel = Kernel::poly(2, 1.0);
        let mut model = SparseEmpiricalKrr::fit(&xs, &y, &kernel, 0.5).unwrap();
        let (xc, yc) = drt_like_sparse(4, 1_000_000, 0.002, 6);
        model.inc_dec(&xc, &yc, &[0, 1]).unwrap();
        assert_eq!(model.n_samples(), 122);
        let p = model.predict(&xs).unwrap();
        assert!(p.iter().all(|v| v.is_finite()));
    }
}
