//! Kernel Ridge Regression engines with multiple incremental/decremental
//! updates — the paper's primary contribution.
//!
//! * [`intrinsic`] — §II: maintains `S^-1 = (Φ Φ^T + ρI)^-1` in feature
//!   space (dimension J); right choice when N ≫ J.
//! * [`empirical`] — §III: maintains `Q^-1 = (K + ρI)^-1` in sample space
//!   (dimension N); right choice when M ≫ N and for RBF kernels.
//! * [`advisor`] — §II.B/§III.B: the batch-size and space-selection cost
//!   model.
//!
//! Both engines expose the same [`KrrModel`] surface so the coordinator can
//! route to either behind one trait object.

pub mod advisor;
pub mod empirical;
pub mod empirical_sparse;
pub mod fold;
pub mod forgetting;
pub mod intrinsic;

use crate::error::Result;
use crate::linalg::Mat;

/// Common interface over the two KRR operating modes.
///
/// Engines carry `D = n_outputs()` target columns behind ONE maintained
/// inverse: the factorization amortizes across outputs, updates apply the
/// Woodbury core to all coefficient columns at once, and multi-output
/// predicts run as packed GEMMs. The slice-based methods are the `D = 1`
/// surface (they error with [`crate::error::Error::Config`] on a
/// multi-output engine); the `_multi` methods are the general path and
/// are exact aliases at `D = 1`.
pub trait KrrModel: Send {
    /// Predict responses for a block of raw feature rows (`D = 1` only).
    fn predict(&self, x: &Mat) -> Result<Vec<f64>>;

    /// One multiple incremental/decremental round: add the rows of
    /// `(x_new, y_new)`, remove the training samples at `remove_idx`
    /// (indices into the *current* training set), in a single batched
    /// update (`D = 1` only).
    fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()>;

    /// Current training-set size.
    fn n_samples(&self) -> usize;

    /// Number of target columns `D` this engine carries.
    fn n_outputs(&self) -> usize;

    /// Predictions over the engine's own training set (the outlier-scoring
    /// hot path; engines override with stored-feature fast paths)
    /// (`D = 1` only).
    fn predict_training(&self) -> Result<Vec<f64>>;

    /// Predict all `D` output columns for a block of rows: `(B, D)` out.
    fn predict_multi(&self, x: &Mat) -> Result<Mat>;

    /// Multi-output inc/dec round: `y_new` is `(B, D)`.
    fn inc_dec_multi(&mut self, x_new: &Mat, y_new: &Mat, remove_idx: &[usize])
        -> Result<()>;

    /// Multi-output training predictions, `(N, D)`.
    fn predict_training_multi(&self) -> Result<Mat>;

    /// Fold duplicate rows into their multiplicity-weighted targets:
    /// each `(store_index, batch_row)` pair (see [`fold::FoldPlan`]; the
    /// store index is post-`inc_dec` for this round) bumps the target
    /// row's multiplicity, averages its stored target, and applies the
    /// equivalent rank-1 maintained-inverse update — numerically
    /// equivalent to having inserted the duplicate unfolded.
    fn apply_folds(&mut self, folds: &[(usize, usize)], x_new: &Mat, y_new: &Mat)
        -> Result<()>;

    /// Human-readable mode name ("intrinsic"/"empirical").
    fn mode(&self) -> &'static str;
}

/// Classification accuracy of sign-thresholded regression outputs vs ±1
/// labels (the paper's datasets are 2-class with ±1 targets).
pub fn classification_accuracy(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred
        .iter()
        .zip(y)
        .filter(|(p, t)| {
            (p.is_sign_positive() && **t > 0.0) || (p.is_sign_negative() && **t <= 0.0)
        })
        .count();
    hits as f64 / pred.len() as f64
}

/// Root-mean-square error.
pub fn rmse(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred.iter().zip(y).map(|(p, t)| (p - t) * (p - t)).sum();
    (s / pred.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_signs() {
        let pred = [0.9, -0.3, 0.1, -2.0];
        let y = [1.0, 1.0, 1.0, -1.0];
        assert!((classification_accuracy(&pred, &y) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[0.0, 2.0]) - (0.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[], &[]), 0.0);
    }
}
