//! Experiment/runtime configuration: a line-oriented `key = value` format
//! (TOML subset: comments, sections flattened as `section.key`), plus typed
//! accessors and CLI-override merging.  Also hosts the canonical
//! [`ExperimentConfig`] used by the paper-reproduction benches and examples.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat key-value configuration with dotted sections.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            map.insert(key, val);
        }
        Ok(Self { map })
    }

    /// Load from a file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.map.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| Error::Config(format!("key {key}: cannot parse {raw:?}"))),
        }
    }

    /// Set/override a value.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.map.insert(key.to_string(), value.into());
    }

    /// Merge `other` on top of `self`.
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.map {
            self.map.insert(k.clone(), v.clone());
        }
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Which operating space an engine runs in (paper §II vs §III).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Space {
    /// Feature-space `S^-1` maintenance — right when N >> J.
    Intrinsic,
    /// Sample-space `Q^-1` maintenance — right when M >> N (and for RBF).
    Empirical,
}

impl std::str::FromStr for Space {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "intrinsic" => Ok(Space::Intrinsic),
            "empirical" => Ok(Space::Empirical),
            other => Err(Error::Config(format!("unknown space {other:?}"))),
        }
    }
}

/// Canonical experiment description (one paper table/figure cell).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset name ("ecg" or "drt").
    pub dataset: String,
    /// Kernel spec ("poly2", "poly3", "rbf").
    pub kernel: String,
    /// Ridge parameter rho (paper: 0.5 for KRR).
    pub ridge: f64,
    /// Basic (initial) training size.
    pub train_size: usize,
    /// Samples added per round (paper: 4).
    pub inc_per_round: usize,
    /// Samples removed per round (paper: 2).
    pub dec_per_round: usize,
    /// Number of rounds (paper: 10).
    pub rounds: usize,
    /// Operating space.
    pub space: Space,
    /// RNG seed.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper defaults for the ECG-like intrinsic-space experiments.
    pub fn ecg(kernel: &str, train_size: usize) -> Self {
        Self {
            dataset: "ecg".into(),
            kernel: kernel.into(),
            ridge: 0.5,
            train_size,
            inc_per_round: 4,
            dec_per_round: 2,
            rounds: 10,
            space: Space::Intrinsic,
            seed: 0xEC6,
        }
    }

    /// Paper defaults for the DRT-like empirical-space experiments.
    pub fn drt(kernel: &str, train_size: usize) -> Self {
        Self {
            dataset: "drt".into(),
            kernel: kernel.into(),
            ridge: 0.5,
            train_size,
            inc_per_round: 4,
            dec_per_round: 2,
            rounds: 10,
            space: Space::Empirical,
            seed: 0xD27,
        }
    }

    /// Build from a [`Config`] section (keys: `exp.dataset`, `exp.kernel`...).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let dataset: String = cfg.get_or("exp.dataset", "ecg".to_string())?;
        let space = if dataset == "drt" { Space::Empirical } else { Space::Intrinsic };
        Ok(Self {
            dataset,
            kernel: cfg.get_or("exp.kernel", "poly2".to_string())?,
            ridge: cfg.get_or("exp.ridge", 0.5)?,
            train_size: cfg.get_or("exp.train_size", 2000usize)?,
            inc_per_round: cfg.get_or("exp.inc_per_round", 4usize)?,
            dec_per_round: cfg.get_or("exp.dec_per_round", 2usize)?,
            rounds: cfg.get_or("exp.rounds", 10usize)?,
            space: cfg
                .get("exp.space")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(space),
            seed: cfg.get_or("exp.seed", 7u64)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let c = Config::parse(
            "# comment\nfoo = 1\n[exp]\ndataset = \"drt\"\nridge = 0.5\n",
        )
        .unwrap();
        assert_eq!(c.get("foo"), Some("1"));
        assert_eq!(c.get("exp.dataset"), Some("drt"));
        assert_eq!(c.get_or("exp.ridge", 0.0).unwrap(), 0.5);
        assert_eq!(c.get_or("missing", 9usize).unwrap(), 9);
    }

    #[test]
    fn parse_errors() {
        assert!(Config::parse("novalue\n").is_err());
        let c = Config::parse("x = abc\n").unwrap();
        assert!(c.get_or("x", 1.0f64).is_err());
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("x = 1\ny = 2\n").unwrap();
        let b = Config::parse("y = 3\nz = 4\n").unwrap();
        a.merge(&b);
        assert_eq!(a.get("y"), Some("3"));
        assert_eq!(a.get("z"), Some("4"));
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn experiment_from_config() {
        let c = Config::parse("[exp]\ndataset = drt\nkernel = rbf\nrounds = 3\n").unwrap();
        let e = ExperimentConfig::from_config(&c).unwrap();
        assert_eq!(e.space, Space::Empirical);
        assert_eq!(e.kernel, "rbf");
        assert_eq!(e.rounds, 3);
        assert_eq!(e.inc_per_round, 4);
    }

    #[test]
    fn space_parse() {
        assert_eq!("intrinsic".parse::<Space>().unwrap(), Space::Intrinsic);
        assert!("weird".parse::<Space>().is_err());
    }

    #[test]
    fn paper_defaults() {
        let e = ExperimentConfig::ecg("poly2", 1000);
        assert_eq!(e.inc_per_round, 4);
        assert_eq!(e.dec_per_round, 2);
        assert_eq!(e.rounds, 10);
        assert_eq!(e.ridge, 0.5);
    }
}
