//! mikrr — leader binary: the streaming coordinator CLI.
//!
//! Subcommands:
//! * `serve`    — run the full streaming pipeline (sensors -> sink ->
//!   batcher -> multiple inc/dec updates) on a synthetic workload and
//!   report throughput/latency.
//! * `eval`     — one paper-style experiment (dataset x kernel),
//!   printing the per-round log10 table rows.
//! * `info`     — environment/artifact report.
//!
//! The full table/figure reproduction lives in `cargo bench`
//! (`rust/benches/paper_tables.rs`) and `examples/paper_eval.rs`.

use mikrr::cli::{App, Arg};
use mikrr::config::Space;
use mikrr::coordinator::experiment::{run_krr, Strategy};
use mikrr::coordinator::{Coordinator, CoordinatorConfig};
use mikrr::data::synth;
use mikrr::error::Error;
use mikrr::kernels::Kernel;
use mikrr::krr::classification_accuracy;
use mikrr::metrics::Timer;
use mikrr::streaming::batcher::BatchPolicy;
use mikrr::streaming::outlier::OutlierConfig;
use mikrr::streaming::sink::SinkNode;
use mikrr::streaming::source::{SensorNode, SourceConfig};

fn app() -> App {
    App::new("mikrr", "multiple incremental/decremental KRR coordinator")
        .subcommand(
            App::new("serve", "run the streaming coordinator on a synthetic sensor fleet")
                .arg(Arg::flag("train", "initial training size").default("2000"))
                .arg(Arg::flag("stream", "streamed samples per sensor").default("200"))
                .arg(Arg::flag("sensors", "number of sensor nodes").default("4"))
                .arg(Arg::flag("dim", "feature dimension").default("21"))
                .arg(Arg::flag("kernel", "poly2|poly3|rbf|linear").default("poly2"))
                .arg(Arg::flag("batch", "max multiple-update batch size").default("4"))
                .arg(Arg::flag("outlier-rate", "injected outlier fraction").default("0.02"))
                .arg(Arg::flag("seed", "rng seed").default("7"))
                .arg(Arg::switch("uncertainty", "serve KBR predictive variance too")),
        )
        .subcommand(
            App::new("eval", "run one paper-style incremental experiment")
                .arg(Arg::flag("dataset", "ecg|drt").default("ecg"))
                .arg(Arg::flag("kernel", "poly2|poly3|rbf").default("poly2"))
                .arg(Arg::flag("train", "initial training size").default("2000"))
                .arg(Arg::flag("rounds", "rounds of +4/-2").default("10"))
                .arg(Arg::flag("seed", "rng seed").default("7"))
                .arg(Arg::switch("skip-none", "skip the slow full-retrain baseline")),
        )
        .subcommand(App::new("info", "environment and artifact report"))
}

fn main() {
    let matches = match app().parse(std::env::args().skip(1)) {
        Ok(m) => m,
        Err(Error::Config(help)) => {
            eprintln!("{help}");
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match matches.cmd() {
        "serve" => cmd_serve(&matches),
        "eval" => cmd_eval(&matches),
        "info" => cmd_info(),
        _ => {
            println!("{}", app().help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parse_kernel(name: &str) -> Result<Kernel, Error> {
    Kernel::parse(name).ok_or_else(|| Error::Config(format!("unknown kernel {name:?}")))
}

fn cmd_serve(m: &mikrr::cli::Matches) -> Result<(), Error> {
    let train: usize = m.get_parse("train")?;
    let stream: usize = m.get_parse("stream")?;
    let sensors: usize = m.get_parse("sensors")?;
    let dim: usize = m.get_parse("dim")?;
    let batch: usize = m.get_parse("batch")?;
    let outlier_rate: f64 = m.get_parse("outlier-rate")?;
    let seed: u64 = m.get_parse("seed")?;
    let kernel = parse_kernel(m.get("kernel").unwrap())?;

    println!(
        "mikrr serve: train={train} stream={stream}x{sensors} dim={dim} kernel={kernel:?}"
    );
    let base = synth::ecg_like(train, dim, seed);
    let cfg = CoordinatorConfig {
        kernel,
        ridge: 0.5,
        space: None,
        batch: BatchPolicy {
            max_batch: batch,
            max_wait: std::time::Duration::from_millis(20),
        },
        outlier: Some(OutlierConfig::default()),
        with_uncertainty: m.is_set("uncertainty"),
        snapshot_rollback: false,
        fold_eps: None,
    };
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, cfg)?;
    println!("space routed: {:?}", coordinator.space());

    let mut sink = SinkNode::new(64);
    let mut handles = Vec::new();
    for sid in 0..sensors {
        let shard = synth::ecg_like(stream, dim, seed ^ ((sid as u64 + 1) << 8));
        let scfg = SourceConfig {
            source_id: sid,
            outlier_rate,
            delay: None,
            seed: seed + sid as u64,
        };
        handles.push(SensorNode::new(shard, scfg).spawn(sink.sender()));
    }
    // all sender handles are out: seal so the run loop ends the moment the
    // sensors finish instead of burning a final max_wait timeout
    sink.seal();
    let t = Timer::start();
    let outcomes = coordinator.run(&mut sink, usize::MAX)?;
    let wall = t.elapsed();
    for h in handles {
        h.join().map_err(|_| Error::Stream("sensor thread panicked".into()))?;
    }
    let added: usize = outcomes.iter().map(|o| o.added).sum();
    let removed: usize = outcomes.iter().map(|o| o.removed).sum();
    println!(
        "processed {added} arrivals / removed {removed} outliers in {} rounds, \
         {wall:.3}s wall ({:.0} samples/s)",
        outcomes.len(),
        added as f64 / wall.max(1e-9)
    );
    println!("update latency: {}", coordinator.update_latency.summary());
    println!("counters: {}", coordinator.counters.render());

    // accuracy sanity on held-out data
    let test = synth::ecg_like(1000, dim, seed ^ 0xFEED);
    let pred = coordinator.handle().predict(&test.x)?;
    println!(
        "held-out accuracy: {:.2}%",
        100.0 * classification_accuracy(&pred, &test.y)
    );
    Ok(())
}

fn cmd_eval(m: &mikrr::cli::Matches) -> Result<(), Error> {
    let dataset = m.get("dataset").unwrap().to_string();
    let kernel = parse_kernel(m.get("kernel").unwrap())?;
    let train: usize = m.get_parse("train")?;
    let rounds: usize = m.get_parse("rounds")?;
    let seed: u64 = m.get_parse("seed")?;
    let space = if dataset == "drt" { Space::Empirical } else { Space::Intrinsic };

    let data = match dataset.as_str() {
        "ecg" => synth::ecg_like(train + rounds * 4 + 1000, 21, seed),
        "drt" => synth::drt_like(train + rounds * 4 + 160, 10_000, 0.01, seed),
        other => return Err(Error::Config(format!("unknown dataset {other:?}"))),
    };
    let strategies: Vec<Strategy> = if m.is_set("skip-none") {
        vec![Strategy::Multiple, Strategy::Single]
    } else {
        vec![Strategy::Multiple, Strategy::Single, Strategy::None]
    };
    let report = run_krr(&data, &kernel, 0.5, space, train, rounds, 4, 2, seed, &strategies)?;
    println!("{}", report.record.render_table(&format!("{dataset} / {kernel:?}")));
    println!("{}", report.record.render_curves("cumulative"));
    println!(
        "improvement (multiple vs single): {:.2}x ; accuracy {:.2}% ; strategies agree: {}",
        report.record.improvement_fold("multiple", "single"),
        100.0 * report.accuracy,
        report.strategies_agree
    );
    Ok(())
}

fn cmd_info() -> Result<(), Error> {
    println!("mikrr {}", mikrr::version());
    println!("threads: {}", mikrr::par::num_threads());
    match mikrr::runtime::artifact_dir() {
        Some(dir) => {
            println!("artifacts: {}", dir.display());
            match mikrr::runtime::PjrtRuntime::load_dir(&dir) {
                Ok(rt) => println!("  loaded+compiled: {:?}", rt.names()),
                Err(e) => println!("  load failed: {e}"),
            }
        }
        None => println!("artifacts: not found (run `make artifacts`)"),
    }
    Ok(())
}
