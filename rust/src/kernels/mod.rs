//! Kernel functions, blocked Gram computation, and the explicit
//! intrinsic-space feature map for polynomial kernels.
//!
//! This is the native (L3) twin of the L1 Pallas kernels in
//! `python/compile/kernels/` — same math, f64, verified against each other
//! through the runtime integration tests.

pub mod featmap;
pub mod gram;

pub use featmap::MonomialTable;
pub use gram::GramWork;

use crate::linalg::matrix::dot;
use crate::linalg::Mat;

/// A kernel function k(x, y).
#[derive(Clone, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = x.y
    Linear,
    /// k(x,y) = (x.y + coef0)^degree
    Poly {
        /// Polynomial degree (paper uses 2 and 3).
        degree: u32,
        /// Additive constant inside the power.
        coef0: f64,
    },
    /// k(x,y) = exp(-gamma ||x-y||^2); paper radius r=50 -> gamma=1/(2 r^2).
    Rbf {
        /// Bandwidth.
        gamma: f64,
    },
}

impl Kernel {
    /// Poly kernel constructor.
    pub fn poly(degree: u32, coef0: f64) -> Self {
        Kernel::Poly { degree, coef0 }
    }

    /// RBF from the paper's "radius" convention.
    pub fn rbf_radius(r: f64) -> Self {
        Kernel::Rbf { gamma: 1.0 / (2.0 * r * r) }
    }

    /// Parse "poly2", "poly3", "rbf", "linear".
    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "linear" => Some(Kernel::Linear),
            "poly2" => Some(Kernel::poly(2, 1.0)),
            "poly3" => Some(Kernel::poly(3, 1.0)),
            "rbf" => Some(Kernel::rbf_radius(50.0)),
            _ => None,
        }
    }

    /// Evaluate on two feature vectors.
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Linear => dot(x, y),
            Kernel::Poly { degree, coef0 } => (dot(x, y) + coef0).powi(degree as i32),
            Kernel::Rbf { gamma } => {
                let mut d2 = 0.0;
                for (a, b) in x.iter().zip(y) {
                    let d = a - b;
                    d2 += d * d;
                }
                (-gamma * d2).exp()
            }
        }
    }

    /// Intrinsic-space dimension J after feature mapping, if finite.
    /// RBF maps to an infinite-dimensional space — the reason the paper's
    /// intrinsic-space mode is "inapplicable to RBFs".
    pub fn intrinsic_dim(&self, m: usize) -> Option<usize> {
        match *self {
            Kernel::Linear => Some(m),
            Kernel::Poly { degree, .. } => Some(featmap::n_monomials(m, degree as usize)),
            Kernel::Rbf { .. } => None,
        }
    }

    /// Build the monomial table for the explicit feature map (poly/linear).
    pub fn feature_table(&self, m: usize) -> Option<MonomialTable> {
        match *self {
            Kernel::Linear => Some(MonomialTable::linear(m)),
            Kernel::Poly { degree, coef0 } => {
                Some(MonomialTable::new(m, degree as usize, coef0))
            }
            Kernel::Rbf { .. } => None,
        }
    }

    /// Full Gram matrix K[i,j] = k(x_i, y_j) for row-sample matrices.
    pub fn gram(&self, x: &Mat, y: &Mat) -> Mat {
        gram::gram(self, x, y)
    }

    /// Symmetric Gram K[i,j] = k(x_i, x_j).
    pub fn gram_symmetric(&self, x: &Mat) -> Mat {
        gram::gram_symmetric(self, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_matches_definitions() {
        let x = [1.0, 2.0];
        let y = [0.5, -1.0];
        assert_eq!(Kernel::Linear.eval(&x, &y), -1.5);
        assert_eq!(Kernel::poly(2, 1.0).eval(&x, &y), 0.25);
        let r = Kernel::rbf_radius(50.0);
        let d2 = 0.25 + 9.0;
        let want = (-d2 / 5000.0_f64).exp();
        assert!((r.eval(&x, &y) - want).abs() < 1e-12);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Kernel::parse("poly2"), Some(Kernel::poly(2, 1.0)));
        assert_eq!(Kernel::parse("rbf"), Some(Kernel::rbf_radius(50.0)));
        assert!(Kernel::parse("cubic").is_none());
    }

    #[test]
    fn intrinsic_dims() {
        // paper: M=21, poly2 -> 253; poly3 -> 2024
        assert_eq!(Kernel::poly(2, 1.0).intrinsic_dim(21), Some(253));
        assert_eq!(Kernel::poly(3, 1.0).intrinsic_dim(21), Some(2024));
        assert_eq!(Kernel::Linear.intrinsic_dim(5), Some(5));
        assert_eq!(Kernel::rbf_radius(50.0).intrinsic_dim(21), None);
    }
}
