//! Explicit intrinsic-space feature map phi for polynomial kernels.
//!
//! For k(x,y) = (x.y + c)^d the multinomial expansion gives
//! `phi_alpha(x) = sqrt(multinom(alpha) * c^(d-|alpha|)) * x^alpha` over all
//! multi-indices |alpha| <= d, so that phi(x).phi(y) == k(x,y) exactly.
//! J = C(M + d, d) — the paper's intrinsic dimension (M=21, d=2 -> 253).
//!
//! This is the L3 twin of `python/compile/kernels/feature_map.py`; the
//! monomial enumeration order matches (combinations-with-replacement by
//! ascending length) so AOT artifacts and native state are interchangeable.

use crate::linalg::Mat;
use crate::par;

/// Number of monomials of degree <= d over m variables: C(m + d, d).
pub fn n_monomials(m: usize, d: usize) -> usize {
    // compute binomial(m + d, d) in u128 to avoid overflow for large m
    let mut num: u128 = 1;
    let mut den: u128 = 1;
    for i in 0..d {
        num *= (m + d - i) as u128;
        den *= (i + 1) as u128;
    }
    (num / den) as usize
}

/// Precomputed monomial table: for each output feature j, the (<= d)
/// variable indices whose product forms the monomial, plus the sqrt
/// coefficient.
#[derive(Clone, Debug)]
pub struct MonomialTable {
    /// Input dimension M.
    pub m: usize,
    /// Kernel degree d.
    pub degree: usize,
    /// Monomials: variable index lists (non-decreasing), length <= degree.
    pub monos: Vec<Vec<u32>>,
    /// sqrt(multinomial * coef0^(d-k)) per monomial.
    pub coefs: Vec<f64>,
}

impl MonomialTable {
    /// Build for (x.y + coef0)^degree over m variables.
    pub fn new(m: usize, degree: usize, coef0: f64) -> Self {
        let mut monos: Vec<Vec<u32>> = Vec::with_capacity(n_monomials(m, degree));
        for k in 0..=degree {
            combinations_with_replacement(m, k, &mut monos);
        }
        let coefs = monos
            .iter()
            .map(|mono| {
                let k = mono.len();
                // multinomial = d! / ((d-k)! * prod(count_v!))
                let mut denom = factorial(degree - k);
                let mut run = 1usize;
                for w in 1..=mono.len() {
                    if w < mono.len() && mono[w] == mono[w - 1] {
                        run += 1;
                    } else {
                        denom *= factorial(run);
                        run = 1;
                    }
                }
                let multinom = factorial(degree) as f64 / denom as f64;
                (multinom * coef0.powi((degree - k) as i32)).sqrt()
            })
            .collect();
        Self { m, degree, monos, coefs }
    }

    /// Degenerate table for the linear kernel (identity map).
    pub fn linear(m: usize) -> Self {
        let monos = (0..m as u32).map(|v| vec![v]).collect();
        Self { m, degree: 1, monos, coefs: vec![1.0; m] }
    }

    /// Output dimension J.
    pub fn j(&self) -> usize {
        self.monos.len()
    }

    /// Map one sample into a caller-provided row buffer (len J).
    pub fn map_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.m);
        debug_assert_eq!(out.len(), self.j());
        for (o, (mono, &c)) in out.iter_mut().zip(self.monos.iter().zip(&self.coefs)) {
            let mut v = c;
            for &var in mono {
                v *= x[var as usize];
            }
            *o = v;
        }
    }

    /// Map a batch: X (B, M) -> Phi (B, J), parallel over rows.
    pub fn map(&self, x: &Mat) -> Mat {
        let mut out = Mat::default();
        self.map_into_mat(x, &mut out);
        out
    }

    /// [`MonomialTable::map`] written into a caller-provided matrix
    /// (reshaped as needed; allocation-free with warm capacity).
    pub fn map_into_mat(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.cols(), self.m, "featmap: input dim {} != {}", x.cols(), self.m);
        let b = x.rows();
        let j = self.j();
        out.resize_scratch(b, j);
        let optr = SendPtr(out.as_mut_slice().as_mut_ptr());
        par::parallel_for(b, 8, |lo, hi| {
            let p = optr;
            for r in lo..hi {
                // SAFETY: disjoint rows per chunk.
                let row = unsafe { std::slice::from_raw_parts_mut(p.0.add(r * j), j) };
                self.map_into(x.row(r), row);
            }
        });
    }
}

fn factorial(n: usize) -> usize {
    (1..=n).product::<usize>().max(1)
}

fn combinations_with_replacement(m: usize, k: usize, out: &mut Vec<Vec<u32>>) {
    if k == 0 {
        out.push(Vec::new());
        return;
    }
    let mut cur = vec![0u32; k];
    loop {
        out.push(cur.clone());
        // advance: find rightmost position that can be incremented
        let mut pos = k;
        while pos > 0 {
            pos -= 1;
            if (cur[pos] as usize) < m - 1 {
                cur[pos] += 1;
                let v = cur[pos];
                for p in pos + 1..k {
                    cur[p] = v;
                }
                break;
            }
            if pos == 0 {
                return;
            }
        }
        if m == 1 {
            return; // only one monomial per k when m == 1
        }
    }
}

struct SendPtr(*mut f64);
impl Clone for SendPtr {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl Copy for SendPtr {}
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::linalg::matrix::dot;
    use crate::util::prng::Rng;

    #[test]
    fn counts_match_formula() {
        assert_eq!(n_monomials(21, 2), 253);
        assert_eq!(n_monomials(21, 3), 2024);
        assert_eq!(n_monomials(1, 3), 4); // 1, x, x^2, x^3
        assert_eq!(n_monomials(3, 0), 1);
        let t = MonomialTable::new(21, 2, 1.0);
        assert_eq!(t.j(), 253);
        let t3 = MonomialTable::new(4, 3, 1.0);
        assert_eq!(t3.j(), n_monomials(4, 3));
    }

    #[test]
    fn defining_identity_phi_dot_phi_is_kernel() {
        // phi(x).phi(y) == (x.y + c)^d for random data, several (m, d, c)
        let mut rng = Rng::new(1);
        let cases = [(1usize, 2usize, 1.0f64), (3, 2, 1.0), (5, 3, 1.0), (4, 2, 2.0), (6, 1, 0.5)];
        for &(m, d, c) in &cases {
            let t = MonomialTable::new(m, d, c);
            let x: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let y: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let mut px = vec![0.0; t.j()];
            let mut py = vec![0.0; t.j()];
            t.map_into(&x, &mut px);
            t.map_into(&y, &mut py);
            let got = dot(&px, &py);
            let want = (dot(&x, &y) + c).powi(d as i32);
            assert!(
                (got - want).abs() < 1e-10 * want.abs().max(1.0),
                "m={m} d={d} c={c}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn batch_map_matches_single() {
        let mut rng = Rng::new(2);
        let t = MonomialTable::new(7, 2, 1.0);
        let x = Mat::from_fn(33, 7, |_, _| rng.gaussian());
        let phi = t.map(&x);
        assert_eq!(phi.shape(), (33, t.j()));
        let mut row = vec![0.0; t.j()];
        for r in [0usize, 13, 32] {
            t.map_into(x.row(r), &mut row);
            assert_eq!(phi.row(r), &row[..]);
        }
    }

    #[test]
    fn linear_table_is_identity() {
        let t = MonomialTable::linear(4);
        let mut out = vec![0.0; 4];
        t.map_into(&[1.0, 2.0, 3.0, 4.0], &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn matches_kernel_enum_dims() {
        for m in [1usize, 2, 8, 21] {
            for d in [1u32, 2, 3] {
                let k = Kernel::poly(d, 1.0);
                let t = k.feature_table(m).unwrap();
                assert_eq!(Some(t.j()), k.intrinsic_dim(m));
            }
        }
    }

    #[test]
    fn monomials_nondecreasing_and_unique() {
        let t = MonomialTable::new(5, 3, 1.0);
        let mut seen = std::collections::HashSet::new();
        for mono in &t.monos {
            assert!(mono.windows(2).all(|w| w[0] <= w[1]));
            assert!(seen.insert(mono.clone()));
        }
        assert_eq!(seen.len(), n_monomials(5, 3));
    }
}
