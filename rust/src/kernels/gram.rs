//! Blocked, parallel Gram-matrix computation (native twin of the L1 Pallas
//! gram kernels).
//!
//! Poly/linear kernels go through the GEMM path (`X Y^T` then the scalar
//! map), RBF through the expanded-norm identity; both tile over output
//! blocks and parallelize over rows, mirroring the BlockSpec schedule of
//! `python/compile/kernels/gram.py`. The cross-Gram `X Y^T` rides the
//! shape-adaptive dispatch in [`crate::linalg::gemm::dispatch`]: typical
//! sensor blocks (feature dim M ≤ a few dozen) stream on the row-dot
//! kernel, while wide-feature datasets (M past the crossover) pack and run
//! the 4×8 micro-kernel — no tuning at this call site.
//!
//! The **symmetric** path (`K(X, X)`) routes through
//! [`crate::linalg::gemm::syrk_into`]: the inner products cost half the
//! flops of the general product, and for RBF the transcendental map runs on
//! the lower triangle only (halving the `exp` calls) before mirroring. The
//! expanded norm `‖x‖² + ‖y‖² − 2xᵀy` is clamped at zero before `exp` on
//! both paths: cancellation can push the squared distance of near-duplicate
//! points a hair negative, which would otherwise inflate `exp` above 1.

use crate::kernels::Kernel;
use crate::linalg::gemm::{matmul_nt_into, syrk_into};
use crate::linalg::matrix::dot;
use crate::linalg::Mat;
use crate::par;

/// Reusable scratch for [`gram_into`] (the RBF path's row norms), so the
/// engines' steady-state Gram construction allocates nothing.
#[derive(Clone, Default)]
pub struct GramWork {
    xn: Vec<f64>,
    yn: Vec<f64>,
}

/// K[i,j] = k(x_i, y_j); x: (N, M), y: (P, M) -> (N, P).
pub fn gram(kernel: &Kernel, x: &Mat, y: &Mat) -> Mat {
    let mut out = Mat::default();
    gram_into(kernel, x, y, &mut out, &mut GramWork::default());
    out
}

/// [`gram`] written into a caller-provided matrix, drawing auxiliary
/// buffers from `work` (allocation-free once both are warm).
pub fn gram_into(kernel: &Kernel, x: &Mat, y: &Mat, out: &mut Mat, work: &mut GramWork) {
    assert_eq!(x.cols(), y.cols(), "gram: feature dims differ");
    matmul_nt_into(x, y, out).expect("shapes checked");
    match *kernel {
        Kernel::Linear => {}
        Kernel::Poly { degree, coef0 } => {
            let d = degree as i32;
            for v in out.as_mut_slice() {
                *v = (*v + coef0).powi(d);
            }
        }
        Kernel::Rbf { gamma } => {
            work.xn.clear();
            work.xn.extend((0..x.rows()).map(|i| dot(x.row(i), x.row(i))));
            work.yn.clear();
            work.yn.extend((0..y.rows()).map(|i| dot(y.row(i), y.row(i))));
            let p = y.rows();
            let (xn, yn) = (&work.xn, &work.yn);
            let kptr = SendPtr(out.as_mut_slice().as_mut_ptr());
            par::parallel_for(x.rows(), 32, |lo, hi| {
                let ptr = kptr;
                for i in lo..hi {
                    // SAFETY: disjoint rows per chunk.
                    let row =
                        unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * p), p) };
                    for (j, v) in row.iter_mut().enumerate() {
                        // clamp: cancellation can drive the expanded norm of
                        // near-duplicate points a hair negative
                        let d2 = (xn[i] + yn[j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            });
        }
    }
}

/// Symmetric Gram K(x, x) via the SYRK path: half the inner-product flops,
/// and (for RBF) half the `exp` calls of the general route.
pub fn gram_symmetric(kernel: &Kernel, x: &Mat) -> Mat {
    let mut k = Mat::default();
    gram_symmetric_into(kernel, x, &mut k, &mut GramWork::default());
    k
}

/// [`gram_symmetric`] written into a caller-provided matrix. The result is
/// exactly symmetric by construction (the lower triangle is computed once
/// and mirrored), so no `symmetrize` drift-control pass is needed.
pub fn gram_symmetric_into(kernel: &Kernel, x: &Mat, out: &mut Mat, work: &mut GramWork) {
    let n = x.rows();
    // X X^T at half the flops; exactly symmetric on return
    syrk_into(1.0, x, 0.0, out).expect("fresh square output");
    match *kernel {
        Kernel::Linear => {}
        Kernel::Poly { degree, coef0 } => {
            // the scalar map is cheap — apply to the full (symmetric)
            // matrix; equal inputs give bitwise-equal outputs
            let d = degree as i32;
            for v in out.as_mut_slice() {
                *v = (*v + coef0).powi(d);
            }
        }
        Kernel::Rbf { gamma } => {
            // row norms are the diagonal of X X^T — copy them out before
            // the map overwrites the diagonal
            work.xn.clear();
            work.xn.extend((0..n).map(|i| out[(i, i)]));
            let xn = &work.xn;
            let kptr = SendPtr(out.as_mut_slice().as_mut_ptr());
            // transcendental map on the lower triangle only
            par::parallel_for(n, 32, |lo, hi| {
                let ptr = kptr;
                for i in lo..hi {
                    // SAFETY: disjoint rows per chunk.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(ptr.0.add(i * n), i + 1)
                    };
                    let xni = xn[i];
                    for (j, v) in row.iter_mut().enumerate() {
                        // same clamp as the general path (see module docs);
                        // on the diagonal the identity is exact: d2 = 0
                        let d2 = (xni + xn[j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            });
            // mirror lower -> upper (writes strict upper, reads strict
            // lower produced by the completed pass above)
            par::parallel_for(n, 256, |lo, hi| {
                let ptr = kptr;
                for i in lo..hi {
                    for j in i + 1..n {
                        // SAFETY: disjoint (i, j>i) writes per chunk.
                        unsafe { *ptr.0.add(i * n + j) = *ptr.0.add(j * n + i) };
                    }
                }
            });
        }
    }
}

/// Cross-kernel row: k(x_query, each row of X) — the prediction hot path.
pub fn gram_row(kernel: &Kernel, x_train: &Mat, q: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), x_train.rows());
    for (o, i) in out.iter_mut().zip(0..x_train.rows()) {
        *o = kernel.eval(q, x_train.row(i));
    }
}

struct SendPtr(*mut f64);
impl Clone for SendPtr {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl Copy for SendPtr {}
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_nt;
    use crate::util::prng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    #[test]
    fn gram_matches_pointwise_eval() {
        let x = randm(23, 7, 1);
        let y = randm(17, 7, 2);
        for kernel in [
            Kernel::Linear,
            Kernel::poly(2, 1.0),
            Kernel::poly(3, 1.0),
            Kernel::rbf_radius(2.0),
        ] {
            let k = gram(&kernel, &x, &y);
            assert_eq!(k.shape(), (23, 17));
            for i in [0usize, 9, 22] {
                for j in [0usize, 8, 16] {
                    let want = kernel.eval(x.row(i), y.row(j));
                    assert!(
                        (k[(i, j)] - want).abs() < 1e-10,
                        "{kernel:?} ({i},{j}): {} vs {want}",
                        k[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn gram_symmetric_matches_pointwise_eval() {
        // the SYRK route against the defining formula, every kernel
        let x = randm(21, 6, 11);
        for kernel in [
            Kernel::Linear,
            Kernel::poly(2, 1.0),
            Kernel::poly(3, 1.0),
            Kernel::rbf_radius(2.0),
        ] {
            let k = gram_symmetric(&kernel, &x);
            assert_eq!(k.shape(), (21, 21));
            for i in 0..21 {
                for j in 0..21 {
                    let want = kernel.eval(x.row(i), x.row(j));
                    assert!(
                        (k[(i, j)] - want).abs() < 1e-10,
                        "{kernel:?} ({i},{j}): {} vs {want}",
                        k[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_gram_is_symmetric_unit_diag_rbf() {
        let x = randm(19, 5, 3);
        let k = gram_symmetric(&Kernel::rbf_radius(1.0), &x);
        assert!(k.max_abs_diff(&k.transpose()) < 1e-14);
        for i in 0..19 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rbf_near_duplicates_clamped_to_valid_range() {
        // rows with large norms that are (near-)duplicates: the expanded
        // norm ‖x‖²+‖y‖²−2xᵀy cancels catastrophically and can come out a
        // hair negative, which without the clamp gives exp(+ε) > 1
        let m = 9;
        let mut x = Mat::from_fn(12, m, |r, c| 1.0e6 * ((r * m + c) as f64).sin());
        // row 1 = exact duplicate of row 0; row 2 = near-duplicate
        for c in 0..m {
            x[(1, c)] = x[(0, c)];
            x[(2, c)] = x[(0, c)] + 1e-8;
        }
        for kernel in [Kernel::rbf_radius(2.0), Kernel::rbf_radius(50.0)] {
            let ks = gram_symmetric(&kernel, &x);
            let kg = gram(&kernel, &x, &x);
            for k in [&ks, &kg] {
                assert!(k.is_finite(), "{kernel:?}: non-finite entries");
                for i in 0..12 {
                    for j in 0..12 {
                        assert!(
                            k[(i, j)] <= 1.0 && k[(i, j)] >= 0.0,
                            "{kernel:?} ({i},{j}) = {} out of (0, 1]",
                            k[(i, j)]
                        );
                    }
                }
                // exact duplicate: kernel value exactly 1 under the clamp
                assert_eq!(k[(0, 1)], 1.0, "{kernel:?} duplicate rows");
                assert_eq!(k[(1, 0)], 1.0, "{kernel:?} duplicate rows");
            }
            // near-duplicate: the true kernel value is ~1; the expanded
            // norm carries ~1e-4 absolute cancellation noise at these
            // magnitudes, but the clamp guarantees it stays a valid kernel
            // value just below 1 instead of exp(+noise) > 1
            for k in [&ks, &kg] {
                assert!(k[(0, 2)] > 0.99, "{kernel:?}: {}", k[(0, 2)]);
                assert!(k[(1, 2)] > 0.99, "{kernel:?}: {}", k[(1, 2)]);
            }
        }
    }

    #[test]
    fn gram_row_matches_gram() {
        let x = randm(11, 4, 4);
        let q = randm(1, 4, 5);
        let kernel = Kernel::poly(2, 1.0);
        let full = gram(&kernel, &q, &x);
        let mut row = vec![0.0; 11];
        gram_row(&kernel, &x, q.row(0), &mut row);
        for j in 0..11 {
            assert!((full[(0, j)] - row[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_agrees_with_feature_map() {
        // K = Phi Phi^T via the monomial table — the defining identity again
        // but at matrix level, both code paths.
        let x = randm(9, 3, 6);
        let kernel = Kernel::poly(2, 1.0);
        let k = gram_symmetric(&kernel, &x);
        let t = kernel.feature_table(3).unwrap();
        let phi = t.map(&x);
        let k2 = matmul_nt(&phi, &phi).unwrap();
        assert!(k.max_abs_diff(&k2) < 1e-9);
    }
}
