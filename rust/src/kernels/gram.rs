//! Blocked, parallel Gram-matrix computation (native twin of the L1 Pallas
//! gram kernels).
//!
//! Poly/linear kernels go through the GEMM path (`X Y^T` then the scalar
//! map), RBF through the expanded-norm identity; both tile over output
//! blocks and parallelize over rows, mirroring the BlockSpec schedule of
//! `python/compile/kernels/gram.py`.

use crate::kernels::Kernel;
use crate::linalg::gemm::matmul_nt_into;
use crate::linalg::matrix::dot;
use crate::linalg::Mat;
use crate::par;

/// Reusable scratch for [`gram_into`] (the RBF path's row norms), so the
/// engines' steady-state Gram construction allocates nothing.
#[derive(Clone, Default)]
pub struct GramWork {
    xn: Vec<f64>,
    yn: Vec<f64>,
}

/// K[i,j] = k(x_i, y_j); x: (N, M), y: (P, M) -> (N, P).
pub fn gram(kernel: &Kernel, x: &Mat, y: &Mat) -> Mat {
    let mut out = Mat::default();
    gram_into(kernel, x, y, &mut out, &mut GramWork::default());
    out
}

/// [`gram`] written into a caller-provided matrix, drawing auxiliary
/// buffers from `work` (allocation-free once both are warm).
pub fn gram_into(kernel: &Kernel, x: &Mat, y: &Mat, out: &mut Mat, work: &mut GramWork) {
    assert_eq!(x.cols(), y.cols(), "gram: feature dims differ");
    matmul_nt_into(x, y, out).expect("shapes checked");
    match *kernel {
        Kernel::Linear => {}
        Kernel::Poly { degree, coef0 } => {
            let d = degree as i32;
            for v in out.as_mut_slice() {
                *v = (*v + coef0).powi(d);
            }
        }
        Kernel::Rbf { gamma } => {
            work.xn.clear();
            work.xn.extend((0..x.rows()).map(|i| dot(x.row(i), x.row(i))));
            work.yn.clear();
            work.yn.extend((0..y.rows()).map(|i| dot(y.row(i), y.row(i))));
            let p = y.rows();
            let (xn, yn) = (&work.xn, &work.yn);
            let kptr = SendPtr(out.as_mut_slice().as_mut_ptr());
            par::parallel_for(x.rows(), 32, |lo, hi| {
                let ptr = kptr;
                for i in lo..hi {
                    // SAFETY: disjoint rows per chunk.
                    let row =
                        unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * p), p) };
                    for (j, v) in row.iter_mut().enumerate() {
                        let d2 = (xn[i] + yn[j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            });
        }
    }
}

/// Symmetric Gram K(x, x), exploiting symmetry for the scalar map.
pub fn gram_symmetric(kernel: &Kernel, x: &Mat) -> Mat {
    let mut k = gram(kernel, x, x);
    k.symmetrize();
    k
}

/// [`gram_symmetric`] written into a caller-provided matrix.
pub fn gram_symmetric_into(kernel: &Kernel, x: &Mat, out: &mut Mat, work: &mut GramWork) {
    gram_into(kernel, x, x, out, work);
    out.symmetrize();
}

/// Cross-kernel row: k(x_query, each row of X) — the prediction hot path.
pub fn gram_row(kernel: &Kernel, x_train: &Mat, q: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), x_train.rows());
    for (o, i) in out.iter_mut().zip(0..x_train.rows()) {
        *o = kernel.eval(q, x_train.row(i));
    }
}

struct SendPtr(*mut f64);
impl Clone for SendPtr {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl Copy for SendPtr {}
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_nt;
    use crate::util::prng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    #[test]
    fn gram_matches_pointwise_eval() {
        let x = randm(23, 7, 1);
        let y = randm(17, 7, 2);
        for kernel in [Kernel::Linear, Kernel::poly(2, 1.0), Kernel::poly(3, 1.0), Kernel::rbf_radius(2.0)] {
            let k = gram(&kernel, &x, &y);
            assert_eq!(k.shape(), (23, 17));
            for i in [0usize, 9, 22] {
                for j in [0usize, 8, 16] {
                    let want = kernel.eval(x.row(i), y.row(j));
                    assert!(
                        (k[(i, j)] - want).abs() < 1e-10,
                        "{kernel:?} ({i},{j}): {} vs {want}",
                        k[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_gram_is_symmetric_unit_diag_rbf() {
        let x = randm(19, 5, 3);
        let k = gram_symmetric(&Kernel::rbf_radius(1.0), &x);
        assert!(k.max_abs_diff(&k.transpose()) < 1e-14);
        for i in 0..19 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_row_matches_gram() {
        let x = randm(11, 4, 4);
        let q = randm(1, 4, 5);
        let kernel = Kernel::poly(2, 1.0);
        let full = gram(&kernel, &q, &x);
        let mut row = vec![0.0; 11];
        gram_row(&kernel, &x, q.row(0), &mut row);
        for j in 0..11 {
            assert!((full[(0, j)] - row[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_agrees_with_feature_map() {
        // K = Phi Phi^T via the monomial table — the defining identity again
        // but at matrix level, both code paths.
        let x = randm(9, 3, 6);
        let kernel = Kernel::poly(2, 1.0);
        let k = gram_symmetric(&kernel, &x);
        let t = kernel.feature_table(3).unwrap();
        let phi = t.map(&x);
        let k2 = matmul_nt(&phi, &phi).unwrap();
        assert!(k.max_abs_diff(&k2) < 1e-9);
    }
}
