//! Kernelized Bayesian Regression with incremental/decremental uncertainty
//! updates (paper Section IV).
//!
//! Model: `y_i = u^T phi(x_i) + b_i` with Gaussian prior
//! `u ~ N(0, sigma_u^2 I)` and homoscedastic noise `b_i ~ N(0, sigma_b^2)`.
//! The posterior (eq. 41-42) is Gaussian with
//!
//! ```text
//! Sigma_{u|y,Phi} = (I/sigma_u^2 + Phi Phi^T / sigma_b^2)^-1
//! mu_{u|y,Phi}    = Sigma_{u|y,Phi} (Phi y^T) / sigma_b^2
//! ```
//!
//! Adding |C| / removing |R| samples shifts the posterior *precision* by
//! `sigma_b^-2 Phi_H Phi_H'`, so the covariance updates with the same
//! batched Woodbury rule as KRR (eq. 43) and the mean refreshes from the
//! maintained `Phi y^T` running sum (eq. 44).  The predictive distribution
//! (eq. 45-50) gives calibrated uncertainty:
//!
//! ```text
//! mu*  = phi(x*)^T mu          psi* = sigma_b^2 + phi(x*)^T Sigma phi(x*)
//! ```
//!
//! With these settings KBR is a finite-feature Gaussian process; the
//! [`KbrModel::log_marginal_likelihood`] hook exposes the GP evidence for
//! hyperparameter sanity checks (an extension beyond the paper).

use crate::error::{Error, Result};
use crate::kernels::{Kernel, MonomialTable};
use crate::linalg::gemm::{gemv, gemv_into};
use crate::linalg::matrix::{axpy_slice, dot};
use crate::linalg::solve::{spd_inverse, spd_logdet};
use crate::linalg::woodbury::{incdec_into, IncDecWork};
use crate::linalg::Mat;
use crate::ensure_shape;

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state posterior update performs zero
/// heap allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct KbrWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Mapped insertion block Φ_C (C, J).
    phi_c: Mat,
    /// Scaled update columns Φ_H / σ_b (J, C + R).
    phi_h: Mat,
    /// Column signs (+1 insert / −1 remove).
    signs: Vec<f64>,
    /// Woodbury scratch.
    incdec: IncDecWork,
}

/// Prior/noise hyperparameters (paper §V: both 0.01).
#[derive(Clone, Copy, Debug)]
pub struct KbrHyper {
    /// Prior weight variance sigma_u^2.
    pub sigma_u2: f64,
    /// Observation noise variance sigma_b^2.
    pub sigma_b2: f64,
}

impl Default for KbrHyper {
    fn default() -> Self {
        Self { sigma_u2: 0.01, sigma_b2: 0.01 }
    }
}

/// A Gaussian predictive distribution per query point.
#[derive(Clone, Debug)]
pub struct Predictive {
    /// Posterior predictive means mu*.
    pub mean: Vec<f64>,
    /// Posterior predictive variances psi* (includes noise sigma_b^2).
    pub var: Vec<f64>,
}

impl Predictive {
    /// Central credible interval half-widths at ~95% (1.96 sigma).
    pub fn interval95(&self) -> Vec<(f64, f64)> {
        self.mean
            .iter()
            .zip(&self.var)
            .map(|(m, v)| {
                let hw = 1.96 * v.max(0.0).sqrt();
                (m - hw, m + hw)
            })
            .collect()
    }
}

/// Caller-owned workspace for [`KbrModel::predict_into`]: the mapped query
/// block and the Σ Φ*ᵀ product, kept warm so steady-state uncertainty
/// serving performs zero heap allocations (measured in
/// `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct KbrPredictWork {
    /// Mapped query features Φ* (B, J).
    phi_star: Mat,
    /// Σ Φ*ᵀ (J, B) — the batched covariance product.
    sc: Mat,
}

/// Incremental Kernelized Bayesian Regression engine (intrinsic space).
#[derive(Clone)]
pub struct KbrModel {
    kernel: Kernel,
    table: MonomialTable,
    hyper: KbrHyper,
    /// Posterior covariance Sigma_{u|y,Phi} (J, J).
    cov: Mat,
    /// Posterior mean mu_{u|y,Phi} (J,).
    mean: Vec<f64>,
    /// Mapped training features (N, J) — needed for decremental columns.
    phi: Mat,
    /// Targets.
    y: Vec<f64>,
    /// Running Phi^T y (J,).
    py: Vec<f64>,
    work: KbrWork,
}

impl KbrModel {
    /// Fit the batch posterior from scratch (eq. 41-42): O(N J^2 + J^3).
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, hyper: KbrHyper) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.len(),
            "KbrModel::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.len()
        );
        if hyper.sigma_u2 <= 0.0 || hyper.sigma_b2 <= 0.0 {
            return Err(Error::Config("KBR variances must be > 0".into()));
        }
        let table = kernel.feature_table(x.cols()).ok_or_else(|| {
            Error::Config(format!(
                "kernel {kernel:?} has infinite intrinsic dimension; KBR here \
                 operates in intrinsic space (paper §IV)"
            ))
        })?;
        let phi = table.map(x); // (N, J)
        let j = table.j();
        // precision = I/sigma_u^2 + Phi^T Phi / sigma_b^2 — transpose-side
        // SYRK straight off the row-major store (half the flops, no
        // materialized Phi^T; the noise scale folds into alpha)
        let mut prec = Mat::default();
        crate::linalg::gemm::syrk_t_into(1.0 / hyper.sigma_b2, &phi, 0.0, &mut prec)?;
        prec.add_diag(1.0 / hyper.sigma_u2)?;
        let cov = spd_inverse(&prec)?;
        let mut py = vec![0.0; j];
        for (r, &yr) in y.iter().enumerate() {
            axpy_slice(yr, phi.row(r), &mut py);
        }
        let mean = {
            let mut v = gemv(&cov, &py)?;
            for m in &mut v {
                *m /= hyper.sigma_b2;
            }
            v
        };
        Ok(Self {
            kernel: kernel.clone(),
            table,
            hyper,
            cov,
            mean,
            phi,
            y: y.to_vec(),
            py,
            work: KbrWork::default(),
        })
    }

    /// One batched incremental/decremental posterior update (eq. 43-44).
    /// Steady state performs zero heap allocations: the scaled Φ_H, signs
    /// and Woodbury scratch live in the per-model workspace, the covariance
    /// update is in place, and the stores edit inside reserved capacity.
    pub fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.len(),
            "KbrModel::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.len()
        );
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.len() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.len()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        let j = self.table.j();
        self.table.map_into_mat(x_new, &mut self.work.phi_c); // (C, J)
        // Phi_H scaled by 1/sigma_b so the precision shift matches eq. 43
        let inv_sb = 1.0 / self.hyper.sigma_b2.sqrt();
        self.work.phi_h.resize_scratch(j, c + r);
        for row in 0..c {
            for jj in 0..j {
                self.work.phi_h[(jj, row)] = self.work.phi_c[(row, jj)] * inv_sb;
            }
        }
        for col in 0..r {
            let ri = self.work.rem[col];
            for jj in 0..j {
                self.work.phi_h[(jj, c + col)] = self.phi[(ri, jj)] * inv_sb;
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, c));
        self.work.signs.extend(std::iter::repeat_n(-1.0, r));
        incdec_into(
            &mut self.cov,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        // maintain Phi^T y and the stores
        for row in 0..c {
            axpy_slice(y_new[row], self.work.phi_c.row(row), &mut self.py);
        }
        for &ri in &self.work.rem {
            axpy_slice(-self.y[ri], self.phi.row(ri), &mut self.py);
        }
        self.phi.drop_rows_sorted(&self.work.rem)?;
        for (i, &ri) in self.work.rem.iter().enumerate() {
            self.y.remove(ri - i);
        }
        for row in 0..c {
            self.phi.push_row(self.work.phi_c.row(row))?;
            self.y.push(y_new[row]);
        }
        // mean refresh (eq. 44)
        gemv_into(&self.cov, &self.py, &mut self.mean)?;
        for m in &mut self.mean {
            *m /= self.hyper.sigma_b2;
        }
        Ok(())
    }

    /// Posterior predictive distribution for a block of raw feature rows
    /// (eq. 45-50).
    pub fn predict(&self, x: &Mat) -> Result<Predictive> {
        let mut mean = Vec::new();
        let mut var = Vec::new();
        self.predict_into(x, &mut mean, &mut var, &mut KbrPredictWork::default())?;
        Ok(Predictive { mean, var })
    }

    /// [`KbrModel::predict`] written into caller-provided buffers, drawing
    /// every intermediate from `work` — allocation-free once warm. The
    /// variance column `Σ Φ*ᵀ` is built as ONE batched product over the
    /// whole micro-batch (a packed GEMM above the dispatch crossover)
    /// instead of B per-request covariance GEMVs, which is where the
    /// serving layer's BLAS-3 win lives.
    pub fn predict_into(
        &self,
        x: &Mat,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        work: &mut KbrPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.table.m,
            "KbrModel::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        gemv_into(&work.phi_star, &self.mean, mean)?;
        // psi* = sigma_b^2 + diag(Phi* Sigma Phi*^T)
        crate::linalg::gemm::matmul_nt_into(&self.cov, &work.phi_star, &mut work.sc)?; // (J, B)
        let b = work.phi_star.rows();
        debug_assert_eq!(work.sc.rows(), work.phi_star.cols());
        let sc = work.sc.as_slice();
        var.clear();
        for r in 0..b {
            // Φ* row r (contiguous) · Σ Φ*ᵀ column r (stride B) — no
            // materialized column copy
            let mut q = 0.0;
            for (jj, &p) in work.phi_star.row(r).iter().enumerate() {
                q += p * sc[jj * b + r];
            }
            var.push(self.hyper.sigma_b2 + q.max(0.0));
        }
        Ok(())
    }

    /// GP log marginal likelihood log p(y | Phi) for the current training
    /// set (extension: evidence for hyperparameter checking).
    pub fn log_marginal_likelihood(&self) -> Result<f64> {
        // p(y|Phi) = N(0, sigma_u^2 Phi^T Phi + sigma_b^2 I)  (N-dim)
        let n = self.y.len();
        // Phi Phi^T is symmetric: SYRK route, half the flops of the
        // general product
        let k = crate::linalg::gemm::syrk(&self.phi)?; // (N,N)
        let mut c = k;
        c.scale(self.hyper.sigma_u2);
        c.add_diag(self.hyper.sigma_b2)?;
        let ld = spd_logdet(&c)?;
        let alpha = crate::linalg::solve::solve_spd(&c, &self.y)?;
        let quad = dot(&self.y, &alpha);
        Ok(-0.5 * (quad + ld + n as f64 * (2.0 * std::f64::consts::PI).ln()))
    }

    /// Posterior mean vector (J,).
    pub fn posterior_mean(&self) -> &[f64] {
        &self.mean
    }

    /// Posterior covariance (J, J).
    pub fn posterior_cov(&self) -> &Mat {
        &self.cov
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Hyperparameters.
    pub fn hyper(&self) -> KbrHyper {
        self.hyper
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_mat_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.1 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn incremental_equals_batch_posterior() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(30, 4, 1);
        let (xc, yc) = data(4, 4, 2);
        let mut inc = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        inc.inc_dec(&xc, &yc, &[3, 9]).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[3, 9]).unwrap();
        y2.remove(9);
        y2.remove(3);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let batch = KbrModel::fit(&x2, &y2, &kernel, KbrHyper::default()).unwrap();

        assert_vec_close(inc.posterior_mean(), batch.posterior_mean(), 1e-6);
        assert_mat_close(inc.posterior_cov(), batch.posterior_cov(), 1e-6);
    }

    #[test]
    fn predictive_variance_positive_and_shrinking() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(40, 3, 3);
        let (xt, _) = data(6, 3, 4);
        let small = KbrModel::fit(
            &x.block(0, 8, 0, 3),
            &y[..8],
            &kernel,
            KbrHyper::default(),
        )
        .unwrap();
        let big = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let ps = small.predict(&xt).unwrap();
        let pb = big.predict(&xt).unwrap();
        for (vs, vb) in ps.var.iter().zip(&pb.var) {
            assert!(*vb > 0.0);
            assert!(*vb <= vs + 1e-9, "variance must not grow with data");
            assert!(*vb >= KbrHyper::default().sigma_b2 - 1e-12);
        }
    }

    #[test]
    fn posterior_mean_tracks_krr_limit() {
        // with sigma_u^2 = sigma_b^2 / rho, KBR posterior mean == KRR
        // solution without bias; sanity: predictions close to KRR's
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(50, 3, 5);
        let (xt, _) = data(8, 3, 6);
        let hyper = KbrHyper { sigma_u2: 0.02, sigma_b2: 0.01 }; // rho = 0.5
        let kbr = KbrModel::fit(&x, &y, &kernel, hyper).unwrap();
        let pm = kbr.predict(&xt).unwrap();
        // reference: intrinsic ridge solve without bias term
        let table = kernel.feature_table(3).unwrap();
        let phi = table.map(&x);
        let phit = phi.transpose();
        let mut s = crate::linalg::gemm::syrk(&phit).unwrap();
        s.add_diag(0.5).unwrap();
        let mut py = vec![0.0; table.j()];
        for (r, &yr) in y.iter().enumerate() {
            axpy_slice(yr, phi.row(r), &mut py);
        }
        let u = crate::linalg::solve::solve_spd(&s, &py).unwrap();
        let phit_star = table.map(&xt);
        let want = gemv(&phit_star, &u).unwrap();
        assert_vec_close(&pm.mean, &want, 1e-6);
    }

    #[test]
    fn interval95_brackets_mean() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(20, 3, 7);
        let kbr = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let p = kbr.predict(&x.block(0, 5, 0, 3)).unwrap();
        for ((lo, hi), m) in p.interval95().iter().zip(&p.mean) {
            assert!(lo < m && m < hi);
        }
    }

    #[test]
    fn evidence_is_finite_and_improves_with_fit() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(15, 3, 8);
        let kbr = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let lml = kbr.log_marginal_likelihood().unwrap();
        assert!(lml.is_finite());
        // garbage targets must have lower evidence
        let mut rng = Rng::new(9);
        let y_bad: Vec<f64> = (0..15).map(|_| 10.0 * rng.gaussian()).collect();
        let kbr_bad = KbrModel::fit(&x, &y_bad, &kernel, KbrHyper::default()).unwrap();
        assert!(kbr_bad.log_marginal_likelihood().unwrap() < lml);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(10, 3, 10);
        assert!(KbrModel::fit(&x, &y, &Kernel::rbf_radius(50.0), KbrHyper::default()).is_err());
        let bad = KbrHyper { sigma_u2: 0.0, sigma_b2: 0.01 };
        assert!(KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), bad).is_err());
        let mut m = KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), KbrHyper::default()).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[10]).is_err());
    }
}
