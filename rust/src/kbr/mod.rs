//! Kernelized Bayesian Regression with incremental/decremental uncertainty
//! updates (paper Section IV).
//!
//! Model: `y_i = u^T phi(x_i) + b_i` with Gaussian prior
//! `u ~ N(0, sigma_u^2 I)` and homoscedastic noise `b_i ~ N(0, sigma_b^2)`.
//! The posterior (eq. 41-42) is Gaussian with
//!
//! ```text
//! Sigma_{u|y,Phi} = (I/sigma_u^2 + Phi Phi^T / sigma_b^2)^-1
//! mu_{u|y,Phi}    = Sigma_{u|y,Phi} (Phi y^T) / sigma_b^2
//! ```
//!
//! Adding |C| / removing |R| samples shifts the posterior *precision* by
//! `sigma_b^-2 Phi_H Phi_H'`, so the covariance updates with the same
//! batched Woodbury rule as KRR (eq. 43) and the mean refreshes from the
//! maintained `Phi y^T` running sum (eq. 44).  The posterior precision is
//! independent of the targets, so all `D` output columns share the ONE
//! maintained covariance: the mean becomes a `(J, D)` matrix refreshed by
//! a single GEMM, and the per-query predictive variance is shared across
//! outputs.  Duplicate-fold multiplicities enter the precision the same
//! way repeated rows would (`c_i` copies of `φ_i φ_iᵀ / σ_b²`), so a fold
//! is one rank-1 precision increment.  The predictive distribution
//! (eq. 45-50) gives calibrated uncertainty:
//!
//! ```text
//! mu*  = phi(x*)^T mu          psi* = sigma_b^2 + phi(x*)^T Sigma phi(x*)
//! ```
//!
//! With these settings KBR is a finite-feature Gaussian process; the
//! [`KbrModel::log_marginal_likelihood`] hook exposes the GP evidence for
//! hyperparameter sanity checks (an extension beyond the paper).

use crate::error::{Error, Result};
use crate::kernels::{Kernel, MonomialTable};
use crate::linalg::gemm::{gemv_into, ger, matmul_into};
use crate::linalg::matrix::{axpy_slice, dot};
use crate::linalg::solve::{spd_inverse, spd_logdet};
use crate::linalg::woodbury::{incdec_into, IncDecWork};
use crate::linalg::Mat;
use crate::ensure_shape;

/// Per-model workspace: every intermediate an `inc_dec` round needs, kept
/// warm across rounds so the steady-state posterior update performs zero
/// heap allocations (see `linalg::woodbury`'s workspace contract).
#[derive(Clone, Default)]
struct KbrWork {
    /// Sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Mapped insertion block Φ_C (C, J).
    phi_c: Mat,
    /// Scaled update columns Φ_H / σ_b (J, C + R).
    phi_h: Mat,
    /// Column signs (+1 insert / −1 remove).
    signs: Vec<f64>,
    /// Woodbury scratch.
    incdec: IncDecWork,
    /// D=1 shim scratch: `y_new` as a (B, 1) column.
    y_shim: Mat,
}

/// Prior/noise hyperparameters (paper §V: both 0.01).
#[derive(Clone, Copy, Debug)]
pub struct KbrHyper {
    /// Prior weight variance sigma_u^2.
    pub sigma_u2: f64,
    /// Observation noise variance sigma_b^2.
    pub sigma_b2: f64,
}

impl Default for KbrHyper {
    fn default() -> Self {
        Self { sigma_u2: 0.01, sigma_b2: 0.01 }
    }
}

/// `(lo, hi)` bounds of the central ~95% credible interval (1.96 sigma)
/// for each `(mean, var)` pair, written into a caller-provided buffer —
/// the allocation-free core shared by [`Predictive::interval95_into`] and
/// the serve layer's uncertainty fan-in.
pub fn interval95_from_into(mean: &[f64], var: &[f64], out: &mut Vec<(f64, f64)>) {
    out.clear();
    out.extend(mean.iter().zip(var).map(|(m, v)| {
        let hw = 1.96 * v.max(0.0).sqrt();
        (m - hw, m + hw)
    }));
}

/// A Gaussian predictive distribution per query point.
#[derive(Clone, Debug)]
pub struct Predictive {
    /// Posterior predictive means mu*.
    pub mean: Vec<f64>,
    /// Posterior predictive variances psi* (includes noise sigma_b^2).
    pub var: Vec<f64>,
}

impl Predictive {
    /// Central credible interval bounds at ~95% (1.96 sigma).
    pub fn interval95(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        self.interval95_into(&mut out);
        out
    }

    /// [`Predictive::interval95`] written into a caller-provided buffer —
    /// allocation-free once `out` has capacity (the serve layer's warm
    /// uncertainty path).
    pub fn interval95_into(&self, out: &mut Vec<(f64, f64)>) {
        interval95_from_into(&self.mean, &self.var, out);
    }
}

/// A multi-output Gaussian predictive distribution: per-query mean row
/// across `D` outputs, ONE shared variance per query (the posterior
/// precision is target-independent, so all outputs see the same psi*).
#[derive(Clone, Debug)]
pub struct PredictiveMulti {
    /// Posterior predictive means, (B, D).
    pub mean: Mat,
    /// Shared posterior predictive variances psi* (B,).
    pub var: Vec<f64>,
}

/// Caller-owned workspace for [`KbrModel::predict_into`]: the mapped query
/// block and the Σ Φ*ᵀ product, kept warm so steady-state uncertainty
/// serving performs zero heap allocations (measured in
/// `rust/tests/alloc_count.rs`).
#[derive(Clone, Default)]
pub struct KbrPredictWork {
    /// Mapped query features Φ* (B, J).
    phi_star: Mat,
    /// Σ Φ*ᵀ (J, B) — the batched covariance product.
    sc: Mat,
}

/// Incremental Kernelized Bayesian Regression engine (intrinsic space).
#[derive(Clone)]
pub struct KbrModel {
    kernel: Kernel,
    table: MonomialTable,
    hyper: KbrHyper,
    /// Posterior covariance Sigma_{u|y,Phi} (J, J) — shared by all D
    /// output columns (the precision never sees the targets).
    cov: Mat,
    /// Posterior means, one column per output (J, D).
    mean: Mat,
    /// Mapped training features (N, J) — needed for decremental columns.
    phi: Mat,
    /// Targets, multiplicity-averaged, (N, D).
    y: Mat,
    /// Per-row duplicate multiplicities c_i (all 1.0 until a fold).
    mult: Vec<f64>,
    /// Running Phi^T C Ȳ (J, D).
    py: Mat,
    work: KbrWork,
}

impl KbrModel {
    /// Fit the batch posterior from scratch (eq. 41-42): O(N J^2 + J^3),
    /// `D = 1`.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, hyper: KbrHyper) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::fit_multi(x, &ym, kernel, hyper)
    }

    /// Fit the batch posterior with a `(N, D)` target matrix: one
    /// precision factorization, `D` mean columns.
    pub fn fit_multi(x: &Mat, y: &Mat, kernel: &Kernel, hyper: KbrHyper) -> Result<Self> {
        ensure_shape!(
            x.rows() == y.rows(),
            "KbrModel::fit",
            "x has {} rows, y has {}",
            x.rows(),
            y.rows()
        );
        if hyper.sigma_u2 <= 0.0 || hyper.sigma_b2 <= 0.0 {
            return Err(Error::Config("KBR variances must be > 0".into()));
        }
        if y.cols() == 0 {
            return Err(Error::Config("target matrix needs >= 1 column".into()));
        }
        let table = kernel.feature_table(x.cols()).ok_or_else(|| {
            Error::Config(format!(
                "kernel {kernel:?} has infinite intrinsic dimension; KBR here \
                 operates in intrinsic space (paper §IV)"
            ))
        })?;
        let phi = table.map(x); // (N, J)
        let j = table.j();
        let d = y.cols();
        // precision = I/sigma_u^2 + Phi^T Phi / sigma_b^2 — transpose-side
        // SYRK straight off the row-major store (half the flops, no
        // materialized Phi^T; the noise scale folds into alpha)
        let mut prec = Mat::default();
        crate::linalg::gemm::syrk_t_into(1.0 / hyper.sigma_b2, &phi, 0.0, &mut prec)?;
        prec.add_diag(1.0 / hyper.sigma_u2)?;
        let cov = spd_inverse(&prec)?;
        // PY = Phi^T Y: all D right-hand sides in one TN product
        let mut py = Mat::zeros(j, d);
        crate::linalg::gemm::gemm_tn_acc(1.0, &phi, y, &mut py)?;
        let mut mean = Mat::default();
        matmul_into(&cov, &py, &mut mean)?;
        for m in mean.as_mut_slice() {
            *m /= hyper.sigma_b2;
        }
        Ok(Self {
            kernel: kernel.clone(),
            table,
            hyper,
            cov,
            mean,
            phi,
            y: y.clone(),
            mult: vec![1.0; y.rows()],
            py,
            work: KbrWork::default(),
        })
    }

    /// One batched incremental/decremental posterior update (eq. 43-44),
    /// `D = 1` surface. Steady state performs zero heap allocations.
    pub fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "inc_dec is the D=1 surface; use inc_dec_multi".into(),
            ));
        }
        let mut shim = std::mem::take(&mut self.work.y_shim);
        shim.resize_scratch(y_new.len(), 1);
        shim.as_mut_slice().copy_from_slice(y_new);
        let out = self.inc_dec_multi(x_new, &shim, remove_idx);
        self.work.y_shim = shim;
        out
    }

    /// One batched incremental/decremental posterior update (eq. 43-44)
    /// over all `D` output columns. Steady state performs zero heap
    /// allocations: the scaled Φ_H, signs and Woodbury scratch live in the
    /// per-model workspace, the covariance update is in place, and the
    /// stores edit inside reserved capacity. A multiplicity-`c` row leaves
    /// through a `√c/σ_b`-scaled column.
    pub fn inc_dec_multi(&mut self, x_new: &Mat, y_new: &Mat, remove_idx: &[usize]) -> Result<()> {
        ensure_shape!(
            x_new.rows() == y_new.rows(),
            "KbrModel::inc_dec",
            "x_new {} rows, y_new {}",
            x_new.rows(),
            y_new.rows()
        );
        if x_new.rows() > 0 {
            ensure_shape!(
                y_new.cols() == self.y.cols(),
                "KbrModel::inc_dec",
                "y_new has {} cols, engine carries D = {}",
                y_new.cols(),
                self.y.cols()
            );
        }
        self.work.rem.clear();
        self.work.rem.extend_from_slice(remove_idx);
        self.work.rem.sort_unstable();
        self.work.rem.dedup();
        if let Some(&mx) = self.work.rem.last() {
            if mx >= self.y.rows() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.rows()
                )));
            }
        }
        let c = x_new.rows();
        let r = self.work.rem.len();
        if c + r == 0 {
            return Ok(());
        }
        let j = self.table.j();
        self.table.map_into_mat(x_new, &mut self.work.phi_c); // (C, J)
        // Phi_H scaled by 1/sigma_b so the precision shift matches eq. 43;
        // a multiplicity-c row carries √c of extra scale (its whole
        // precision share leaves in one ±1-signed rank-1 term)
        let inv_sb = 1.0 / self.hyper.sigma_b2.sqrt();
        self.work.phi_h.resize_scratch(j, c + r);
        for row in 0..c {
            for jj in 0..j {
                self.work.phi_h[(jj, row)] = self.work.phi_c[(row, jj)] * inv_sb;
            }
        }
        for col in 0..r {
            let ri = self.work.rem[col];
            let w = self.mult[ri].sqrt() * inv_sb;
            for jj in 0..j {
                self.work.phi_h[(jj, c + col)] = self.phi[(ri, jj)] * w;
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, c));
        self.work.signs.extend(std::iter::repeat_n(-1.0, r));
        incdec_into(
            &mut self.cov,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        // maintain Phi^T C Y and the stores
        for row in 0..c {
            ger(&mut self.py, 1.0, self.work.phi_c.row(row), y_new.row(row))?;
        }
        for &ri in &self.work.rem {
            ger(&mut self.py, -self.mult[ri], self.phi.row(ri), self.y.row(ri))?;
        }
        self.phi.drop_rows_sorted(&self.work.rem)?;
        self.y.drop_rows_sorted(&self.work.rem)?;
        for (i, &ri) in self.work.rem.iter().enumerate() {
            self.mult.remove(ri - i);
        }
        for row in 0..c {
            self.phi.push_row(self.work.phi_c.row(row))?;
            self.y.push_row(y_new.row(row))?;
            self.mult.push(1.0);
        }
        self.refresh_mean()
    }

    /// Fold duplicates into multiplicity-weighted rows: each target row
    /// gains one more `φ_i φ_iᵀ / σ_b²` precision share (ONE batched
    /// rank-|F| Woodbury increment over the unscaled stored rows), and the
    /// running `Φᵀ C Ȳ` absorbs the new observation — identical posterior
    /// to the unfolded insert. Allocation-free once warm.
    pub fn apply_folds(&mut self, folds: &[(usize, usize)], _x_new: &Mat, y_new: &Mat) -> Result<()> {
        if folds.is_empty() {
            return Ok(());
        }
        let n = self.y.rows();
        let d = self.y.cols();
        let j = self.table.j();
        let inv_sb = 1.0 / self.hyper.sigma_b2.sqrt();
        self.work.phi_h.resize_scratch(j, folds.len());
        for (k, &(i, br)) in folds.iter().enumerate() {
            ensure_shape!(
                i < n && br < y_new.rows(),
                "KbrModel::apply_folds",
                "fold ({i}, {br}) out of range (n = {n}, batch = {})",
                y_new.rows()
            );
            ensure_shape!(
                y_new.cols() == d,
                "KbrModel::apply_folds",
                "y_new has {} cols, engine carries D = {d}",
                y_new.cols()
            );
            for jj in 0..j {
                self.work.phi_h[(jj, k)] = self.phi[(i, jj)] * inv_sb;
            }
        }
        self.work.signs.clear();
        self.work.signs.extend(std::iter::repeat_n(1.0, folds.len()));
        incdec_into(
            &mut self.cov,
            &self.work.phi_h,
            &self.work.signs,
            &mut self.work.incdec,
        )?;
        for &(i, br) in folds {
            let c = self.mult[i];
            ger(&mut self.py, 1.0, self.phi.row(i), y_new.row(br))?;
            for dc in 0..d {
                self.y[(i, dc)] = (c * self.y[(i, dc)] + y_new[(br, dc)]) / (c + 1.0);
            }
            self.mult[i] = c + 1.0;
        }
        self.refresh_mean()
    }

    /// Mean refresh (eq. 44): ONE `(J, J)·(J, D)` GEMM for all outputs.
    fn refresh_mean(&mut self) -> Result<()> {
        matmul_into(&self.cov, &self.py, &mut self.mean)?;
        for m in self.mean.as_mut_slice() {
            *m /= self.hyper.sigma_b2;
        }
        Ok(())
    }

    /// Posterior predictive distribution for a block of raw feature rows
    /// (eq. 45-50), `D = 1`.
    pub fn predict(&self, x: &Mat) -> Result<Predictive> {
        let mut mean = Vec::new();
        let mut var = Vec::new();
        self.predict_into(x, &mut mean, &mut var, &mut KbrPredictWork::default())?;
        Ok(Predictive { mean, var })
    }

    /// Multi-output posterior predictive distribution: `(B, D)` means and
    /// the shared per-query variance column.
    pub fn predict_multi(&self, x: &Mat) -> Result<PredictiveMulti> {
        let mut mean = Mat::default();
        let mut var = Vec::new();
        self.predict_multi_into(x, &mut mean, &mut var, &mut KbrPredictWork::default())?;
        Ok(PredictiveMulti { mean, var })
    }

    /// [`KbrModel::predict`] written into caller-provided buffers, drawing
    /// every intermediate from `work` — allocation-free once warm. The
    /// variance column `Σ Φ*ᵀ` is built as ONE batched product over the
    /// whole micro-batch (a packed GEMM above the dispatch crossover)
    /// instead of B per-request covariance GEMVs, which is where the
    /// serving layer's BLAS-3 win lives. `D = 1` only.
    pub fn predict_into(
        &self,
        x: &Mat,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        work: &mut KbrPredictWork,
    ) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "predict_into is the D=1 surface; use predict_multi_into".into(),
            ));
        }
        ensure_shape!(
            x.cols() == self.table.m,
            "KbrModel::predict",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        gemv_into(&work.phi_star, self.mean.as_slice(), mean)?;
        self.variance_into(var, work)
    }

    /// Multi-output [`KbrModel::predict_into`]: `mean` becomes `(B, D)`
    /// via ONE packed `(B, J)·(J, D)` GEMM, `var` the shared per-query
    /// variance. Allocation-free once warm.
    pub fn predict_multi_into(
        &self,
        x: &Mat,
        mean: &mut Mat,
        var: &mut Vec<f64>,
        work: &mut KbrPredictWork,
    ) -> Result<()> {
        ensure_shape!(
            x.cols() == self.table.m,
            "KbrModel::predict_multi",
            "x has {} cols, expected {}",
            x.cols(),
            self.table.m
        );
        self.table.map_into_mat(x, &mut work.phi_star); // (B, J)
        matmul_into(&work.phi_star, &self.mean, mean)?; // (B, D)
        self.variance_into(var, work)
    }

    /// psi* = sigma_b^2 + diag(Phi* Sigma Phi*^T) from the mapped block
    /// already sitting in `work.phi_star`.
    fn variance_into(&self, var: &mut Vec<f64>, work: &mut KbrPredictWork) -> Result<()> {
        crate::linalg::gemm::matmul_nt_into(&self.cov, &work.phi_star, &mut work.sc)?; // (J, B)
        let b = work.phi_star.rows();
        debug_assert_eq!(work.sc.rows(), work.phi_star.cols());
        let sc = work.sc.as_slice();
        var.clear();
        for r in 0..b {
            // Φ* row r (contiguous) · Σ Φ*ᵀ column r (stride B) — no
            // materialized column copy
            let mut q = 0.0;
            for (jj, &p) in work.phi_star.row(r).iter().enumerate() {
                q += p * sc[jj * b + r];
            }
            var.push(self.hyper.sigma_b2 + q.max(0.0));
        }
        Ok(())
    }

    /// GP log marginal likelihood log p(Y | Phi) for the current training
    /// set, summed over the `D` independent output columns (extension:
    /// evidence for hyperparameter checking). With folded rows this is the
    /// evidence of the weighted store (multiplicity-averaged targets), a
    /// diagnostics-path approximation of the unfolded stream's evidence.
    pub fn log_marginal_likelihood(&self) -> Result<f64> {
        // p(y|Phi) = N(0, sigma_u^2 Phi^T Phi + sigma_b^2 I)  (N-dim)
        let n = self.y.rows();
        // Phi Phi^T is symmetric: SYRK route, half the flops of the
        // general product
        let k = crate::linalg::gemm::syrk(&self.phi)?; // (N,N)
        let mut c = k;
        c.scale(self.hyper.sigma_u2);
        c.add_diag(self.hyper.sigma_b2)?;
        let ld = spd_logdet(&c)?;
        let mut total = 0.0;
        for dc in 0..self.y.cols() {
            let ycol: Vec<f64> = (0..n).map(|i| self.y[(i, dc)]).collect();
            let alpha = crate::linalg::solve::solve_spd(&c, &ycol)?;
            let quad = dot(&ycol, &alpha);
            total += -0.5 * (quad + ld + n as f64 * (2.0 * std::f64::consts::PI).ln());
        }
        Ok(total)
    }

    /// Posterior mean vector (J,) (`D = 1` view).
    pub fn posterior_mean(&self) -> &[f64] {
        debug_assert_eq!(self.y.cols(), 1, "posterior_mean is the D=1 view");
        self.mean.as_slice()
    }

    /// Posterior mean matrix, (J, D).
    pub fn posterior_mean_multi(&self) -> &Mat {
        &self.mean
    }

    /// Posterior covariance (J, J).
    pub fn posterior_cov(&self) -> &Mat {
        &self.cov
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.rows()
    }

    /// Number of target columns D.
    pub fn n_outputs(&self) -> usize {
        self.y.cols()
    }

    /// Per-row duplicate multiplicities (all 1.0 unless folds happened).
    pub fn multiplicities(&self) -> &[f64] {
        &self.mult
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Hyperparameters.
    pub fn hyper(&self) -> KbrHyper {
        self.hyper
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemv;
    use crate::testutil::{assert_mat_close, assert_vec_close};
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.1 * rng.gaussian())
            .collect();
        (x, y)
    }

    #[test]
    fn incremental_equals_batch_posterior() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(30, 4, 1);
        let (xc, yc) = data(4, 4, 2);
        let mut inc = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        inc.inc_dec(&xc, &yc, &[3, 9]).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&[3, 9]).unwrap();
        y2.remove(9);
        y2.remove(3);
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let batch = KbrModel::fit(&x2, &y2, &kernel, KbrHyper::default()).unwrap();

        assert_vec_close(inc.posterior_mean(), batch.posterior_mean(), 1e-6);
        assert_mat_close(inc.posterior_cov(), batch.posterior_cov(), 1e-6);
    }

    #[test]
    fn predictive_variance_positive_and_shrinking() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(40, 3, 3);
        let (xt, _) = data(6, 3, 4);
        let small = KbrModel::fit(
            &x.block(0, 8, 0, 3),
            &y[..8],
            &kernel,
            KbrHyper::default(),
        )
        .unwrap();
        let big = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let ps = small.predict(&xt).unwrap();
        let pb = big.predict(&xt).unwrap();
        for (vs, vb) in ps.var.iter().zip(&pb.var) {
            assert!(*vb > 0.0);
            assert!(*vb <= vs + 1e-9, "variance must not grow with data");
            assert!(*vb >= KbrHyper::default().sigma_b2 - 1e-12);
        }
    }

    #[test]
    fn posterior_mean_tracks_krr_limit() {
        // with sigma_u^2 = sigma_b^2 / rho, KBR posterior mean == KRR
        // solution without bias; sanity: predictions close to KRR's
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(50, 3, 5);
        let (xt, _) = data(8, 3, 6);
        let hyper = KbrHyper { sigma_u2: 0.02, sigma_b2: 0.01 }; // rho = 0.5
        let kbr = KbrModel::fit(&x, &y, &kernel, hyper).unwrap();
        let pm = kbr.predict(&xt).unwrap();
        // reference: intrinsic ridge solve without bias term
        let table = kernel.feature_table(3).unwrap();
        let phi = table.map(&x);
        let phit = phi.transpose();
        let mut s = crate::linalg::gemm::syrk(&phit).unwrap();
        s.add_diag(0.5).unwrap();
        let mut py = vec![0.0; table.j()];
        for (r, &yr) in y.iter().enumerate() {
            axpy_slice(yr, phi.row(r), &mut py);
        }
        let u = crate::linalg::solve::solve_spd(&s, &py).unwrap();
        let phit_star = table.map(&xt);
        let want = gemv(&phit_star, &u).unwrap();
        assert_vec_close(&pm.mean, &want, 1e-6);
    }

    #[test]
    fn interval95_brackets_mean() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(20, 3, 7);
        let kbr = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let p = kbr.predict(&x.block(0, 5, 0, 3)).unwrap();
        for ((lo, hi), m) in p.interval95().iter().zip(&p.mean) {
            assert!(lo < m && m < hi);
        }
        // the _into twin matches the allocating path exactly
        let mut buf = Vec::new();
        p.interval95_into(&mut buf);
        assert_eq!(buf, p.interval95());
    }

    #[test]
    fn evidence_is_finite_and_improves_with_fit() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(15, 3, 8);
        let kbr = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let lml = kbr.log_marginal_likelihood().unwrap();
        assert!(lml.is_finite());
        // garbage targets must have lower evidence
        let mut rng = Rng::new(9);
        let y_bad: Vec<f64> = (0..15).map(|_| 10.0 * rng.gaussian()).collect();
        let kbr_bad = KbrModel::fit(&x, &y_bad, &kernel, KbrHyper::default()).unwrap();
        assert!(kbr_bad.log_marginal_likelihood().unwrap() < lml);
    }

    #[test]
    fn rejects_invalid() {
        let (x, y) = data(10, 3, 10);
        assert!(KbrModel::fit(&x, &y, &Kernel::rbf_radius(50.0), KbrHyper::default()).is_err());
        let bad = KbrHyper { sigma_u2: 0.0, sigma_b2: 0.01 };
        assert!(KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), bad).is_err());
        let mut m = KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), KbrHyper::default()).unwrap();
        assert!(m.inc_dec(&Mat::zeros(0, 3), &[], &[10]).is_err());
    }

    #[test]
    fn multi_output_posterior_matches_independent_models() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y0) = data(25, 3, 11);
        let (_, y1) = data(25, 3, 12);
        let ym = Mat::from_fn(25, 2, |r, c| if c == 0 { y0[r] } else { y1[r] });
        let multi = KbrModel::fit_multi(&x, &ym, &kernel, KbrHyper::default()).unwrap();
        let m0 = KbrModel::fit(&x, &y0, &kernel, KbrHyper::default()).unwrap();
        let m1 = KbrModel::fit(&x, &y1, &kernel, KbrHyper::default()).unwrap();
        let (xt, _) = data(6, 3, 13);
        let pm = multi.predict_multi(&xt).unwrap();
        let p0 = m0.predict(&xt).unwrap();
        let p1 = m1.predict(&xt).unwrap();
        for r in 0..6 {
            assert!((pm.mean[(r, 0)] - p0.mean[r]).abs() < 1e-10);
            assert!((pm.mean[(r, 1)] - p1.mean[r]).abs() < 1e-10);
            // one shared variance column, equal to both D=1 variances
            assert!((pm.var[r] - p0.var[r]).abs() < 1e-12);
            assert!((pm.var[r] - p1.var[r]).abs() < 1e-12);
        }
    }

    #[test]
    fn fold_equals_unfolded_duplicate_insert() {
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = data(20, 3, 14);
        let mut folded = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let xdup = Mat::from_fn(1, 3, |_, c| x[(4, c)]);
        let ydup = Mat::from_vec(1, 1, vec![-0.2]).unwrap();
        folded.apply_folds(&[(4, 0)], &xdup, &ydup).unwrap();
        assert_eq!(folded.n_samples(), 20, "folding must not grow N");

        let x_ref = x.vcat(&xdup).unwrap();
        let mut y_ref = y.clone();
        y_ref.push(-0.2);
        let unfolded = KbrModel::fit(&x_ref, &y_ref, &kernel, KbrHyper::default()).unwrap();
        assert_vec_close(folded.posterior_mean(), unfolded.posterior_mean(), 1e-10);
        assert_mat_close(folded.posterior_cov(), unfolded.posterior_cov(), 1e-10);
    }
}
