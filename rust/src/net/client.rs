//! Blocking client for the reactor protocol — the reference
//! implementation of the frame grammar, used by the loopback tests, the
//! `net/storm` microbench, and `examples/net_serve.rs`.
//!
//! One client owns one connection and is synchronous by construction.
//! Pipelining is explicit: [`NetClient::send_predict`] /
//! [`NetClient::send_update`] enqueue frames without waiting, and
//! [`NetClient::recv`] pulls whatever answer arrives next — ids
//! correlate them. [`NetClient::query`] is the simple call-and-wait
//! wrapper matching the in-process [`crate::serve::PredictClient`].

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::frame::{self, Frame};
use crate::error::{Error, Result};
use crate::serve::query::{PredictRequest, PredictResponse};
use crate::streaming::StreamEvent;
use crate::telemetry::TelemetrySnapshot;

/// Blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    rbuf: Vec<u8>,
    out: Vec<u8>,
    scratch: Vec<u8>,
    next_id: u64,
    max_frame_len: usize,
}

impl NetClient {
    /// Connect. `max_frame_len` must be at least the server's cap (it
    /// bounds what [`NetClient::recv`] will accept).
    pub fn connect(addr: SocketAddr, max_frame_len: usize) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Self {
            stream,
            rbuf: Vec::new(),
            out: Vec::new(),
            scratch: Vec::new(),
            next_id: 1,
            max_frame_len,
        })
    }

    /// Bound how long [`NetClient::recv`] blocks (`None` = forever).
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self) -> Result<()> {
        self.stream.write_all(&self.out)?;
        self.out.clear();
        Ok(())
    }

    /// Send one predict frame without waiting; returns its id.
    pub fn send_predict(&mut self, req: &PredictRequest) -> Result<u64> {
        let id = self.fresh_id();
        frame::encode_predict(&mut self.out, &mut self.scratch, id, req);
        self.send()?;
        Ok(id)
    }

    /// Send one update frame without waiting; returns its id.
    pub fn send_update(&mut self, ev: &StreamEvent) -> Result<u64> {
        let id = self.fresh_id();
        frame::encode_update(&mut self.out, &mut self.scratch, id, ev);
        self.send()?;
        Ok(id)
    }

    /// Push pre-encoded bytes down the socket verbatim — the loopback
    /// tests use this to deliver torn and bit-flipped frames.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes)?;
        Ok(())
    }

    /// Block until one complete frame arrives and decode it.
    pub fn recv(&mut self) -> Result<Frame> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(total) = frame::peek_frame(&self.rbuf, self.max_frame_len)? {
                let f = frame::decode_frame(&self.rbuf[..total])?;
                self.rbuf.drain(..total);
                return Ok(f);
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(Error::Stream("server closed the connection".into()));
                }
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Err(Error::Stream("timed out waiting for a frame".into()));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
    }

    /// Send one stats-pull frame without waiting; returns its id.
    pub fn send_stats_pull(&mut self) -> Result<u64> {
        let id = self.fresh_id();
        frame::encode_stats_pull(&mut self.out, &mut self.scratch, id);
        self.send()?;
        Ok(id)
    }

    /// Pull the server's merged fleet telemetry snapshot (the `MKTL`
    /// frame): every counter/gauge slot, every histogram, and the
    /// reactor's flight-recorder tail. The server records nothing while
    /// answering, so two pulls against an idle server decode to equal —
    /// indeed byte-identical — snapshots.
    pub fn stats(&mut self) -> Result<TelemetrySnapshot> {
        let id = self.send_stats_pull()?;
        loop {
            match self.recv()? {
                Frame::Stats { id: rid, snapshot } if rid == id => return Ok(snapshot),
                Frame::Error { id: rid, transient, msg } if rid == id || rid == 0 => {
                    return Err(if transient {
                        Error::Stream(msg)
                    } else {
                        Error::Config(msg)
                    });
                }
                _ => continue,
            }
        }
    }

    /// Send one request and block for ITS answer (frames for other ids —
    /// e.g. acks of pipelined updates — are skipped). A `RetryAfter`
    /// surfaces as a *transient* [`Error::Stream`] so retry loops built
    /// on [`Error::is_transient`] do the right thing; an `Error` frame
    /// keeps its server-side transience.
    pub fn query(&mut self, req: &PredictRequest) -> Result<PredictResponse> {
        let id = self.send_predict(req)?;
        loop {
            match self.recv()? {
                Frame::Response { id: rid, resp } if rid == id => return Ok(resp),
                Frame::RetryAfter { id: rid, retry_ms } if rid == id => {
                    return Err(Error::Stream(format!(
                        "request shed, retry after {retry_ms}ms"
                    )));
                }
                Frame::Error { id: rid, transient, msg } if rid == id || rid == 0 => {
                    return Err(if transient {
                        Error::Stream(msg)
                    } else {
                        Error::Config(msg)
                    });
                }
                _ => continue,
            }
        }
    }
}
