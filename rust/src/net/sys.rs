//! Readiness polling for the reactor, dependency-free.
//!
//! On Linux x86_64/aarch64 this is real `epoll` via raw syscalls — the
//! same no-libc idiom as `par/mod.rs`'s `sched_setaffinity` shim (the
//! offline crate set has no `libc`/`mio`). Everywhere else a portable
//! fallback reports every registered socket as ready on a short tick:
//! readiness becomes *spurious* rather than edge-accurate, which is
//! correct (if slower) because every reactor handler already tolerates
//! `WouldBlock` on nonblocking sockets. The fallback bounds its tick at
//! 1ms so a quiet server costs a wakeup per millisecond, not a spin.
//!
//! The poller is level-triggered: a socket stays ready until drained,
//! so a handler that stops mid-buffer is re-driven on the next wait.

use crate::error::{Error, Result};

/// OS identity of a socket, as the poller wants it.
#[cfg(unix)]
pub type SockId = std::os::fd::RawFd;
/// OS identity of a socket (unused by the fallback poller, which keys
/// readiness on tokens alone).
#[cfg(not(unix))]
pub type SockId = u64;

/// Extract the poller identity of any socket-like object.
#[cfg(unix)]
pub fn sock_id<T: std::os::fd::AsRawFd>(s: &T) -> SockId {
    s.as_raw_fd()
}

/// Fallback identity: the portable poller never inspects it.
#[cfg(not(unix))]
pub fn sock_id<T>(_s: &T) -> SockId {
    0
}

/// What a registration wants to be woken for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Interest {
    /// Wake when a read would make progress (includes accept and peer
    /// hangup).
    pub readable: bool,
    /// Wake when a write would make progress.
    pub writable: bool,
}

impl Interest {
    /// Read-only interest (the steady state of an idle connection).
    pub const READ: Interest = Interest { readable: true, writable: false };
    /// Read + write interest (a connection with a backed-up write buffer).
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };
}

/// One readiness event out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the socket was registered with.
    pub token: u64,
    /// Reading would make progress.
    pub readable: bool,
    /// Writing would make progress.
    pub writable: bool,
    /// Error or hangup condition — the owner should drive the socket and
    /// let the resulting `read`/`write` error classify it.
    pub error: bool,
}

/// Whether this build runs a real epoll backend (`false` means the
/// spurious-readiness fallback).
pub const EPOLL_BACKED: bool =
    cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")));

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::{Event, Interest, SockId};
    use crate::error::{Error, Result};

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: usize = 3;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EPOLL_CREATE1: usize = 291;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const CLOSE: usize = 57;
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: usize = 1;
    const EPOLL_CTL_DEL: usize = 2;
    const EPOLL_CTL_MOD: usize = 3;
    const EPOLL_CLOEXEC: usize = 0x80000;
    const EINTR: isize = 4;

    /// Kernel ABI event record. x86_64 packs it to 12 bytes; everywhere
    /// else it is naturally aligned. Fields are only ever read by value —
    /// taking a reference into a packed struct is UB-adjacent.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy, Default)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    /// A real epoll instance.
    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> Result<Self> {
            let epfd = check(unsafe {
                syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0)
            })? as i32;
            Ok(Self { epfd, buf: vec![EpollEvent::default(); 256] })
        }

        fn ctl(&mut self, op: usize, id: SockId, token: u64, interest: Interest) -> Result<()> {
            let mut flags = EPOLLRDHUP;
            if interest.readable {
                flags |= EPOLLIN;
            }
            if interest.writable {
                flags |= EPOLLOUT;
            }
            let ev = EpollEvent { events: flags, data: token };
            // DEL must still pass a non-null event pointer (pre-2.6.9
            // kernel ABI quirk); the kernel ignores its contents.
            check(unsafe {
                syscall6(
                    nr::EPOLL_CTL,
                    self.epfd as usize,
                    op,
                    id as usize,
                    &ev as *const EpollEvent as usize,
                    0,
                    0,
                )
            })?;
            Ok(())
        }

        pub fn register(&mut self, id: SockId, token: u64, interest: Interest) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, id, token, interest)
        }

        pub fn modify(&mut self, id: SockId, token: u64, interest: Interest) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, id, token, interest)
        }

        pub fn deregister(&mut self, id: SockId, token: u64) -> Result<()> {
            self.ctl(EPOLL_CTL_DEL, id, token, Interest::default())
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
            events.clear();
            let n = unsafe {
                // null sigmask: plain epoll_wait semantics (the bare
                // epoll_wait syscall does not exist on aarch64)
                syscall6(
                    nr::EPOLL_PWAIT,
                    self.epfd as usize,
                    self.buf.as_mut_ptr() as usize,
                    self.buf.len(),
                    timeout_ms as usize,
                    0,
                    0,
                )
            };
            if n == -EINTR {
                return Ok(()); // interrupted wait = zero events
            }
            let n = check(n)? as usize;
            for i in 0..n.min(self.buf.len()) {
                // copy out by value; never reference into the (possibly
                // packed) record
                let raw = self.buf[i];
                let flags = raw.events;
                events.push(Event {
                    token: raw.data,
                    readable: flags & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0,
                    writable: flags & EPOLLOUT != 0,
                    error: flags & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                syscall6(nr::CLOSE, self.epfd as usize, 0, 0, 0, 0, 0);
            }
        }
    }

    fn check(ret: isize) -> Result<isize> {
        if ret < 0 {
            Err(Error::Io(std::io::Error::from_raw_os_error(-ret as i32)))
        } else {
            Ok(ret)
        }
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    use super::{Event, Interest, SockId};
    use crate::error::Result;
    use std::collections::BTreeMap;
    use std::time::Duration;

    /// Portable fallback: every registered token is reported ready on a
    /// bounded tick. Spurious readiness + nonblocking sockets degrade to
    /// polling, never to incorrectness.
    pub struct Poller {
        registered: BTreeMap<u64, Interest>,
    }

    impl Poller {
        pub fn new() -> Result<Self> {
            Ok(Self { registered: BTreeMap::new() })
        }

        pub fn register(&mut self, _id: SockId, token: u64, interest: Interest) -> Result<()> {
            self.registered.insert(token, interest);
            Ok(())
        }

        pub fn modify(&mut self, _id: SockId, token: u64, interest: Interest) -> Result<()> {
            self.registered.insert(token, interest);
            Ok(())
        }

        pub fn deregister(&mut self, _id: SockId, token: u64) -> Result<()> {
            self.registered.remove(&token);
            Ok(())
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
            events.clear();
            let tick = Duration::from_millis((timeout_ms.max(0) as u64).min(1));
            std::thread::sleep(tick);
            for (&token, interest) in &self.registered {
                events.push(Event {
                    token,
                    readable: interest.readable,
                    writable: interest.writable,
                    error: false,
                });
            }
            Ok(())
        }
    }
}

pub use imp::Poller;

/// Convert a poller wait error into something callers can retry on:
/// transient by construction (readiness polling is stateless).
pub fn transient(e: Error) -> Error {
    match e {
        Error::Io(io) => Error::Stream(format!("poller: {io}")),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poller_builds_and_times_out_empty() {
        let mut p = Poller::new().unwrap();
        let mut ev = Vec::new();
        p.wait(&mut ev, 0).unwrap();
        assert!(ev.is_empty());
    }

    #[cfg(unix)]
    #[test]
    fn listener_becomes_readable_on_connect() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut p = Poller::new().unwrap();
        p.register(sock_id(&listener), 7, Interest::READ).unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        // readiness may take a beat; poll a few times
        let mut ev = Vec::new();
        let mut seen = false;
        for _ in 0..100 {
            p.wait(&mut ev, 50).unwrap();
            if ev.iter().any(|e| e.token == 7 && e.readable) {
                seen = true;
                break;
            }
        }
        assert!(seen, "pending accept never reported readable");
        p.deregister(sock_id(&listener), 7).unwrap();
        p.wait(&mut ev, 0).unwrap();
        assert!(
            ev.iter().all(|e| e.token != 7),
            "deregistered socket still reported"
        );
    }
}
