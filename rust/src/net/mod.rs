//! Dependency-free network serving front-end.
//!
//! The paper pitches multiple incremental KRR at "big streams ... in
//! cloud centers", and the ROADMAP's north star is serving traffic that
//! arrives over sockets, not over in-process channels. This module puts
//! the [`crate::serve`] layer behind TCP without adding a dependency:
//!
//! * [`sys`] — readiness polling: raw-syscall epoll on Linux
//!   x86_64/aarch64 (the same no-libc idiom as `par/mod.rs`), a
//!   spurious-readiness fallback everywhere else.
//! * [`frame`] — the wire protocol: each message is one
//!   [`crate::persist::codec`] CRC section whose payload is the
//!   *canonical* serialization of the in-process request/response types.
//! * [`reactor`] — the single-threaded event loop: nonblocking accept,
//!   per-connection buffers, per-[`crate::serve::QueryKind`] batch
//!   window shared with [`crate::serve::MicroBatchServer`], and
//!   load-shedding admission control (`RetryAfter`).
//! * [`client`] — a blocking reference client for tests, benches, and
//!   examples.
//!
//! The frame grammar, shed semantics, and retry-after contract are
//! documented in `serve/mod.rs` §"Network serving and admission
//! control"; throughput and tail latency under a mixed predict/update
//! storm are tracked by the `net/storm` microbench (`sustained_rps` in
//! the CI perf gate, next to `speedup_serve_microbatch`).
//!
//! Observability rides the same socket: an `MKTL` stats frame
//! ([`NetClient::stats`]) pulls the merged
//! [`crate::telemetry::TelemetrySnapshot`] — reactor + router + every
//! shard registry, plus the reactor's flight-recorder tail — without
//! perturbing what it measures (the pull path records nothing). See
//! `serve/mod.rs` §"Telemetry and flight recording".

pub mod client;
pub mod frame;
pub mod reactor;
pub mod sys;

pub use client::NetClient;
pub use frame::Frame;
pub use reactor::{NetConfig, NetLive, NetServer, NetStats};
