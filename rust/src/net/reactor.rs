//! The event-driven serving front-end: one reactor thread multiplexing
//! every connection over [`super::sys::Poller`], coalescing predict
//! frames into the per-[`QueryKind`] micro-batch lanes and feeding
//! update frames to the router ingest path through a bounded queue.
//!
//! # Execution model
//!
//! A single thread owns the listener, every connection, and the batch
//! window — there are no locks on the request path. Each poll iteration:
//!
//! 1. wait for readiness (bounded so the stop flag and the window
//!    deadline are honored),
//! 2. accept new connections / drain readable sockets, decoding complete
//!    frames and admitting them (or shedding, see below),
//! 3. when the window fills ([`MicroBatchPolicy::max_rows`] rows) or its
//!    deadline passes ([`MicroBatchPolicy::max_wait`] after the first
//!    admitted row), run ONE batched [`RouterHandle`] query per
//!    [`QueryKind`] present ([`QueryLanes`], the same core the
//!    in-process [`crate::serve::MicroBatchServer`] uses) and answer
//!    every admitted frame out of its kind's lane.
//!
//! B concurrent network predicts therefore cost one packed GEMM per
//! kind, exactly like B in-process clients.
//!
//! # Admission control
//!
//! Nothing queues unboundedly — see `serve/mod.rs` §"Network serving and
//! admission control" for the contract. Per connection: at most
//! [`NetConfig::max_inflight_per_conn`] admitted predicts. Globally: at
//! most [`NetConfig::pending_budget`] admitted rows per window; updates
//! go through a bounded [`std::sync::mpsc::sync_channel`] of
//! [`NetConfig::update_queue`] events. Anything over budget is answered
//! *immediately* with a `RetryAfter` frame and never stored; a
//! connection whose unread replies exceed [`NetConfig::max_write_buf`]
//! is closed as a slow reader. Frames that fail CRC/framing get one
//! best-effort `Error` frame and the connection is closed — a torn frame
//! means the byte stream can never resynchronize.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::frame::{self, Frame};
use super::sys::{sock_id, Event, Interest, Poller, SockId};
use crate::error::{Error, Result};
use crate::metrics::{Counters, LatencyHist, Timer};
use crate::serve::microbatch::QueryLanes;
use crate::serve::query::QueryKind;
use crate::serve::{MicroBatchPolicy, RouterHandle};
use crate::streaming::StreamEvent;
use crate::telemetry::{FlightRecorder, MetricId, Registry, SpanKind, DEFAULT_RECORDER_CAPACITY};

/// Reactor configuration. Defaults serve a loopback fleet; production
/// deployments tune the budgets to the provisioned memory.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address (`"127.0.0.1:0"` = loopback, OS-assigned port).
    pub addr: String,
    /// Batch window shared with the in-process micro-batcher.
    pub batch: MicroBatchPolicy,
    /// Hard cap on a frame's declared payload length; an over-cap header
    /// is a protocol error (connection closed), not a queued read.
    pub max_frame_len: usize,
    /// Max admitted-but-unanswered predict frames per connection.
    pub max_inflight_per_conn: usize,
    /// Global cap on admitted rows in one window; further predicts shed.
    pub pending_budget: usize,
    /// Bounded update queue (events) between reactor and ingest consumer.
    pub update_queue: usize,
    /// Backoff hint carried by `RetryAfter` frames, milliseconds.
    pub retry_after_ms: u32,
    /// Close a connection whose pending replies exceed this many bytes.
    pub max_write_buf: usize,
    /// Max simultaneous connections; excess accepts are dropped on sight.
    pub max_conns: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            batch: MicroBatchPolicy::default(),
            max_frame_len: 1 << 20,
            max_inflight_per_conn: 64,
            pending_budget: 1024,
            update_queue: 1024,
            retry_after_ms: 5,
            max_write_buf: 4 << 20,
            max_conns: 1024,
        }
    }
}

/// Final reactor statistics, returned by [`NetServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    /// Named counters: `accepted`, `conn_rejected`, `shed_predict`,
    /// `shed_update`, `predicts_served`, `updates_admitted`,
    /// `protocol_errors`, `slow_reader_closed`, `batches`, `poll_errors`.
    pub counters: Counters,
    /// Rows per executed window (recorded as raw samples; use
    /// [`LatencyHist::percentile`] for the p99 occupancy figure).
    pub window_occupancy: LatencyHist,
    /// High-water mark of admitted rows — bounded by
    /// [`NetConfig::pending_budget`] by construction.
    pub max_pending_rows: usize,
}

/// Live counters readable while the reactor runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetLive {
    /// Connections accepted so far.
    pub accepted: u64,
    /// Frames shed (predict + update).
    pub shed: u64,
    /// Currently open connections.
    pub active_conns: u64,
}

#[derive(Default)]
struct LiveCells {
    accepted: AtomicU64,
    shed: AtomicU64,
    active_conns: AtomicU64,
}

/// Handle to a running reactor. Dropping it stops the reactor and joins
/// the thread; [`NetServer::shutdown`] does the same and returns stats.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    live: Arc<LiveCells>,
    telemetry: Arc<Registry>,
    join: Option<JoinHandle<NetStats>>,
}

impl NetServer {
    /// Bind, spawn the reactor thread, and return the handle plus the
    /// bounded receiver of admitted update events. The caller owns the
    /// ingest side: drain the receiver into
    /// [`crate::serve::router::ShardRouter::ingest`] +
    /// `update_round()`; dropping the receiver makes the reactor answer
    /// further updates with a permanent error.
    pub fn spawn(
        handle: RouterHandle,
        dim: usize,
        cfg: NetConfig,
    ) -> Result<(NetServer, Receiver<StreamEvent>)> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let mut poller = Poller::new()?;
        poller.register(sock_id(&listener), TOKEN_LISTENER, Interest::READ)?;
        let (update_tx, update_rx) = sync_channel(cfg.update_queue.max(1));
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(LiveCells::default());
        let telemetry = Arc::new(Registry::new());
        let reactor = Reactor {
            telemetry: Arc::clone(&telemetry),
            recorder: FlightRecorder::default(),
            handle,
            dim,
            cfg,
            listener,
            poller,
            conns: Vec::new(),
            free: Vec::new(),
            gens: Vec::new(),
            lanes: QueryLanes::new(dim),
            pending: Vec::new(),
            pending_rows: 0,
            window_deadline: Instant::now(),
            update_tx,
            stop: stop.clone(),
            live: live.clone(),
            events: Vec::new(),
            chunk: vec![0u8; 64 * 1024],
            scratch: Vec::new(),
            stats: NetStats::default(),
        };
        let join = std::thread::Builder::new()
            .name("mikrr-net-reactor".into())
            .spawn(move || reactor.run())
            .map_err(Error::Io)?;
        Ok((NetServer { addr, stop, live, telemetry, join: Some(join) }, update_rx))
    }

    /// The reactor-tier metrics registry, readable while it runs. The
    /// merged fleet view (reactor + router + shards) is what the `MKTL`
    /// stats frame ships — pull it with
    /// [`super::client::NetClient::stats`].
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// The bound address (use with an OS-assigned port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the live counters.
    pub fn live(&self) -> NetLive {
        NetLive {
            accepted: self.live.accepted.load(Ordering::Relaxed),
            shed: self.live.shed.load(Ordering::Relaxed),
            active_conns: self.live.active_conns.load(Ordering::Relaxed),
        }
    }

    /// Stop the reactor: the window in flight is executed and flushed
    /// best-effort, every connection is dropped, and the final statistics
    /// come back.
    pub fn shutdown(mut self) -> NetStats {
        self.stop.store(true, Ordering::SeqCst);
        self.join
            .take()
            .expect("server already shut down")
            .join()
            .expect("net reactor panicked")
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

const TOKEN_LISTENER: u64 = 0;

struct Conn {
    stream: TcpStream,
    id: SockId,
    gen: u32,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wstart: usize,
    inflight: usize,
    wants_write: bool,
    /// Answer what is buffered, then close (set on protocol errors).
    closing: bool,
    /// Remove at the next reap point.
    dead: bool,
}

/// One admitted predict frame, waiting for its window to execute.
struct PendingReq {
    slot: usize,
    gen: u32,
    id: u64,
    want: QueryKind,
    start: usize,
    rows: usize,
}

struct Reactor {
    telemetry: Arc<Registry>,
    recorder: FlightRecorder,
    handle: RouterHandle,
    dim: usize,
    cfg: NetConfig,
    listener: TcpListener,
    poller: Poller,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Per-slot generation, bumped on every close: a [`PendingReq`]
    /// whose generation no longer matches is for a connection that died
    /// (and possibly a slot that was reused) — its reply is dropped
    /// instead of misdelivered.
    gens: Vec<u32>,
    lanes: QueryLanes,
    pending: Vec<PendingReq>,
    pending_rows: usize,
    window_deadline: Instant,
    update_tx: SyncSender<StreamEvent>,
    stop: Arc<AtomicBool>,
    live: Arc<LiveCells>,
    events: Vec<Event>,
    chunk: Vec<u8>,
    scratch: Vec<u8>,
    stats: NetStats,
}

impl Reactor {
    fn run(mut self) -> NetStats {
        let mut consecutive_poll_errors = 0u32;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            if self.window_due() {
                self.execute_window();
            }
            let timeout_ms = self.poll_timeout_ms();
            let mut events = std::mem::take(&mut self.events);
            match self.poller.wait(&mut events, timeout_ms) {
                Ok(()) => consecutive_poll_errors = 0,
                Err(_) => {
                    self.telemetry.inc(MetricId::PollErrors);
                    consecutive_poll_errors += 1;
                    if consecutive_poll_errors > 100 {
                        // the poller is wedged; dying loudly beats spinning
                        self.events = events;
                        break;
                    }
                }
            }
            for &ev in &events {
                if ev.token == TOKEN_LISTENER {
                    self.accept_ready();
                } else {
                    let slot = (ev.token - 1) as usize;
                    if ev.readable || ev.error {
                        self.drive_readable(slot);
                    }
                    if ev.writable {
                        self.flush_conn(slot);
                    }
                    self.reap_if_dead(slot);
                }
            }
            self.events = events;
            if self.window_due() {
                self.execute_window();
            }
        }
        // drain: answer the window in flight, push replies best-effort
        self.execute_window();
        for slot in 0..self.conns.len() {
            self.flush_conn(slot);
        }
        // the registry was the source of truth all along; the final
        // stats are its string-keyed view
        self.stats.counters = self.telemetry.counters();
        self.stats.max_pending_rows = self.telemetry.get(MetricId::MaxPendingRows) as usize;
        // dropping self.update_tx (with self) disconnects the receiver
        self.stats
    }

    fn window_due(&self) -> bool {
        self.pending_rows >= self.cfg.batch.max_rows
            || (self.pending_rows > 0 && Instant::now() >= self.window_deadline)
    }

    fn poll_timeout_ms(&self) -> i32 {
        if self.pending_rows > 0 {
            let left = self.window_deadline.saturating_duration_since(Instant::now());
            // ceil to a millisecond: a sub-ms window overshoots by < 1ms
            // rather than busy-polling the last microseconds
            (left.as_millis() as i32 + i32::from(left.subsec_micros() % 1000 != 0)).clamp(1, 10)
        } else {
            10
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    self.telemetry.inc(MetricId::Accepted);
                    self.live.accepted.fetch_add(1, Ordering::Relaxed);
                    let open = self.live.active_conns.load(Ordering::Relaxed) as usize;
                    if open >= self.cfg.max_conns {
                        self.telemetry.inc(MetricId::ConnRejected);
                        drop(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let slot = match self.free.pop() {
                        Some(s) => s,
                        None => {
                            self.conns.push(None);
                            self.gens.push(0);
                            self.conns.len() - 1
                        }
                    };
                    let id = sock_id(&stream);
                    let token = slot as u64 + 1;
                    if self.poller.register(id, token, Interest::READ).is_err() {
                        self.free.push(slot);
                        continue;
                    }
                    self.conns[slot] = Some(Conn {
                        stream,
                        id,
                        gen: self.gens[slot],
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        wstart: 0,
                        inflight: 0,
                        wants_write: false,
                        closing: false,
                        dead: false,
                    });
                    self.live.active_conns.fetch_add(1, Ordering::Relaxed);
                    self.recorder.record(SpanKind::Accept, slot as u64, open as u64 + 1);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn drive_readable(&mut self, slot: usize) {
        loop {
            let Some(conn) = self.conns[slot].as_mut() else { return };
            if conn.dead || conn.closing {
                return;
            }
            match conn.stream.read(&mut self.chunk) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&self.chunk[..n]);
                    // defend the read buffer like the write buffer: a
                    // peer pipelining more than one frame cap + budget's
                    // worth of bytes is over any sane window
                    if conn.rbuf.len()
                        > self.cfg.max_frame_len + frame::HEADER_LEN + frame::TRAILER_LEN
                            + self.cfg.max_write_buf
                    {
                        conn.dead = true;
                        self.telemetry.inc(MetricId::SlowReaderClosed);
                        return;
                    }
                    if n < self.chunk.len() {
                        break; // drained the socket
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        self.parse_frames(slot);
    }

    fn parse_frames(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].as_mut() else { return };
        let mut rbuf = std::mem::take(&mut conn.rbuf);
        let mut consumed = 0;
        loop {
            let alive = self.conns[slot].as_ref().is_some_and(|c| !c.dead && !c.closing);
            if !alive {
                break;
            }
            match frame::peek_frame(&rbuf[consumed..], self.cfg.max_frame_len) {
                Ok(None) => break,
                Ok(Some(total)) => {
                    let decoded = frame::decode_frame(&rbuf[consumed..consumed + total]);
                    consumed += total;
                    match decoded {
                        Ok(f) => self.handle_frame(slot, f),
                        Err(e) => {
                            self.protocol_error(slot, &e);
                            break;
                        }
                    }
                }
                Err(e) => {
                    self.protocol_error(slot, &e);
                    break;
                }
            }
        }
        rbuf.drain(..consumed);
        if let Some(c) = self.conns[slot].as_mut() {
            c.rbuf = rbuf;
        }
    }

    fn handle_frame(&mut self, slot: usize, f: Frame) {
        match f {
            Frame::Predict { id, req } => self.handle_predict(slot, id, req),
            Frame::Update { id, ev } => self.handle_update(slot, id, ev),
            Frame::StatsPull { id } => self.handle_stats_pull(slot, id),
            Frame::Response { .. }
            | Frame::Ack { .. }
            | Frame::RetryAfter { .. }
            | Frame::Error { .. }
            | Frame::Stats { .. } => {
                let e = Error::Config("client sent a server-only frame".into());
                self.protocol_error(slot, &e);
            }
        }
    }

    fn handle_predict(&mut self, slot: usize, id: u64, req: crate::serve::PredictRequest) {
        let rows = req.x.rows();
        if req.x.cols() != self.dim || rows == 0 {
            let e = Error::shape(
                "net::reactor",
                format!(
                    "request batch is {}x{}, expected (>=1, {})",
                    rows,
                    req.x.cols(),
                    self.dim
                ),
            );
            self.reply_error(slot, id, &e);
            return;
        }
        let inflight = self.conns[slot].as_ref().map_or(0, |c| c.inflight);
        if inflight >= self.cfg.max_inflight_per_conn
            || self.pending_rows + rows > self.cfg.pending_budget
        {
            self.telemetry.inc(MetricId::ShedPredict);
            self.recorder.record(SpanKind::Shed, rows as u64, self.pending_rows as u64);
            self.live.shed.fetch_add(1, Ordering::Relaxed);
            self.reply_retry_after(slot, id);
            return;
        }
        if self.pending.is_empty() {
            self.window_deadline = Instant::now() + self.cfg.batch.max_wait;
        }
        let start = self.lanes.push_rows(req.want, &req.x);
        let gen = self.gens[slot];
        self.pending.push(PendingReq { slot, gen, id, want: req.want, start, rows });
        self.pending_rows += rows;
        self.telemetry.gauge_max(MetricId::MaxPendingRows, self.pending_rows as u64);
        if let Some(c) = self.conns[slot].as_mut() {
            c.inflight += 1;
        }
    }

    fn handle_update(&mut self, slot: usize, id: u64, ev: StreamEvent) {
        match self.update_tx.try_send(ev) {
            Ok(()) => {
                self.telemetry.inc(MetricId::UpdatesAdmitted);
                let Self { conns, scratch, .. } = self;
                if let Some(c) = conns[slot].as_mut() {
                    frame::encode_ack(&mut c.wbuf, scratch, id);
                }
                self.flush_conn(slot);
            }
            Err(TrySendError::Full(_)) => {
                self.telemetry.inc(MetricId::ShedUpdate);
                self.recorder.record(SpanKind::Shed, 1, self.pending_rows as u64);
                self.live.shed.fetch_add(1, Ordering::Relaxed);
                self.reply_retry_after(slot, id);
            }
            Err(TrySendError::Disconnected(_)) => {
                let e = Error::Config("update sink detached; ingest is not running".into());
                self.reply_error(slot, id, &e);
            }
        }
    }

    /// Answer a stats pull with the merged fleet snapshot: router +
    /// every shard registry (via [`RouterHandle::telemetry`]), the
    /// reactor's own registry, and the reactor flight-recorder tail.
    ///
    /// This path deliberately records NOTHING — no counter, no span — so
    /// two pulls against an idle server return byte-identical frames
    /// (the acceptance contract for monitoring scrapers diffing pulls).
    fn handle_stats_pull(&mut self, slot: usize, id: u64) {
        let mut snap = self.handle.telemetry();
        self.telemetry.merge_into(&mut snap);
        snap.spans = self.recorder.tail(DEFAULT_RECORDER_CAPACITY);
        let Self { conns, scratch, .. } = self;
        if let Some(c) = conns[slot].as_mut() {
            frame::encode_stats(&mut c.wbuf, scratch, id, &snap);
        }
        self.flush_conn(slot);
    }

    fn reply_retry_after(&mut self, slot: usize, id: u64) {
        let retry_ms = self.cfg.retry_after_ms;
        let Self { conns, scratch, .. } = self;
        if let Some(c) = conns[slot].as_mut() {
            frame::encode_retry_after(&mut c.wbuf, scratch, id, retry_ms);
        }
        self.flush_conn(slot);
    }

    fn reply_error(&mut self, slot: usize, id: u64, e: &Error) {
        let Self { conns, scratch, .. } = self;
        if let Some(c) = conns[slot].as_mut() {
            frame::encode_error(&mut c.wbuf, scratch, id, e);
        }
        self.flush_conn(slot);
    }

    /// Send one best-effort error frame and close: a framing/CRC failure
    /// means the byte stream cannot be resynchronized.
    fn protocol_error(&mut self, slot: usize, e: &Error) {
        self.telemetry.inc(MetricId::ProtocolErrors);
        self.recorder.record(SpanKind::ProtocolError, slot as u64, 0);
        let Self { conns, scratch, .. } = self;
        if let Some(c) = conns[slot].as_mut() {
            frame::encode_error(&mut c.wbuf, scratch, 0, e);
            c.closing = true;
        }
        self.flush_conn(slot);
    }

    fn execute_window(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let rows = self.pending_rows;
        self.stats.window_occupancy.record(rows as f64);
        self.telemetry.inc(MetricId::Batches);
        let t = Timer::start();
        self.lanes.execute(&self.handle, &self.telemetry);
        self.recorder.record(SpanKind::WindowExec, rows as u64, (t.elapsed() * 1e6) as u64);
        let pending = std::mem::take(&mut self.pending);
        for p in &pending {
            let Self { conns, scratch, lanes, gens, telemetry, .. } = &mut *self;
            let alive = conns[p.slot]
                .as_mut()
                .filter(|c| c.gen == gens[p.slot] && c.gen == p.gen && !c.dead);
            let Some(c) = alive else { continue };
            c.inflight = c.inflight.saturating_sub(1);
            match lanes.lane_result(p.want) {
                Ok(resp) => {
                    frame::encode_response_rows(&mut c.wbuf, scratch, p.id, resp, p.start, p.rows);
                    telemetry.inc(MetricId::PredictsServed);
                }
                Err(e) => {
                    frame::encode_error(&mut c.wbuf, scratch, p.id, e);
                }
            }
            self.flush_conn(p.slot);
        }
        self.pending = pending;
        self.pending.clear();
        self.pending_rows = 0;
        self.lanes.reset();
    }

    fn flush_conn(&mut self, slot: usize) {
        let max_write_buf = self.cfg.max_write_buf;
        let Self { conns, poller, telemetry, .. } = self;
        let Some(conn) = conns[slot].as_mut() else { return };
        if conn.dead {
            return;
        }
        while conn.wstart < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wstart..]) {
                Ok(0) => {
                    conn.dead = true;
                    return;
                }
                Ok(n) => conn.wstart += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
        if conn.wstart >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wstart = 0;
            if conn.wants_write {
                conn.wants_write = false;
                let _ = poller.modify(conn.id, slot as u64 + 1, Interest::READ);
            }
            if conn.closing {
                conn.dead = true;
            }
        } else if conn.wbuf.len() - conn.wstart > max_write_buf {
            // slow reader: dropping it bounds reply memory; the client
            // sees a reset and re-resolves
            conn.dead = true;
            telemetry.inc(MetricId::SlowReaderClosed);
        } else if !conn.wants_write {
            conn.wants_write = true;
            let _ = poller.modify(conn.id, slot as u64 + 1, Interest::READ_WRITE);
        }
    }

    fn reap_if_dead(&mut self, slot: usize) {
        let dead = self.conns[slot].as_ref().is_some_and(|c| c.dead);
        if !dead {
            return;
        }
        let conn = self.conns[slot].take().expect("checked above");
        let _ = self.poller.deregister(conn.id, slot as u64 + 1);
        drop(conn);
        self.gens[slot] = self.gens[slot].wrapping_add(1);
        self.free.push(slot);
        self.live.active_conns.fetch_sub(1, Ordering::Relaxed);
        self.recorder.record(SpanKind::ConnClosed, slot as u64, 0);
    }
}
