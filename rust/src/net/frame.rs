//! The wire protocol: length-prefixed, CRC-framed messages built on the
//! [`crate::persist::codec`] section container.
//!
//! Every frame is exactly one persist-codec section
//! `[tag u32][len u64][payload][crc32 u32]` (little-endian, CRC over
//! tag‖len‖payload), so the socket boundary inherits the snapshot/WAL
//! corruption standard for free: a flipped bit anywhere — header
//! included — is detected, and a hostile length is rejected before any
//! allocation. Payloads are the *canonical* serializations of the
//! in-process types ([`PredictRequest`], [`PredictResponse`],
//! [`StreamEvent`]) prefixed with an opaque `id` correlation token the
//! server echoes back; there is no separate network schema to drift.
//!
//! See `serve/mod.rs` §"Network serving and admission control" for the
//! full grammar and the retry-after contract.

use crate::error::{Error, Result};
use crate::persist::codec::{put_u32, put_u64, put_u8, read_section, write_section, Cursor};
use crate::serve::query::{PredictRequest, PredictResponse};
use crate::streaming::StreamEvent;
use crate::telemetry::TelemetrySnapshot;

/// Predict request: `[id u64][PredictRequest]`.
pub const TAG_PREDICT: u32 = u32::from_le_bytes(*b"MKPR");
/// Update (ingest) event: `[id u64][StreamEvent]`.
pub const TAG_UPDATE: u32 = u32::from_le_bytes(*b"MKUP");
/// Predict response: `[id u64][PredictResponse]`.
pub const TAG_RESPONSE: u32 = u32::from_le_bytes(*b"MKRS");
/// Update accepted: `[id u64]`.
pub const TAG_ACK: u32 = u32::from_le_bytes(*b"MKAK");
/// Load-shed: `[id u64][retry_ms u32]` — not admitted, resend later.
pub const TAG_RETRY_AFTER: u32 = u32::from_le_bytes(*b"MKRA");
/// Request failed: `[id u64][transient u8][len u32][utf8 msg]`.
pub const TAG_ERROR: u32 = u32::from_le_bytes(*b"MKER");
/// Stats exposition, both directions: `[id u64][dir u8][snapshot?]`.
/// `dir = 0` is the client's pull (no body); `dir = 1` is the server's
/// reply carrying one canonical [`TelemetrySnapshot`].
pub const TAG_STATS: u32 = u32::from_le_bytes(*b"MKTL");

/// Bytes of section header before the payload (`tag` + `len`).
pub const HEADER_LEN: usize = 12;
/// Trailing CRC bytes.
pub const TRAILER_LEN: usize = 4;

const CTX: &str = "net::frame";

/// One decoded protocol message.
#[derive(Debug)]
pub enum Frame {
    /// Client → server: run a prediction.
    Predict {
        /// Correlation token, echoed back verbatim.
        id: u64,
        /// The request, exactly as the in-process API takes it.
        req: PredictRequest,
    },
    /// Client → server: ingest one observation.
    Update {
        /// Correlation token.
        id: u64,
        /// The event, exactly as the in-process ingest takes it.
        ev: StreamEvent,
    },
    /// Server → client: prediction answer.
    Response {
        /// Echoed correlation token.
        id: u64,
        /// The response, exactly as the in-process API returns it.
        resp: PredictResponse,
    },
    /// Server → client: update admitted into the ingest queue.
    Ack {
        /// Echoed correlation token.
        id: u64,
    },
    /// Server → client: load-shed. The request was NOT admitted and no
    /// state changed; back off `retry_ms` (plus jitter) and resend.
    RetryAfter {
        /// Echoed correlation token (0 when shed before decoding an id).
        id: u64,
        /// Server's backoff hint, milliseconds.
        retry_ms: u32,
    },
    /// Server → client: the request failed.
    Error {
        /// Echoed correlation token (0 for connection-level failures).
        id: u64,
        /// Mirror of [`Error::is_transient`] across the wire: `true`
        /// means a retry of the same frame can plausibly succeed.
        transient: bool,
        /// Human-readable cause.
        msg: String,
    },
    /// Client → server: pull the server's telemetry snapshot. Answering
    /// a pull records nothing — two pulls against an idle server return
    /// byte-identical snapshots.
    StatsPull {
        /// Correlation token, echoed back verbatim.
        id: u64,
    },
    /// Server → client: the merged fleet telemetry snapshot (reactor +
    /// router + every shard registry, plus the flight-recorder tail).
    Stats {
        /// Echoed correlation token.
        id: u64,
        /// The snapshot, exactly as the in-process merge produces it.
        snapshot: TelemetrySnapshot,
    },
}

/// Inspect the start of `buf` for one complete frame without consuming
/// it. `Ok(None)` = incomplete, keep reading; `Ok(Some(total))` = the
/// first `total` bytes hold one whole section; `Err` = the stream is
/// unrecoverable (a declared length over `max_frame_len` means framing
/// can never resynchronize and admission of the frame would unbound the
/// read buffer).
pub fn peek_frame(buf: &[u8], max_frame_len: usize) -> Result<Option<usize>> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let len = u64::from_le_bytes(buf[4..12].try_into().expect("12-byte header"));
    // bound the length from the header ALONE: a hostile 2^60 length must
    // be rejected here, not waited for
    if len > max_frame_len as u64 {
        return Err(Error::persist_corruption(
            CTX,
            format!("frame claims {len} payload bytes, cap is {max_frame_len}"),
        ));
    }
    let total = HEADER_LEN + len as usize + TRAILER_LEN;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some(total))
}

/// Decode one complete frame (exactly the `total` bytes [`peek_frame`]
/// measured). CRC and every payload bound are verified; trailing bytes
/// inside the payload are corruption (no silent slack for a tampered
/// length).
pub fn decode_frame(bytes: &[u8]) -> Result<Frame> {
    let mut cur = Cursor::new(bytes, CTX);
    let (tag, payload) = read_section(&mut cur, CTX)?;
    if !cur.is_empty() {
        return Err(Error::persist_corruption(
            CTX,
            format!("{} stray bytes after frame", cur.remaining()),
        ));
    }
    let mut p = Cursor::new(payload, CTX);
    let id = p.take_u64()?;
    let frame = match tag {
        TAG_PREDICT => Frame::Predict { id, req: PredictRequest::decode_from(&mut p)? },
        TAG_UPDATE => {
            let mut pos = p.pos();
            let ev = StreamEvent::decode_from(payload, &mut pos)?;
            if pos != payload.len() {
                return Err(Error::persist_corruption(
                    CTX,
                    format!("{} stray bytes after update event", payload.len() - pos),
                ));
            }
            return Ok(Frame::Update { id, ev });
        }
        TAG_RESPONSE => Frame::Response { id, resp: PredictResponse::decode_from(&mut p)? },
        TAG_ACK => Frame::Ack { id },
        TAG_RETRY_AFTER => Frame::RetryAfter { id, retry_ms: p.take_u32()? },
        TAG_ERROR => {
            let transient = match p.take_u8()? {
                0 => false,
                1 => true,
                v => {
                    return Err(Error::persist_corruption(
                        CTX,
                        format!("error frame transient flag {v}, expected 0/1"),
                    ))
                }
            };
            let n = p.take_u32()? as usize;
            let msg = String::from_utf8_lossy(p.take_bytes(n)?).into_owned();
            Frame::Error { id, transient, msg }
        }
        TAG_STATS => match p.take_u8()? {
            0 => Frame::StatsPull { id },
            1 => Frame::Stats { id, snapshot: TelemetrySnapshot::decode(&mut p, CTX)? },
            v => {
                return Err(Error::persist_corruption(
                    CTX,
                    format!("stats frame direction {v}, expected 0/1"),
                ))
            }
        },
        other => {
            return Err(Error::persist_corruption(
                CTX,
                format!("unknown frame tag {other:#010x}"),
            ))
        }
    };
    if !p.is_empty() {
        return Err(Error::persist_corruption(
            CTX,
            format!("{} stray bytes in frame payload", p.remaining()),
        ));
    }
    Ok(frame)
}

/// Append a predict frame. `scratch` is a reusable payload staging
/// buffer (cleared here) so warm paths do not allocate per frame.
pub fn encode_predict(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, req: &PredictRequest) {
    scratch.clear();
    put_u64(scratch, id);
    req.encode_into(scratch);
    write_section(out, TAG_PREDICT, scratch);
}

/// Append an update frame.
pub fn encode_update(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, ev: &StreamEvent) {
    scratch.clear();
    put_u64(scratch, id);
    ev.encode_into(scratch);
    write_section(out, TAG_UPDATE, scratch);
}

/// Append a response frame carrying ALL rows of `resp`.
pub fn encode_response(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, resp: &PredictResponse) {
    encode_response_rows(out, scratch, id, resp, 0, resp.mean.rows());
}

/// Append a response frame carrying rows `[start, start + rows)` of a
/// batched response — how the reactor answers each request out of its
/// kind's lane without materializing a per-request response.
pub fn encode_response_rows(
    out: &mut Vec<u8>,
    scratch: &mut Vec<u8>,
    id: u64,
    resp: &PredictResponse,
    start: usize,
    rows: usize,
) {
    scratch.clear();
    put_u64(scratch, id);
    resp.encode_rows_into(scratch, start, rows);
    write_section(out, TAG_RESPONSE, scratch);
}

/// Append an update-admitted ack.
pub fn encode_ack(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64) {
    scratch.clear();
    put_u64(scratch, id);
    write_section(out, TAG_ACK, scratch);
}

/// Append a load-shed answer.
pub fn encode_retry_after(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, retry_ms: u32) {
    scratch.clear();
    put_u64(scratch, id);
    put_u32(scratch, retry_ms);
    write_section(out, TAG_RETRY_AFTER, scratch);
}

/// Append an error answer. `msg` is truncated to `u32::MAX` bytes
/// (practically: never).
pub fn encode_error(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, e: &Error) {
    scratch.clear();
    put_u64(scratch, id);
    put_u8(scratch, e.is_transient() as u8);
    let msg = e.to_string();
    let n = msg.len().min(u32::MAX as usize);
    put_u32(scratch, n as u32);
    scratch.extend_from_slice(&msg.as_bytes()[..n]);
    write_section(out, TAG_ERROR, scratch);
}

/// Append a stats-pull request.
pub fn encode_stats_pull(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64) {
    scratch.clear();
    put_u64(scratch, id);
    put_u8(scratch, 0);
    write_section(out, TAG_STATS, scratch);
}

/// Append a stats reply carrying `snap`'s canonical encoding.
pub fn encode_stats(out: &mut Vec<u8>, scratch: &mut Vec<u8>, id: u64, snap: &TelemetrySnapshot) {
    scratch.clear();
    put_u64(scratch, id);
    put_u8(scratch, 1);
    snap.encode(scratch);
    write_section(out, TAG_STATS, scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::serve::query::QueryKind;

    fn sample_request() -> PredictRequest {
        let x = Mat::from_vec(2, 3, vec![1.0, -0.0, 2.5, 3.0, 4.0, 5.0]).unwrap();
        PredictRequest::new(x, QueryKind::MeanVar)
    }

    fn sample_snapshot() -> TelemetrySnapshot {
        use crate::telemetry::{HistId, MetricId, Registry, SpanEvent, SpanKind};
        let reg = Registry::new();
        reg.add(MetricId::Routed, 7);
        reg.inc(MetricId::Rounds);
        reg.gauge_max(MetricId::MaxBatchRows, 64);
        reg.record_hist(HistId::RoundLatencyUs, 120);
        reg.record_hist(HistId::RoundLatencyUs, 3000);
        let mut snap = reg.snapshot();
        snap.spans.push(SpanEvent { t_us: 5, kind: SpanKind::RoundStart, a: 8, b: 0 });
        snap.spans.push(SpanEvent { t_us: 9, kind: SpanKind::RoundEnd, a: 8, b: 130 });
        snap
    }

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        let req = sample_request();
        encode_predict(&mut buf, &mut scratch, 42, &req);
        let ev = StreamEvent::single(vec![1.0, 2.0, 3.0], 0.5, 3, 7);
        encode_update(&mut buf, &mut scratch, 43, &ev);
        let resp = PredictResponse {
            mean: Mat::from_vec(2, 1, vec![0.25, -1.5]).unwrap(),
            variance: Some(vec![0.1, 0.2]),
        };
        encode_response(&mut buf, &mut scratch, 42, &resp);
        encode_ack(&mut buf, &mut scratch, 43);
        encode_retry_after(&mut buf, &mut scratch, 9, 5);
        encode_error(&mut buf, &mut scratch, 8, &Error::Config("no twin".into()));
        encode_stats_pull(&mut buf, &mut scratch, 11);
        let snap = sample_snapshot();
        encode_stats(&mut buf, &mut scratch, 11, &snap);

        let mut rest = &buf[..];
        let mut frames = Vec::new();
        while !rest.is_empty() {
            let total = peek_frame(rest, 1 << 20).unwrap().expect("complete");
            frames.push(decode_frame(&rest[..total]).unwrap());
            rest = &rest[total..];
        }
        assert_eq!(frames.len(), 8);
        match &frames[0] {
            Frame::Predict { id, req: r } => {
                assert_eq!(*id, 42);
                assert_eq!(r.want, QueryKind::MeanVar);
                assert_eq!(r.x, req.x);
            }
            f => panic!("want Predict, got {f:?}"),
        }
        match &frames[1] {
            Frame::Update { id, ev: e } => {
                assert_eq!(*id, 43);
                assert_eq!(e.seq, ev.seq);
                assert_eq!(e.x, ev.x);
            }
            f => panic!("want Update, got {f:?}"),
        }
        match &frames[2] {
            Frame::Response { id, resp: r } => {
                assert_eq!(*id, 42);
                assert_eq!(*r, resp);
            }
            f => panic!("want Response, got {f:?}"),
        }
        assert!(matches!(frames[3], Frame::Ack { id: 43 }));
        assert!(matches!(frames[4], Frame::RetryAfter { id: 9, retry_ms: 5 }));
        match &frames[5] {
            Frame::Error { id, transient, msg } => {
                assert_eq!(*id, 8);
                assert!(!transient, "Config is permanent");
                assert!(msg.contains("no twin"));
            }
            f => panic!("want Error, got {f:?}"),
        }
        assert!(matches!(frames[6], Frame::StatsPull { id: 11 }));
        match &frames[7] {
            Frame::Stats { id, snapshot } => {
                assert_eq!(*id, 11);
                assert_eq!(*snapshot, snap, "snapshot survives the wire verbatim");
            }
            f => panic!("want Stats, got {f:?}"),
        }
    }

    #[test]
    fn response_rows_slice_matches_block() {
        let resp = PredictResponse {
            mean: Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap(),
            variance: Some(vec![0.1, 0.2, 0.3]),
        };
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        encode_response_rows(&mut buf, &mut scratch, 5, &resp, 1, 2);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        match decode_frame(&buf[..total]).unwrap() {
            Frame::Response { id, resp: r } => {
                assert_eq!(id, 5);
                assert_eq!(r.mean, resp.mean.block(1, 3, 0, 2));
                assert_eq!(r.variance.as_deref(), Some(&[0.2, 0.3][..]));
            }
            f => panic!("want Response, got {f:?}"),
        }
    }

    #[test]
    fn partial_frames_wait_for_more_bytes() {
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        encode_predict(&mut buf, &mut scratch, 1, &sample_request());
        for cut in 0..buf.len() {
            assert_eq!(
                peek_frame(&buf[..cut], 1 << 20).unwrap(),
                None,
                "cut at {cut} should be incomplete"
            );
        }
        assert_eq!(peek_frame(&buf, 1 << 20).unwrap(), Some(buf.len()));
    }

    #[test]
    fn oversize_length_rejected_from_header_alone() {
        let mut buf = Vec::new();
        put_u32(&mut buf, TAG_PREDICT);
        put_u64(&mut buf, u64::MAX / 2); // hostile length, no payload sent
        let e = peek_frame(&buf, 4096).unwrap_err();
        assert!(!e.is_transient(), "oversize framing is permanent: {e:?}");
        // modest-but-over-cap is equally rejected
        let mut buf = Vec::new();
        put_u32(&mut buf, TAG_PREDICT);
        put_u64(&mut buf, 4097);
        assert!(peek_frame(&buf, 4096).is_err());
    }

    #[test]
    fn every_byte_flip_is_rejected() {
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        encode_predict(&mut buf, &mut scratch, 77, &sample_request());
        let total = buf.len();
        for i in 0..total {
            for bit in [0x01u8, 0x80u8] {
                let mut bad = buf.clone();
                bad[i] ^= bit;
                // a flip may corrupt the declared length; peek then
                // decode, either stage must reject (a flip that makes the
                // frame "incomplete" is also a safe outcome at the socket:
                // the reader just waits and eventually times out)
                match peek_frame(&bad, 1 << 20) {
                    Err(_) => {}
                    Ok(None) => {}
                    Ok(Some(t)) => {
                        assert!(
                            decode_frame(&bad[..t]).is_err(),
                            "flip at byte {i} bit {bit:#x} slipped through"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn stray_payload_bytes_are_corruption() {
        // hand-build an ack frame whose payload has 1 stray byte beyond
        // the id, with a VALID crc: structural validation must catch it
        let mut payload = Vec::new();
        put_u64(&mut payload, 3);
        put_u8(&mut payload, 0xEE);
        let mut buf = Vec::new();
        write_section(&mut buf, TAG_ACK, &payload);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        assert!(decode_frame(&buf[..total]).is_err());
    }

    #[test]
    fn stats_frames_are_strict_and_deterministic() {
        let snap = sample_snapshot();
        // deterministic: same snapshot, bitwise-identical frame
        let (mut a, mut b, mut scratch) = (Vec::new(), Vec::new(), Vec::new());
        encode_stats(&mut a, &mut scratch, 3, &snap);
        encode_stats(&mut b, &mut scratch, 3, &snap);
        assert_eq!(a, b, "canonical encoding is unique");
        // every single-bit flip anywhere in the stats frame is caught
        for i in 0..a.len() {
            for bit in [0x01u8, 0x80u8] {
                let mut bad = a.clone();
                bad[i] ^= bit;
                match peek_frame(&bad, 1 << 20) {
                    Err(_) | Ok(None) => {}
                    Ok(Some(t)) => assert!(
                        decode_frame(&bad[..t]).is_err(),
                        "stats flip at byte {i} bit {bit:#x} slipped through"
                    ),
                }
            }
        }
        // hostile direction byte with a valid CRC
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u8(&mut payload, 2);
        let mut buf = Vec::new();
        write_section(&mut buf, TAG_STATS, &payload);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        assert!(decode_frame(&buf[..total]).is_err(), "direction 2 rejected");
        // a pull carrying stray body bytes is corruption, not slack
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u8(&mut payload, 0);
        put_u8(&mut payload, 0xAA);
        let mut buf = Vec::new();
        write_section(&mut buf, TAG_STATS, &payload);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        assert!(decode_frame(&buf[..total]).is_err(), "stray pull bytes rejected");
        // a reply whose snapshot body is truncated mid-histogram fails
        // structurally even with a recomputed CRC
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u8(&mut payload, 1);
        let mut body = Vec::new();
        snap.encode(&mut body);
        payload.extend_from_slice(&body[..body.len() - 3]);
        let mut buf = Vec::new();
        write_section(&mut buf, TAG_STATS, &payload);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        assert!(decode_frame(&buf[..total]).is_err(), "truncated snapshot rejected");
    }

    #[test]
    fn unknown_tag_rejected() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        let mut buf = Vec::new();
        write_section(&mut buf, u32::from_le_bytes(*b"XXXX"), &payload);
        let total = peek_frame(&buf, 1 << 20).unwrap().unwrap();
        assert!(decode_frame(&buf[..total]).is_err());
    }
}
