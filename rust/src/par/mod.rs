//! Data-parallel helpers over a **persistent worker pool** (no external
//! runtime).
//!
//! The offline crate set has no rayon/tokio, so this module provides the
//! minimal parallel substrate the linalg kernels and the streaming pipeline
//! need: a [`parallel_for`] over index ranges and a [`parallel_map`] over
//! slices.
//!
//! # Pool architecture
//!
//! Workers are spawned **once**, on the first multi-threaded call, and live
//! for the rest of the process (`num_threads() - 1` of them; the calling
//! thread always participates as the remaining lane). Each `parallel_for`
//! publishes one stack-allocated job descriptor — a type-erased closure
//! pointer plus per-lane atomic chunk cursors — onto a shared queue, wakes
//! the workers, claims chunks itself, then parks until every worker ticket
//! has drained. A steady-state dispatch performs no heap allocation (the
//! queue's ring buffer is reused across calls).
//!
//! This replaces the per-call `std::thread::scope` spawning of earlier
//! revisions, which cost ~100µs per call — longer than an entire small-J
//! update round. Nested `parallel_for` from inside a worker runs inline
//! (single lane): the pool is flat by design, which both avoids queue
//! deadlock and keeps the thread count bounded by [`num_threads`].
//!
//! # Affinity-stable chunk claiming
//!
//! The index range of a job is split into one contiguous **slot** per
//! active lane, each with its own claim cursor. A lane drains its home
//! slot first — the caller always owns slot 0, worker `w` always prefers
//! slot `1 + w % (slots - 1)` — and only then steals from the other slots
//! in cyclic order. Because the home mapping depends only on the worker's
//! (stable) pool id and the job's lane count, back-to-back dispatches over
//! the same data hand each lane the **same index ranges** every time: the
//! C rows and packed A panels a lane touched in the previous `KC` sweep of
//! the packed GEMM engine are still hot in that lane's private cache when
//! the next sweep dispatches. Uneven bodies still load-balance through the
//! stealing pass, and every index is processed exactly once either way, so
//! results are independent of which lane ran what.
//!
//! # Lane pinning (`MIKRR_PIN`)
//!
//! On Linux (x86_64/aarch64) each spawned worker pins itself to a distinct
//! logical CPU at pool build via a raw `sched_setaffinity` syscall (the
//! crate is dependency-free — no libc). Worker `w` takes core `w + 1`,
//! leaving core 0 to the (unpinned) caller lane; on standard Linux
//! enumerations the resulting contiguous low core ids keep the pool on one
//! socket / shared LLC, which is what keeps the affinity-stable slot
//! claiming above cache-effective across dispatches. When the host has
//! fewer CPUs than lanes, pinning is skipped (doubling threads up on a
//! core would be worse than the scheduler). `MIKRR_PIN=0` (or
//! `off`/`false`) disables pinning — use it on oversubscribed or shared
//! hosts; elsewhere the syscall shim is a no-op and the pool behaves as
//! before. Pinning is best-effort: a rejected mask (e.g. a cgroup cpuset)
//! is silently ignored.
//!
//! The lane count **and** the pin map are computed together, once, and
//! frozen before the first dispatch ([`num_threads`] caches the shared
//! geometry): changing `MIKRR_THREADS` or `MIKRR_PIN` mid-process can
//! never desync chunk claiming from the pinned cores
//! (`rust/tests/pool_pinning.rs` pins this down).
//!
//! Both sides of the handshake use a **spin-then-park backoff**: an idle
//! worker first busy-polls the queue-length counter for [`SPIN_ITERS`]
//! pause cycles before parking on the condvar, and a dispatching caller
//! likewise spins briefly before `thread::park`. Back-to-back sub-100µs
//! dispatches (the skinny update shapes of a small-J round) therefore hand
//! work over without a futex wake per call; a pool that goes quiet parks
//! within tens of microseconds and burns nothing.
//!
//! `MIKRR_THREADS=1` (or a single-core host) means the pool is never built
//! and every call runs inline on the caller — the allocation-free path the
//! engines' zero-allocation contract is measured on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Hard ceiling on pool lanes (caller + workers): past this, queue
/// contention and memory-bandwidth saturation outweigh extra cores for the
/// matrix sizes this system runs (J up to 2024).
pub const MAX_THREADS: usize = 16;

/// The pool's frozen shape: lane count plus the per-worker pin map, read
/// from the environment **once** and never recomputed — so a mid-process
/// `MIKRR_THREADS`/`MIKRR_PIN` change cannot desync chunk claiming from
/// the pinned cores.
struct Geometry {
    /// Parallel lanes (caller + spawned workers), capped by [`MAX_THREADS`].
    lanes: usize,
    /// Pin target (logical CPU id) for spawned worker `w`; empty when
    /// pinning is disabled (`MIKRR_PIN=0`), unsupported on this platform,
    /// or the pool is single-lane.
    pin: Vec<usize>,
}

fn geometry() -> &'static Geometry {
    static GEO: OnceLock<Geometry> = OnceLock::new();
    GEO.get_or_init(|| {
        let lanes = std::env::var("MIKRR_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
            .min(MAX_THREADS);
        let pin = if lanes > 1 && affinity::SUPPORTED && pin_requested() {
            let ncpu = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            build_pin_map(lanes, ncpu)
        } else {
            Vec::new()
        };
        Geometry { lanes, pin }
    })
}

/// `MIKRR_PIN` gate: pinning defaults **on** where supported; `0`, `off`,
/// or `false` disables it.
fn pin_requested() -> bool {
    !matches!(
        std::env::var("MIKRR_PIN").as_deref(),
        Ok("0") | Ok("off") | Ok("false")
    )
}

/// Worker → logical-CPU map: worker `w` takes core `w + 1`, leaving core
/// 0 with the (unpinned) caller lane — every pinned worker gets its own
/// core. When the host has fewer CPUs than lanes (an oversized
/// `MIKRR_THREADS` override), pinning is skipped entirely: hard-affining
/// two compute threads to one core would be strictly worse than letting
/// the scheduler balance them.
fn build_pin_map(lanes: usize, ncpu: usize) -> Vec<usize> {
    if ncpu < 2 || lanes > ncpu {
        return Vec::new();
    }
    (0..lanes - 1).map(|w| w + 1).collect()
}

/// Number of parallel lanes to use: `MIKRR_THREADS` env override, else
/// available parallelism — the [`MAX_THREADS`] cap applies to both, so an
/// oversized override cannot oversubscribe the pool.
///
/// The value is computed once — together with the [`pinned_lanes`] pin
/// map — and cached for the life of the process: changing `MIKRR_THREADS`
/// (or `MIKRR_PIN`) after the first parallel call has no effect, and the
/// worker pool (sized from this value) is never resized. Set them before
/// touching any parallel code path (tests that need the single-threaded
/// path set the override at process start).
pub fn num_threads() -> usize {
    geometry().lanes
}

/// Number of pool workers with a pinned core (0 when pinning is disabled
/// via `MIKRR_PIN=0`, unsupported on this platform, or the pool is
/// single-lane). Frozen together with [`num_threads`] on first use.
pub fn pinned_lanes() -> usize {
    geometry().pin.len()
}

/// Best-effort thread→core pinning via a raw `sched_setaffinity` syscall
/// (the offline crate set has no libc). Linux x86_64/aarch64 only; the
/// fallback module below makes every other target a no-op.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod affinity {
    pub(super) const SUPPORTED: bool = true;

    /// `cpu_set_t` is 1024 bits in the kernel ABI.
    const CPU_SET_WORDS: usize = 16;

    #[cfg(target_arch = "x86_64")]
    const SYS_SCHED_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "aarch64")]
    const SYS_SCHED_SETAFFINITY: usize = 122;

    /// Pin the calling thread to `cpu`. Errors are deliberately ignored
    /// (the mask may fall outside the process's cgroup cpuset): pinning is
    /// a performance hint, never a correctness requirement.
    pub(super) fn pin_current_thread(cpu: usize) {
        if cpu >= CPU_SET_WORDS * 64 {
            return;
        }
        let mut mask = [0u64; CPU_SET_WORDS];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        // SAFETY: the syscall reads `mask` (alive for the duration of the
        // call) and only mutates scheduler state; pid 0 = calling thread.
        unsafe {
            syscall3(
                SYS_SCHED_SETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_ptr() as usize,
            );
        }
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall3(n: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall3(n: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            options(nostack),
        );
        ret
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod affinity {
    pub(super) const SUPPORTED: bool = false;

    pub(super) fn pin_current_thread(_cpu: usize) {}
}

/// Dynamic chunking granularity: chunks per lane. >1 so uneven bodies
/// (e.g. triangular updates) load-balance; small enough that the atomic
/// cursors are uncontended relative to chunk work.
const CHUNKS_PER_LANE: usize = 4;

/// Busy-poll iterations before an idle lane falls back to blocking
/// (worker: condvar wait; caller: `thread::park`). One iteration is an
/// atomic load plus a `spin_loop` hint — the budget covers a few tens of
/// microseconds, which spans the inter-dispatch gap of the small-J update
/// rounds without noticeably occupying a core when the pool goes idle.
const SPIN_ITERS: usize = 1 << 14;

/// One dispatched `parallel_for`, shared between the caller and the pool.
/// Lives on the caller's stack for the duration of the call; the caller
/// blocks until `pending` reaches zero, which is what makes the lifetime
/// erasure in [`parallel_for`] sound.
struct JobShared {
    /// Type-erased `&body` (caller lifetime transmuted away).
    body: *const (dyn Fn(usize, usize) + Sync),
    /// Exclusive end of the index range.
    n: usize,
    /// Chunk granularity for the cursors.
    chunk: usize,
    /// Active lane slots for this job (helpers + the caller).
    slots: usize,
    /// Indices per slot (chunk-aligned); the last slot clips to `n`.
    span: usize,
    /// Per-slot claim cursors (offsets within the slot's span). Slot `s`
    /// owns indices `[s·span, min((s+1)·span, n))`; lanes drain their home
    /// slot first and steal the rest (see the module docs).
    cursors: [AtomicUsize; MAX_THREADS],
    /// Worker tickets not yet fully processed.
    pending: AtomicUsize,
    /// Set when any lane's body panicked; remaining lanes stop claiming
    /// and the caller re-panics after the tickets drain.
    panicked: AtomicBool,
    /// Caller to unpark when the last ticket drains.
    caller: std::thread::Thread,
}

// SAFETY: all mutation goes through the atomics; `body` is only called
// (never mutated) and points at a `Sync` closure.
unsafe impl Sync for JobShared {}

/// A queued reference to a [`JobShared`], sendable to workers. The pointee
/// outlives the ticket: the publishing caller blocks until `pending` hits
/// zero, and workers never touch the job after their decrement.
#[derive(Clone, Copy)]
struct Ticket(*const JobShared);
unsafe impl Send for Ticket {}

struct PoolShared {
    queue: Mutex<VecDeque<Ticket>>,
    available: Condvar,
    /// Tickets currently queued (kept in sync under the queue lock): lets
    /// idle workers spin-poll for work without touching the mutex.
    queued: AtomicUsize,
}

struct Pool {
    shared: &'static PoolShared,
    /// Cached lane count (spawned workers + the caller), frozen at build
    /// time so a dispatch never re-derives it from the environment.
    lanes: usize,
}

thread_local! {
    /// This thread's pool worker id (`usize::MAX` = not a pool worker).
    /// Doubles as the stable key for affinity-stable home-slot selection.
    static POOL_LANE: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

fn in_pool_worker() -> bool {
    POOL_LANE.with(|f| f.get()) != usize::MAX
}

/// The home slot this thread drains first for a job with `slots` active
/// lanes: the caller owns slot 0; worker `w` prefers `1 + w % (slots - 1)`
/// — stable per worker, so repeat dispatches re-touch the same indices.
fn home_slot(slots: usize) -> usize {
    let id = POOL_LANE.with(|f| f.get());
    if id == usize::MAX || slots <= 1 {
        0
    } else {
        1 + id % (slots - 1)
    }
}

/// The process-wide pool, built lazily on the first multi-threaded call.
/// `None` when `num_threads() == 1` (no workers to spawn).
fn pool() -> Option<&'static Pool> {
    static POOL: OnceLock<Option<Pool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let geo = geometry();
        let workers = geo.lanes.saturating_sub(1);
        if workers == 0 {
            return None;
        }
        let shared: &'static PoolShared = Box::leak(Box::new(PoolShared {
            queue: Mutex::new(VecDeque::with_capacity(4 * workers)),
            available: Condvar::new(),
            queued: AtomicUsize::new(0),
        }));
        for w in 0..workers {
            let pin = geo.pin.get(w).copied();
            std::thread::Builder::new()
                .name(format!("mikrr-worker-{w}"))
                .spawn(move || worker_loop(shared, w, pin))
                .expect("failed to spawn mikrr pool worker");
        }
        Some(Pool { shared, lanes: workers + 1 })
    })
    .as_ref()
}

/// Claim the next ticket: spin-poll the queue-length counter first (a
/// sub-100µs dispatch cadence is served without futex traffic), then park
/// on the condvar.
fn next_ticket(shared: &'static PoolShared) -> Ticket {
    for _ in 0..SPIN_ITERS {
        if shared.queued.load(Ordering::Acquire) > 0 {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            if let Some(t) = q.pop_front() {
                shared.queued.fetch_sub(1, Ordering::Relaxed);
                return t;
            }
            // another lane won the race: keep spinning
        }
        std::hint::spin_loop();
    }
    let mut q = shared.queue.lock().expect("pool queue poisoned");
    loop {
        if let Some(t) = q.pop_front() {
            shared.queued.fetch_sub(1, Ordering::Relaxed);
            return t;
        }
        q = shared.available.wait(q).expect("pool queue poisoned");
    }
}

fn worker_loop(shared: &'static PoolShared, id: usize, pin: Option<usize>) {
    POOL_LANE.with(|f| f.set(id));
    if let Some(cpu) = pin {
        affinity::pin_current_thread(cpu);
    }
    loop {
        let ticket = next_ticket(shared);
        // SAFETY: the publishing caller keeps the JobShared alive until
        // `pending` reaches zero; we decrement only after the last access.
        let job = unsafe { &*ticket.0 };
        // Contain body panics: the worker must survive (it serves every
        // future job) and the ticket must still drain or the caller would
        // park forever. The caller re-raises after the drain; the original
        // message has already gone through the panic hook to stderr.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_chunks(job, home_slot(job.slots))
        }));
        if outcome.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        // Clone the (Arc-backed) handle BEFORE the decrement: the moment
        // `pending` hits zero the caller may return and pop its stack frame.
        let caller = job.caller.clone();
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            caller.unpark();
        }
    }
}

/// Claim and run chunks until every slot is exhausted (or another lane
/// panicked — no point finishing a doomed job): the home slot first, then
/// the remaining slots in cyclic order (work stealing).
fn run_chunks(job: &JobShared, home: usize) {
    // SAFETY: `body` outlives the job (see `parallel_for`).
    let body = unsafe { &*job.body };
    'slots: for off in 0..job.slots {
        let s = (home + off) % job.slots;
        let base = s * job.span;
        let end = ((s + 1) * job.span).min(job.n);
        if base >= end {
            continue;
        }
        loop {
            if job.panicked.load(Ordering::Relaxed) {
                break 'slots;
            }
            let start = base + job.cursors[s].fetch_add(job.chunk, Ordering::Relaxed);
            if start >= end {
                break;
            }
            body(start, (start + job.chunk).min(end));
        }
    }
}

/// Run `body(chunk_start, chunk_end)` in parallel over `0..n`, splitting
/// into contiguous chunks claimed slot-first by the pool workers and the
/// calling thread (see the module docs for the affinity-stable claiming
/// scheme). `body` must be `Sync` (it is shared). Falls back to a single
/// inline call when `n < min_parallel`, only 1 lane is configured, or the
/// caller is itself a pool worker (no nested parallelism).
pub fn parallel_for<F>(n: usize, min_parallel: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    if num_threads() <= 1 || n < min_parallel || in_pool_worker() {
        body(0, n);
        return;
    }
    let Some(pool) = pool() else {
        body(0, n);
        return;
    };
    // Never queue more tickets than there are chunks to claim.
    let helpers = (pool.lanes - 1).min(n.saturating_sub(1));
    if helpers == 0 {
        body(0, n);
        return;
    }
    // active lanes for this call: the helpers plus the caller (fewer than
    // pool.lanes when n is small)
    let lanes = helpers + 1;
    let chunk = n.div_ceil(lanes * CHUNKS_PER_LANE).max(1);
    // chunk-aligned slot width; span·lanes >= n, so every index has a slot
    let span = n.div_ceil(lanes).div_ceil(chunk) * chunk;
    let body_ref: &(dyn Fn(usize, usize) + Sync) = &body;
    // SAFETY: we erase the borrow's lifetime to store it in JobShared, and
    // re-establish soundness by blocking below until every ticket has been
    // consumed — no worker can touch `body` after this function returns.
    let body_erased: *const (dyn Fn(usize, usize) + Sync) =
        unsafe { std::mem::transmute(body_ref) };
    let job = JobShared {
        body: body_erased,
        n,
        chunk,
        slots: lanes,
        span,
        cursors: [const { AtomicUsize::new(0) }; MAX_THREADS],
        pending: AtomicUsize::new(helpers),
        panicked: AtomicBool::new(false),
        caller: std::thread::current(),
    };
    {
        let mut q = pool.shared.queue.lock().expect("pool queue poisoned");
        for _ in 0..helpers {
            q.push_back(Ticket(&job));
        }
        // publish the new length while still holding the lock: spinning
        // workers see it immediately, parked ones get the notify below
        pool.shared.queued.fetch_add(helpers, Ordering::Release);
    }
    pool.shared.available.notify_all();
    // The caller is a full lane (home slot 0): claim chunks alongside the
    // workers. A panic here must still wait for the tickets to drain —
    // workers hold pointers into this stack frame — so catch, drain, then
    // re-raise.
    let outcome =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_chunks(&job, 0)));
    if outcome.is_err() {
        job.panicked.store(true, Ordering::Release);
    }
    // Wait for every ticket to drain. The Acquire load pairs with the
    // workers' AcqRel decrement, making their body writes visible here.
    // Spin first — the tail of a small dispatch drains in microseconds —
    // then park. `park` can wake spuriously (or from a stale token), hence
    // the loop.
    let mut spins = 0usize;
    while job.pending.load(Ordering::Acquire) != 0 {
        if spins < SPIN_ITERS {
            spins += 1;
            std::hint::spin_loop();
        } else {
            std::thread::park();
        }
    }
    if let Err(payload) = outcome {
        std::panic::resume_unwind(payload);
    }
    if job.panicked.load(Ordering::Acquire) {
        panic!("parallel_for: a worker lane panicked (original panic above)");
    }
}

/// Parallel map over `0..n` producing a `Vec<T>`; `f(i)` must be independent
/// per index.  Order is preserved.
pub fn parallel_map<T, F>(n: usize, min_parallel: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for(n, min_parallel, |lo, hi| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in lo..hi {
                // SAFETY: chunks are disjoint index ranges, each index is
                // written exactly once, and `out` outlives the call.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Raw-pointer wrapper that is Send+Copy; safe because `parallel_for` chunks
/// are disjoint. Crate-visible: the LU panel's per-slot pivot reduction
/// (`linalg::solve`) uses it for its stack-resident partial-maxima array.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 10_000;
        let counter = AtomicU64::new(0);
        parallel_for(n, 1, |lo, hi| {
            for i in lo..hi {
                counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
            }
        });
        let expect: u64 = (1..=n as u64).sum();
        assert_eq!(counter.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn slot_partition_covers_ragged_sizes() {
        // exercise the per-slot cursors + stealing across sizes that leave
        // empty or clipped slots (n barely over the lane count, primes,
        // exact chunk multiples)
        for n in [1usize, 2, 3, 5, 17, 63, 64, 65, 257, 1000] {
            let counter = AtomicU64::new(0);
            parallel_for(n, 1, |lo, hi| {
                for i in lo..hi {
                    counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
                }
            });
            let expect: u64 = (1..=n as u64).sum();
            assert_eq!(counter.load(Ordering::Relaxed), expect, "n={n}");
        }
    }

    #[test]
    fn stealing_balances_uneven_bodies() {
        // front-loaded cost: the first slot's chunks are ~100x the rest, so
        // completion requires the other lanes to steal into slot 0's range
        let n = 4_096;
        let counter = AtomicU64::new(0);
        parallel_for(n, 1, |lo, hi| {
            for i in lo..hi {
                let reps = if i < 256 { 100 } else { 1 };
                let mut acc = 0u64;
                for r in 0..reps {
                    acc = acc.wrapping_add(std::hint::black_box(i as u64 + r));
                }
                std::hint::black_box(acc);
                counter.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn small_n_inline() {
        let hit = AtomicU64::new(0);
        parallel_for(3, 1000, |lo, hi| {
            assert_eq!((lo, hi), (0, 3));
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, 1, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn zero_n() {
        parallel_for(0, 1, |_, _| panic!("must not run"));
        let v: Vec<usize> = parallel_map(0, 1, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn num_threads_capped_and_stable() {
        // regression: the MIKRR_THREADS override used to bypass the cap
        let n = num_threads();
        assert!((1..=MAX_THREADS).contains(&n), "n={n}");
        // cached: later calls return the same value
        assert_eq!(num_threads(), n);
        // the pin map is frozen with the lane count and never exceeds the
        // worker count
        let pinned = pinned_lanes();
        assert!(pinned <= n.saturating_sub(1));
        // cached: later calls return the same value
        assert_eq!(pinned_lanes(), pinned);
    }

    #[test]
    fn pin_map_assigns_distinct_worker_cores() {
        // enough CPUs: every worker gets its own core, none takes core 0
        let map = build_pin_map(5, 8);
        assert_eq!(map, vec![1, 2, 3, 4]);
        // more lanes than CPUs: pinning would double up cores — skip it
        assert!(build_pin_map(6, 4).is_empty());
        // single-CPU host: nothing to pin
        assert!(build_pin_map(4, 1).is_empty());
    }

    #[test]
    fn pool_survives_many_dispatches() {
        // the pool is persistent: thousands of small dispatches must all
        // complete and produce exact results (exercises ticket reuse and
        // the park/unpark handshake under churn)
        for round in 0..2_000u64 {
            let counter = AtomicU64::new(0);
            parallel_for(64, 1, |lo, hi| {
                counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), 64, "round {round}");
        }
    }

    #[test]
    fn nested_parallel_for_runs_inline_and_completes() {
        // nested calls from pool workers must not deadlock: the inner call
        // runs inline on whichever lane executes the outer body
        let counter = AtomicU64::new(0);
        parallel_for(32, 1, |lo, hi| {
            for _ in lo..hi {
                parallel_for(10, 1, |ilo, ihi| {
                    counter.fetch_add((ihi - ilo) as u64, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 320);
    }

    #[test]
    fn body_panic_propagates_and_pool_survives() {
        // a panicking body must surface to the caller (as with the old
        // scoped spawns) without wedging or killing the persistent pool
        let r = std::panic::catch_unwind(|| {
            parallel_for(1024, 1, |lo, _| {
                if lo == 0 {
                    panic!("deliberate test panic in parallel body");
                }
            });
        });
        assert!(r.is_err(), "panic did not propagate");
        // the pool must still serve jobs afterwards
        let counter = AtomicU64::new(0);
        parallel_for(256, 1, |lo, hi| {
            counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 256);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        // multiple user threads dispatching at once: jobs interleave on the
        // shared queue and every caller sees its own exact result
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let counter = AtomicU64::new(0);
                    for _ in 0..200 {
                        parallel_for(128, 1, |lo, hi| {
                            counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
                        });
                    }
                    assert_eq!(counter.load(Ordering::Relaxed), 200 * 128, "caller {t}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
