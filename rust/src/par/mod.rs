//! Data-parallel helpers over a **persistent worker pool** (no external
//! runtime).
//!
//! The offline crate set has no rayon/tokio, so this module provides the
//! minimal parallel substrate the linalg kernels and the streaming pipeline
//! need: a [`parallel_for`] over index ranges and a [`parallel_map`] over
//! slices.
//!
//! # Pool architecture
//!
//! Workers are spawned **once**, on the first multi-threaded call, and live
//! for the rest of the process (`num_threads() - 1` of them; the calling
//! thread always participates as the remaining lane). Each `parallel_for`
//! publishes one stack-allocated job descriptor — a type-erased closure
//! pointer plus an atomic chunk cursor — onto a shared queue, wakes the
//! workers, claims chunks itself, then parks until every worker ticket has
//! drained. Chunks are claimed dynamically (`fetch_add` on the cursor) so
//! uneven bodies load-balance, and a steady-state dispatch performs no heap
//! allocation (the queue's ring buffer is reused across calls).
//!
//! This replaces the per-call `std::thread::scope` spawning of earlier
//! revisions, which cost ~100µs per call — longer than an entire small-J
//! update round. Nested `parallel_for` from inside a worker runs inline
//! (single lane): the pool is flat by design, which both avoids queue
//! deadlock and keeps the thread count bounded by [`num_threads`].
//!
//! Both sides of the handshake use a **spin-then-park backoff**: an idle
//! worker first busy-polls the queue-length counter for [`SPIN_ITERS`]
//! pause cycles before parking on the condvar, and a dispatching caller
//! likewise spins briefly before `thread::park`. Back-to-back sub-100µs
//! dispatches (the skinny update shapes of a small-J round) therefore hand
//! work over without a futex wake per call; a pool that goes quiet parks
//! within tens of microseconds and burns nothing. The lane count itself is
//! computed once ([`num_threads`] caches it) and frozen into the pool at
//! build time.
//!
//! `MIKRR_THREADS=1` (or a single-core host) means the pool is never built
//! and every call runs inline on the caller — the allocation-free path the
//! engines' zero-allocation contract is measured on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Hard ceiling on pool lanes (caller + workers): past this, queue
/// contention and memory-bandwidth saturation outweigh extra cores for the
/// matrix sizes this system runs (J up to 2024).
pub const MAX_THREADS: usize = 16;

/// Number of parallel lanes to use: `MIKRR_THREADS` env override, else
/// available parallelism — the [`MAX_THREADS`] cap applies to both, so an
/// oversized override cannot oversubscribe the pool.
///
/// The value is computed once and cached for the life of the process:
/// changing `MIKRR_THREADS` after the first parallel call has no effect,
/// and the worker pool (sized from this value) is never resized. Set it
/// before touching any parallel code path (tests that need the
/// single-threaded path set it at process start).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("MIKRR_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(MAX_THREADS);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Dynamic chunking granularity: chunks per lane. >1 so uneven bodies
/// (e.g. triangular updates) load-balance; small enough that the atomic
/// cursor is uncontended relative to chunk work.
const CHUNKS_PER_LANE: usize = 4;

/// Busy-poll iterations before an idle lane falls back to blocking
/// (worker: condvar wait; caller: `thread::park`). One iteration is an
/// atomic load plus a `spin_loop` hint — the budget covers a few tens of
/// microseconds, which spans the inter-dispatch gap of the small-J update
/// rounds without noticeably occupying a core when the pool goes idle.
const SPIN_ITERS: usize = 1 << 14;

/// One dispatched `parallel_for`, shared between the caller and the pool.
/// Lives on the caller's stack for the duration of the call; the caller
/// blocks until `pending` reaches zero, which is what makes the lifetime
/// erasure in [`parallel_for`] sound.
struct JobShared {
    /// Type-erased `&body` (caller lifetime transmuted away).
    body: *const (dyn Fn(usize, usize) + Sync),
    /// Next unclaimed index.
    next: AtomicUsize,
    /// Exclusive end of the index range.
    n: usize,
    /// Chunk granularity for the cursor.
    chunk: usize,
    /// Worker tickets not yet fully processed.
    pending: AtomicUsize,
    /// Set when any lane's body panicked; remaining lanes stop claiming
    /// and the caller re-panics after the tickets drain.
    panicked: AtomicBool,
    /// Caller to unpark when the last ticket drains.
    caller: std::thread::Thread,
}

// SAFETY: all mutation goes through the atomics; `body` is only called
// (never mutated) and points at a `Sync` closure.
unsafe impl Sync for JobShared {}

/// A queued reference to a [`JobShared`], sendable to workers. The pointee
/// outlives the ticket: the publishing caller blocks until `pending` hits
/// zero, and workers never touch the job after their decrement.
#[derive(Clone, Copy)]
struct Ticket(*const JobShared);
unsafe impl Send for Ticket {}

struct PoolShared {
    queue: Mutex<VecDeque<Ticket>>,
    available: Condvar,
    /// Tickets currently queued (kept in sync under the queue lock): lets
    /// idle workers spin-poll for work without touching the mutex.
    queued: AtomicUsize,
}

struct Pool {
    shared: &'static PoolShared,
    /// Cached lane count (spawned workers + the caller), frozen at build
    /// time so a dispatch never re-derives it from the environment.
    lanes: usize,
}

thread_local! {
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_pool_worker() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

/// The process-wide pool, built lazily on the first multi-threaded call.
/// `None` when `num_threads() == 1` (no workers to spawn).
fn pool() -> Option<&'static Pool> {
    static POOL: OnceLock<Option<Pool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = num_threads().saturating_sub(1);
        if workers == 0 {
            return None;
        }
        let shared: &'static PoolShared = Box::leak(Box::new(PoolShared {
            queue: Mutex::new(VecDeque::with_capacity(4 * workers)),
            available: Condvar::new(),
            queued: AtomicUsize::new(0),
        }));
        for w in 0..workers {
            std::thread::Builder::new()
                .name(format!("mikrr-worker-{w}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn mikrr pool worker");
        }
        Some(Pool { shared, lanes: workers + 1 })
    })
    .as_ref()
}

/// Claim the next ticket: spin-poll the queue-length counter first (a
/// sub-100µs dispatch cadence is served without futex traffic), then park
/// on the condvar.
fn next_ticket(shared: &'static PoolShared) -> Ticket {
    for _ in 0..SPIN_ITERS {
        if shared.queued.load(Ordering::Acquire) > 0 {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            if let Some(t) = q.pop_front() {
                shared.queued.fetch_sub(1, Ordering::Relaxed);
                return t;
            }
            // another lane won the race: keep spinning
        }
        std::hint::spin_loop();
    }
    let mut q = shared.queue.lock().expect("pool queue poisoned");
    loop {
        if let Some(t) = q.pop_front() {
            shared.queued.fetch_sub(1, Ordering::Relaxed);
            return t;
        }
        q = shared.available.wait(q).expect("pool queue poisoned");
    }
}

fn worker_loop(shared: &'static PoolShared) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let ticket = next_ticket(shared);
        // SAFETY: the publishing caller keeps the JobShared alive until
        // `pending` reaches zero; we decrement only after the last access.
        let job = unsafe { &*ticket.0 };
        // Contain body panics: the worker must survive (it serves every
        // future job) and the ticket must still drain or the caller would
        // park forever. The caller re-raises after the drain; the original
        // message has already gone through the panic hook to stderr.
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_chunks(job)));
        if outcome.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        // Clone the (Arc-backed) handle BEFORE the decrement: the moment
        // `pending` hits zero the caller may return and pop its stack frame.
        let caller = job.caller.clone();
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            caller.unpark();
        }
    }
}

/// Claim and run chunks until the cursor is exhausted (or another lane
/// panicked — no point finishing a doomed job).
fn run_chunks(job: &JobShared) {
    // SAFETY: `body` outlives the job (see `parallel_for`).
    let body = unsafe { &*job.body };
    loop {
        if job.panicked.load(Ordering::Relaxed) {
            break;
        }
        let start = job.next.fetch_add(job.chunk, Ordering::Relaxed);
        if start >= job.n {
            break;
        }
        let end = (start + job.chunk).min(job.n);
        body(start, end);
    }
}

/// Run `body(chunk_start, chunk_end)` in parallel over `0..n`, splitting
/// into contiguous chunks claimed dynamically by the pool workers and the
/// calling thread. `body` must be `Sync` (it is shared). Falls back to a
/// single inline call when `n < min_parallel`, only 1 lane is configured,
/// or the caller is itself a pool worker (no nested parallelism).
pub fn parallel_for<F>(n: usize, min_parallel: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    if num_threads() <= 1 || n < min_parallel || in_pool_worker() {
        body(0, n);
        return;
    }
    let Some(pool) = pool() else {
        body(0, n);
        return;
    };
    // Never queue more tickets than there are chunks to claim.
    let helpers = (pool.lanes - 1).min(n.saturating_sub(1));
    if helpers == 0 {
        body(0, n);
        return;
    }
    // active lanes for this call: the helpers plus the caller (fewer than
    // pool.lanes when n is small)
    let lanes = helpers + 1;
    let chunk = n.div_ceil(lanes * CHUNKS_PER_LANE).max(1);
    let body_ref: &(dyn Fn(usize, usize) + Sync) = &body;
    // SAFETY: we erase the borrow's lifetime to store it in JobShared, and
    // re-establish soundness by blocking below until every ticket has been
    // consumed — no worker can touch `body` after this function returns.
    let body_erased: *const (dyn Fn(usize, usize) + Sync) =
        unsafe { std::mem::transmute(body_ref) };
    let job = JobShared {
        body: body_erased,
        next: AtomicUsize::new(0),
        n,
        chunk,
        pending: AtomicUsize::new(helpers),
        panicked: AtomicBool::new(false),
        caller: std::thread::current(),
    };
    {
        let mut q = pool.shared.queue.lock().expect("pool queue poisoned");
        for _ in 0..helpers {
            q.push_back(Ticket(&job));
        }
        // publish the new length while still holding the lock: spinning
        // workers see it immediately, parked ones get the notify below
        pool.shared.queued.fetch_add(helpers, Ordering::Release);
    }
    pool.shared.available.notify_all();
    // The caller is a full lane: claim chunks alongside the workers. A
    // panic here must still wait for the tickets to drain — workers hold
    // pointers into this stack frame — so catch, drain, then re-raise.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_chunks(&job)));
    if outcome.is_err() {
        job.panicked.store(true, Ordering::Release);
    }
    // Wait for every ticket to drain. The Acquire load pairs with the
    // workers' AcqRel decrement, making their body writes visible here.
    // Spin first — the tail of a small dispatch drains in microseconds —
    // then park. `park` can wake spuriously (or from a stale token), hence
    // the loop.
    let mut spins = 0usize;
    while job.pending.load(Ordering::Acquire) != 0 {
        if spins < SPIN_ITERS {
            spins += 1;
            std::hint::spin_loop();
        } else {
            std::thread::park();
        }
    }
    if let Err(payload) = outcome {
        std::panic::resume_unwind(payload);
    }
    if job.panicked.load(Ordering::Acquire) {
        panic!("parallel_for: a worker lane panicked (original panic above)");
    }
}

/// Parallel map over `0..n` producing a `Vec<T>`; `f(i)` must be independent
/// per index.  Order is preserved.
pub fn parallel_map<T, F>(n: usize, min_parallel: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for(n, min_parallel, |lo, hi| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in lo..hi {
                // SAFETY: chunks are disjoint index ranges, each index is
                // written exactly once, and `out` outlives the call.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Raw-pointer wrapper that is Send+Copy; safe because `parallel_for` chunks
/// are disjoint.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 10_000;
        let counter = AtomicU64::new(0);
        parallel_for(n, 1, |lo, hi| {
            for i in lo..hi {
                counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
            }
        });
        let expect: u64 = (1..=n as u64).sum();
        assert_eq!(counter.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn small_n_inline() {
        let hit = AtomicU64::new(0);
        parallel_for(3, 1000, |lo, hi| {
            assert_eq!((lo, hi), (0, 3));
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, 1, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn zero_n() {
        parallel_for(0, 1, |_, _| panic!("must not run"));
        let v: Vec<usize> = parallel_map(0, 1, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn num_threads_capped_and_stable() {
        // regression: the MIKRR_THREADS override used to bypass the cap
        let n = num_threads();
        assert!((1..=MAX_THREADS).contains(&n), "n={n}");
        // cached: later calls return the same value
        assert_eq!(num_threads(), n);
    }

    #[test]
    fn pool_survives_many_dispatches() {
        // the pool is persistent: thousands of small dispatches must all
        // complete and produce exact results (exercises ticket reuse and
        // the park/unpark handshake under churn)
        for round in 0..2_000u64 {
            let counter = AtomicU64::new(0);
            parallel_for(64, 1, |lo, hi| {
                counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), 64, "round {round}");
        }
    }

    #[test]
    fn nested_parallel_for_runs_inline_and_completes() {
        // nested calls from pool workers must not deadlock: the inner call
        // runs inline on whichever lane executes the outer body
        let counter = AtomicU64::new(0);
        parallel_for(32, 1, |lo, hi| {
            for _ in lo..hi {
                parallel_for(10, 1, |ilo, ihi| {
                    counter.fetch_add((ihi - ilo) as u64, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 320);
    }

    #[test]
    fn body_panic_propagates_and_pool_survives() {
        // a panicking body must surface to the caller (as with the old
        // scoped spawns) without wedging or killing the persistent pool
        let r = std::panic::catch_unwind(|| {
            parallel_for(1024, 1, |lo, _| {
                if lo == 0 {
                    panic!("deliberate test panic in parallel body");
                }
            });
        });
        assert!(r.is_err(), "panic did not propagate");
        // the pool must still serve jobs afterwards
        let counter = AtomicU64::new(0);
        parallel_for(256, 1, |lo, hi| {
            counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 256);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        // multiple user threads dispatching at once: jobs interleave on the
        // shared queue and every caller sees its own exact result
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let counter = AtomicU64::new(0);
                    for _ in 0..200 {
                        parallel_for(128, 1, |lo, hi| {
                            counter.fetch_add((hi - lo) as u64, Ordering::Relaxed);
                        });
                    }
                    assert_eq!(counter.load(Ordering::Relaxed), 200 * 128, "caller {t}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
