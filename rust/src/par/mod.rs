//! Scoped data-parallel helpers over `std::thread` (no external runtime).
//!
//! The offline crate set has no rayon/tokio, so this module provides the
//! minimal parallel substrate the linalg kernels and the streaming pipeline
//! need: a `parallel_for` over index ranges with static chunking, and a
//! `parallel_map` over slices.  Threads are spawned per call via
//! `std::thread::scope`; for the matrix sizes in this system (J up to 2024)
//! spawn overhead is amortized by making chunks coarse, and the hot path can
//! opt out below a work threshold.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Hard ceiling on worker threads: past this, the scoped-spawn overhead
/// outweighs the extra cores for the matrix sizes this system runs.
pub const MAX_THREADS: usize = 16;

/// Number of worker threads to use: `MIKRR_THREADS` env override, else
/// available parallelism — the [`MAX_THREADS`] cap applies to both, so an
/// oversized override cannot oversubscribe the scoped-spawn pools.
///
/// The value is computed once and cached for the life of the process:
/// changing `MIKRR_THREADS` after the first parallel call has no effect.
/// Set it before touching any parallel code path (tests that need the
/// single-threaded path set it at process start).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("MIKRR_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(MAX_THREADS);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `body(chunk_start, chunk_end)` in parallel over `0..n`, splitting into
/// contiguous chunks, one per worker.  `body` must be `Sync` (it is shared).
/// Falls back to a single inline call when `n` is small or 1 worker.
pub fn parallel_for<F>(n: usize, min_parallel: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = num_threads();
    if workers <= 1 || n < min_parallel {
        body(0, n);
        return;
    }
    let workers = workers.min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Parallel map over `0..n` producing a `Vec<T>`; `f(i)` must be independent
/// per index.  Order is preserved.
pub fn parallel_map<T, F>(n: usize, min_parallel: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for(n, min_parallel, |lo, hi| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in lo..hi {
                // SAFETY: chunks are disjoint index ranges, each index is
                // written exactly once, and `out` outlives the scope.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Raw-pointer wrapper that is Send+Copy; safe because `parallel_for` chunks
/// are disjoint.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 10_000;
        let counter = AtomicU64::new(0);
        parallel_for(n, 1, |lo, hi| {
            for i in lo..hi {
                counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
            }
        });
        let expect: u64 = (1..=n as u64).sum();
        assert_eq!(counter.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn small_n_inline() {
        let hit = AtomicU64::new(0);
        parallel_for(3, 1000, |lo, hi| {
            assert_eq!((lo, hi), (0, 3));
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, 1, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn zero_n() {
        parallel_for(0, 1, |_, _| panic!("must not run"));
        let v: Vec<usize> = parallel_map(0, 1, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn num_threads_capped_and_stable() {
        // regression: the MIKRR_THREADS override used to bypass the cap
        let n = num_threads();
        assert!((1..=MAX_THREADS).contains(&n), "n={n}");
        // cached: later calls return the same value
        assert_eq!(num_threads(), n);
    }
}
