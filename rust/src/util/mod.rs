//! Small shared utilities: PRNG, statistics helpers, formatting, and the
//! allocation-counting allocator used to verify the zero-allocation
//! contract of the maintained-inverse engines.

pub mod alloc_counter;
pub mod prng;
pub mod stats;

/// Format a duration in seconds with adaptive precision.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// log10 that maps non-positive inputs to a large negative sentinel, matching
/// the paper's log10-seconds reporting without NaNs for sub-resolution times.
pub fn log10_time(seconds: f64) -> f64 {
    if seconds <= 0.0 {
        -9.0
    } else {
        seconds.log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("us"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn log10_guard() {
        assert_eq!(log10_time(0.0), -9.0);
        assert!((log10_time(100.0) - 2.0).abs() < 1e-12);
    }
}
