//! Summary-statistics helpers shared by the bench harness and metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (interpolated for even lengths; 0 for empty).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Min of a slice (+inf for empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Max of a slice (-inf for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }
}
