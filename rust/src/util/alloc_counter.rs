//! A counting global allocator for asserting allocation-freedom.
//!
//! The maintained-inverse engines promise zero heap allocations per
//! steady-state `inc_dec` round (see `linalg::woodbury`'s workspace
//! contract). That promise is only worth having if it is *measured*:
//! binaries that want to verify it install [`CountingAlloc`] as their
//! global allocator and diff [`count`] around the section under test.
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: mikrr::util::alloc_counter::CountingAlloc = CountingAlloc;
//!
//! let before = alloc_counter::count();
//! hot_path();
//! assert_eq!(alloc_counter::count() - before, 0);
//! ```
//!
//! Counts allocation *events* (alloc / realloc / alloc_zeroed), not bytes —
//! for a zero-allocation assertion the event count is the sharper signal.
//! The counter is process-global and monotonic; concurrent threads all
//! bump it, so pin `MIKRR_THREADS=1` (before any parallel call) when
//! asserting exact zeros.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATION_EVENTS: AtomicU64 = AtomicU64::new(0);

/// System allocator wrapper that counts allocation events.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

/// Total allocation events since process start (0 unless [`CountingAlloc`]
/// is installed as the global allocator).
pub fn count() -> u64 {
    ALLOCATION_EVENTS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_is_monotonic() {
        // the lib's test binary does not install the allocator, so we only
        // check the counter API itself
        let a = count();
        let b = count();
        assert!(b >= a);
    }
}
