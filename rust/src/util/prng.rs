//! Deterministic PRNGs (no external crates): SplitMix64 for seeding,
//! Xoshiro256++ for the main stream, Box–Muller Gaussians, shuffles.
//!
//! Every stochastic component of the system (synthetic data, stream
//! arrival, property tests) takes an explicit seed so whole experiment
//! runs are bit-reproducible.

/// SplitMix64 — used to expand a single `u64` seed into the Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality general-purpose generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (zero state is impossible).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free enough for our sizes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k positions become the sample
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
        assert!(t.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
