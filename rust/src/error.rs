//! Typed error domain for the mikrr library.
//!
//! The library surface returns [`Result<T>`]; binaries convert to
//! `anyhow::Error` at the edge (a `From` impl is provided).

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions the library can surface.
#[derive(Debug)]
pub enum Error {
    /// Matrix/vector dimension mismatch: (context, expected, got).
    Shape {
        /// Operation that failed.
        context: &'static str,
        /// Human-readable expected-vs-got description.
        detail: String,
    },
    /// Numerical failure (singular matrix, non-SPD Cholesky pivot, ...).
    Numerical {
        /// Operation that failed.
        context: &'static str,
        /// Diagnostic detail (pivot value, row index, ...).
        detail: String,
    },
    /// The decremental rule's validity condition was violated
    /// (e.g. removing more samples than the residual set, paper §III.B).
    InvalidUpdate(String),
    /// Configuration / CLI errors.
    Config(String),
    /// AOT artifact loading / manifest errors.
    Artifact(String),
    /// PJRT runtime errors (wraps the `xla` crate error).
    Runtime(String),
    /// Streaming pipeline errors (closed channels, poisoned state, ...).
    Stream(String),
    /// I/O.
    Io(std::io::Error),
    /// Durability-layer failures (snapshot / WAL encode-decode, crash-safe
    /// file plumbing — see [`crate::persist`]). Splits into an underlying
    /// [`PersistDetail`] because the recovery path treats the two halves
    /// oppositely: a filesystem error is transient (retry the write), a
    /// checksum violation is permanent (fall back a snapshot generation).
    Persist {
        /// Operation that failed (e.g. `"Wal::append"`).
        context: &'static str,
        /// What went wrong underneath.
        detail: PersistDetail,
    },
}

/// The underlying cause of an [`Error::Persist`].
#[derive(Debug)]
pub enum PersistDetail {
    /// Filesystem failure (open/write/fsync/rename) — environmental, a
    /// retry of the same operation can plausibly succeed.
    Io(std::io::Error),
    /// Checksum / framing / version violation — the bytes themselves are
    /// wrong, so re-reading replays the same failure; recovery must fall
    /// back to an older snapshot generation instead.
    Corruption(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape { context, detail } => {
                write!(f, "shape error in {context}: {detail}")
            }
            Error::Numerical { context, detail } => {
                write!(f, "numerical error in {context}: {detail}")
            }
            Error::InvalidUpdate(d) => write!(f, "invalid incremental update: {d}"),
            Error::Config(d) => write!(f, "config error: {d}"),
            Error::Artifact(d) => write!(f, "artifact error: {d}"),
            Error::Runtime(d) => write!(f, "runtime error: {d}"),
            Error::Stream(d) => write!(f, "stream error: {d}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Persist { context, detail } => match detail {
                PersistDetail::Io(e) => write!(f, "persist error in {context}: io: {e}"),
                PersistDetail::Corruption(d) => {
                    write!(f, "persist error in {context}: corruption: {d}")
                }
            },
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Persist { detail: PersistDetail::Io(e), .. } => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for shape errors.
    pub fn shape(context: &'static str, detail: impl Into<String>) -> Self {
        Error::Shape { context, detail: detail.into() }
    }

    /// Shorthand constructor for numerical errors.
    pub fn numerical(context: &'static str, detail: impl Into<String>) -> Self {
        Error::Numerical { context, detail: detail.into() }
    }

    /// Shorthand constructor for persistence I/O failures (transient).
    pub fn persist_io(context: &'static str, e: std::io::Error) -> Self {
        Error::Persist { context, detail: PersistDetail::Io(e) }
    }

    /// Shorthand constructor for persistence corruption (permanent — the
    /// recovery path falls back a snapshot generation on this).
    pub fn persist_corruption(context: &'static str, detail: impl Into<String>) -> Self {
        Error::Persist { context, detail: PersistDetail::Corruption(detail.into()) }
    }

    /// Transient-vs-permanent classification — the serve-layer supervisor's
    /// retry policy keys off this ([`crate::serve::ShardSupervisor`]).
    ///
    /// *Transient* means a retry of the same operation can plausibly
    /// succeed once conditions change: a numerical failure can clear after
    /// a rollback + self-heal refactorization, and stream / I/O / runtime
    /// failures are environmental. *Permanent* errors are deterministic
    /// functions of the request itself (wrong shape, bad config, an
    /// invalid removal set, a broken artifact) — retrying replays the same
    /// failure, so the supervisor quarantines instead of retrying.
    ///
    /// Persistence errors split by their [`PersistDetail`]: a filesystem
    /// failure is transient (the write can be retried), while checksum
    /// corruption is permanent — re-reading the same bytes fails the same
    /// way, so recovery falls back a snapshot generation instead.
    pub fn is_transient(&self) -> bool {
        match self {
            Error::Numerical { .. } | Error::Stream(_) | Error::Io(_) | Error::Runtime(_) => {
                true
            }
            Error::Persist { detail, .. } => matches!(detail, PersistDetail::Io(_)),
            Error::Shape { .. }
            | Error::InvalidUpdate(_)
            | Error::Config(_)
            | Error::Artifact(_) => false,
        }
    }
}

/// Guard macro: checks a shape/dimension precondition.
#[macro_export]
macro_rules! ensure_shape {
    ($cond:expr, $ctx:literal, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::error::Error::shape($ctx, format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::shape("gemm", "a.cols=3 != b.rows=4");
        assert!(e.to_string().contains("gemm"));
        let e = Error::numerical("cholesky", "pivot -1e-3 at row 5");
        assert!(e.to_string().contains("cholesky"));
        let e = Error::InvalidUpdate("batch larger than residual".into());
        assert!(e.to_string().contains("batch"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn io_source_is_exposed() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        let src = e.source().expect("Io carries a source");
        assert!(src.to_string().contains("gone"));
        assert!(Error::Config("x".into()).source().is_none());
    }

    #[test]
    fn transient_classification() {
        assert!(Error::numerical("woodbury", "singular core").is_transient());
        assert!(Error::Stream("channel closed".into()).is_transient());
        assert!(Error::Runtime("pjrt".into()).is_transient());
        let io: Error = std::io::Error::new(std::io::ErrorKind::TimedOut, "t").into();
        assert!(io.is_transient());
        assert!(!Error::shape("gemm", "3 != 4").is_transient());
        assert!(!Error::InvalidUpdate("remove 9 >= n 5".into()).is_transient());
        assert!(!Error::Config("bad".into()).is_transient());
        assert!(!Error::Artifact("missing manifest".into()).is_transient());
    }

    #[test]
    fn persist_classification_and_display() {
        let io = std::io::Error::new(std::io::ErrorKind::Interrupted, "disk yanked");
        let e = Error::persist_io("Wal::append", io);
        assert!(e.is_transient(), "persist io is retryable");
        assert!(e.to_string().contains("Wal::append"));
        assert!(e.to_string().contains("disk yanked"));
        {
            use std::error::Error as _;
            let src = e.source().expect("persist io carries a source");
            assert!(src.to_string().contains("disk yanked"));
        }
        let c = Error::persist_corruption("snapshot::read", "crc mismatch in section 3");
        assert!(!c.is_transient(), "corruption must fall back a generation, not retry");
        assert!(c.to_string().contains("corruption"));
        assert!(c.to_string().contains("crc mismatch"));
        {
            use std::error::Error as _;
            assert!(c.source().is_none());
        }
    }
}
