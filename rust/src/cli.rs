//! Declarative CLI parsing (clap-lite, no external crates).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! typed accessors with defaults, required flags, and generated help text.
//!
//! ```no_run
//! use mikrr::cli::{App, Arg};
//! let app = App::new("mikrr", "incremental KRR coordinator")
//!     .subcommand(
//!         App::new("serve", "run the streaming coordinator")
//!             .arg(Arg::flag("rounds", "number of stream rounds").default("10")),
//!     );
//! let m = app.parse(std::env::args().skip(1)).unwrap();
//! ```

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// One flag specification.
#[derive(Clone, Debug)]
pub struct Arg {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    required: bool,
    is_switch: bool,
}

impl Arg {
    /// A `--name <value>` flag.
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false, is_switch: false }
    }

    /// A boolean `--name` switch (no value).
    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false, is_switch: true }
    }

    /// Set a default value.
    pub fn default(mut self, v: &str) -> Self {
        self.default = Some(v.to_string());
        self
    }

    /// Mark required.
    pub fn required(mut self) -> Self {
        self.required = true;
        self
    }
}

/// An application or subcommand.
#[derive(Clone, Debug)]
pub struct App {
    name: &'static str,
    about: &'static str,
    args: Vec<Arg>,
    subs: Vec<App>,
}

/// Parse result: matched subcommand path and flag values.
#[derive(Debug, Default)]
pub struct Matches {
    /// Chain of matched subcommand names (empty for the root).
    pub subcommand: Vec<&'static str>,
    values: BTreeMap<&'static str, String>,
    switches: BTreeMap<&'static str, bool>,
    /// Positional arguments (anything not starting with `--`).
    pub positional: Vec<String>,
}

impl Matches {
    /// String value of a flag (default applied).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Typed accessor.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        let raw = self
            .get(name)
            .ok_or_else(|| Error::Config(format!("missing flag --{name}")))?;
        raw.parse::<T>()
            .map_err(|_| Error::Config(format!("flag --{name}: cannot parse {raw:?}")))
    }

    /// Boolean switch state.
    pub fn is_set(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Last matched subcommand (or "" at root).
    pub fn cmd(&self) -> &str {
        self.subcommand.last().copied().unwrap_or("")
    }
}

impl App {
    /// New app/subcommand.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, args: Vec::new(), subs: Vec::new() }
    }

    /// Add a flag.
    pub fn arg(mut self, a: Arg) -> Self {
        self.args.push(a);
        self
    }

    /// Add a subcommand.
    pub fn subcommand(mut self, s: App) -> Self {
        self.subs.push(s);
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            out.push_str("<COMMAND> ");
        }
        out.push_str("[FLAGS]\n");
        if !self.subs.is_empty() {
            out.push_str("\nCOMMANDS:\n");
            for s in &self.subs {
                out.push_str(&format!("  {:<18} {}\n", s.name, s.about));
            }
        }
        if !self.args.is_empty() {
            out.push_str("\nFLAGS:\n");
            for a in &self.args {
                let mut line = format!("  --{}", a.name);
                if !a.is_switch {
                    line.push_str(" <v>");
                }
                let mut help = a.help.to_string();
                if let Some(d) = &a.default {
                    help.push_str(&format!(" [default: {d}]"));
                }
                if a.required {
                    help.push_str(" (required)");
                }
                out.push_str(&format!("{line:<26} {help}\n"));
            }
        }
        out
    }

    /// Parse an argument iterator (excluding argv[0]).
    pub fn parse<I>(&self, args: I) -> Result<Matches>
    where
        I: IntoIterator<Item = String>,
    {
        let mut m = Matches::default();
        self.parse_into(&mut args.into_iter().peekable(), &mut m)?;
        Ok(m)
    }

    fn parse_into(
        &self,
        it: &mut std::iter::Peekable<impl Iterator<Item = String>>,
        m: &mut Matches,
    ) -> Result<()> {
        // defaults first
        for a in &self.args {
            if let Some(d) = &a.default {
                m.values.insert(a.name, d.clone());
            }
        }
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(Error::Config(self.help()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| {
                        Error::Config(format!("unknown flag --{key} for {}", self.name))
                    })?;
                if spec.is_switch {
                    if inline_val.is_some() {
                        return Err(Error::Config(format!("switch --{key} takes no value")));
                    }
                    m.switches.insert(spec.name, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            Error::Config(format!("flag --{key} needs a value"))
                        })?,
                    };
                    m.values.insert(spec.name, v);
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == tok) {
                m.subcommand.push(sub.name);
                return sub.parse_into(it, m);
            } else {
                m.positional.push(tok);
            }
        }
        for a in &self.args {
            if a.required && !m.values.contains_key(a.name) {
                return Err(Error::Config(format!("missing required flag --{}", a.name)));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("t", "test app")
            .subcommand(
                App::new("run", "run it")
                    .arg(Arg::flag("n", "count").default("5"))
                    .arg(Arg::flag("name", "label").required())
                    .arg(Arg::switch("fast", "go fast")),
            )
            .subcommand(App::new("info", "show info"))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let m = app()
            .parse(vec!["run".into(), "--name".into(), "x".into(), "--fast".into()])
            .unwrap();
        assert_eq!(m.cmd(), "run");
        assert_eq!(m.get("name"), Some("x"));
        assert_eq!(m.get_parse::<usize>("n").unwrap(), 5);
        assert!(m.is_set("fast"));
    }

    #[test]
    fn inline_equals() {
        let m = app()
            .parse(vec!["run".into(), "--name=x".into(), "--n=9".into()])
            .unwrap();
        assert_eq!(m.get_parse::<usize>("n").unwrap(), 9);
    }

    #[test]
    fn missing_required_errors() {
        assert!(app().parse(vec!["run".into()]).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let e = app().parse(vec!["run".into(), "--bogus".into(), "1".into()]);
        assert!(e.is_err());
    }

    #[test]
    fn positional_collected() {
        let m = app().parse(vec!["info".into(), "extra".into()]).unwrap();
        assert_eq!(m.cmd(), "info");
        assert_eq!(m.positional, vec!["extra"]);
    }

    #[test]
    fn help_renders() {
        let h = app().help();
        assert!(h.contains("COMMANDS"));
        assert!(h.contains("run"));
    }

    #[test]
    fn bad_parse_type() {
        let m = app()
            .parse(vec!["run".into(), "--name".into(), "x".into(), "--n".into(), "zz".into()])
            .unwrap();
        assert!(m.get_parse::<usize>("n").is_err());
    }
}
