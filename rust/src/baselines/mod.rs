//! The paper's two comparison baselines (§V):
//!
//! * [`Nonincremental`] — recompute the whole model from scratch after each
//!   round of data operations (the green curves).
//! * [`SingleIncremental`] — apply every insertion and deletion as its own
//!   rank-1 update (the red curves; Engel et al. / recursive-KRR style).
//! * [`SingleIncKbr`] — the single-instance KBR baseline for Figs. 7-8.
//!
//! All baselines produce *identical estimators* to the multiple-incremental
//! engines (that's the paper's accuracy-invariance claim); only their
//! computational profile differs.

use crate::config::Space;
use crate::error::Result;
use crate::kbr::{KbrHyper, KbrModel};
use crate::kernels::Kernel;
use crate::krr::empirical::EmpiricalKrr;
use crate::krr::intrinsic::IntrinsicKrr;
use crate::krr::KrrModel;
use crate::linalg::Mat;

/// Full-retrain baseline: stores the raw dataset, refits on every round.
pub struct Nonincremental {
    kernel: Kernel,
    rho: f64,
    space: Space,
    x: Mat,
    y: Vec<f64>,
    model: Box<dyn KrrModel>,
}

impl Nonincremental {
    /// Initial fit.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64, space: Space) -> Result<Self> {
        let model = fit_space(x, y, kernel, rho, space)?;
        Ok(Self {
            kernel: kernel.clone(),
            rho,
            space,
            x: x.clone(),
            y: y.to_vec(),
            model,
        })
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }

    /// Predict through the current model.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        self.model.predict(x)
    }

    /// One round: edit the dataset, then retrain from scratch
    /// (the O(N J^2 + J^3) / O(N^2 M + N^3) cost the paper highlights).
    pub fn round(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        let mut rem: Vec<usize> = remove_idx.to_vec();
        rem.sort_unstable();
        rem.dedup();
        self.x.remove_rows(&rem)?;
        for (i, &ri) in rem.iter().enumerate() {
            self.y.remove(ri - i);
        }
        if x_new.rows() > 0 {
            self.x = self.x.vcat(x_new)?;
            self.y.extend_from_slice(y_new);
        }
        self.model = fit_space(&self.x, &self.y, &self.kernel, self.rho, self.space)?;
        Ok(())
    }
}

/// Single-instance incremental baseline: same engines, but every inserted
/// sample is one rank-1 update and every removed sample one rank-1
/// downdate — |C| + |R| separate updates (and head refreshes) per round.
pub struct SingleIncremental {
    model: Box<dyn KrrModel>,
}

impl SingleIncremental {
    /// Initial fit (same cost as the multiple engine's bootstrap).
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, rho: f64, space: Space) -> Result<Self> {
        Ok(Self { model: fit_space(x, y, kernel, rho, space)? })
    }

    /// Predict through the engine.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        self.model.predict(x)
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.model.n_samples()
    }

    /// One round as (|R| removals + |C| insertions), each its own update.
    /// Removals go first with indices adjusted as the set shrinks.
    pub fn round(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        let mut rem: Vec<usize> = remove_idx.to_vec();
        rem.sort_unstable();
        rem.dedup();
        // descending order keeps earlier indices stable
        for &ri in rem.iter().rev() {
            self.model.inc_dec(&Mat::zeros(0, x_new.cols()), &[], &[ri])?;
        }
        for r in 0..x_new.rows() {
            let xi = Mat::from_vec(1, x_new.cols(), x_new.row(r).to_vec())?;
            self.model.inc_dec(&xi, &[y_new[r]], &[])?;
        }
        Ok(())
    }
}

/// Single-instance incremental KBR baseline (paper Figs. 7-8).
pub struct SingleIncKbr {
    model: KbrModel,
}

impl SingleIncKbr {
    /// Initial posterior fit.
    pub fn fit(x: &Mat, y: &[f64], kernel: &Kernel, hyper: KbrHyper) -> Result<Self> {
        Ok(Self { model: KbrModel::fit(x, y, kernel, hyper)? })
    }

    /// Inner model access.
    pub fn model(&self) -> &KbrModel {
        &self.model
    }

    /// One round as single-sample posterior updates.
    pub fn round(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        let mut rem: Vec<usize> = remove_idx.to_vec();
        rem.sort_unstable();
        rem.dedup();
        for &ri in rem.iter().rev() {
            self.model.inc_dec(&Mat::zeros(0, x_new.cols()), &[], &[ri])?;
        }
        for r in 0..x_new.rows() {
            let xi = Mat::from_vec(1, x_new.cols(), x_new.row(r).to_vec())?;
            self.model.inc_dec(&xi, &[y_new[r]], &[])?;
        }
        Ok(())
    }
}

fn fit_space(
    x: &Mat,
    y: &[f64],
    kernel: &Kernel,
    rho: f64,
    space: Space,
) -> Result<Box<dyn KrrModel>> {
    Ok(match space {
        Space::Intrinsic => Box::new(IntrinsicKrr::fit(x, y, kernel, rho)?),
        Space::Empirical => Box::new(EmpiricalKrr::fit(x, y, kernel, rho)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::dot;
    use crate::testutil::assert_vec_close;
    use crate::util::prng::Rng;

    fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
            .collect();
        (x, y)
    }

    /// The paper's accuracy-invariance claim: all three strategies produce
    /// the same predictions after the same rounds.
    #[test]
    fn all_three_strategies_agree_intrinsic() {
        let (x, y) = data(40, 4, 1);
        let (xt, _) = data(10, 4, 2);
        let kernel = Kernel::poly(2, 1.0);
        let mut none = Nonincremental::fit(&x, &y, &kernel, 0.5, Space::Intrinsic).unwrap();
        let mut single = SingleIncremental::fit(&x, &y, &kernel, 0.5, Space::Intrinsic).unwrap();
        let mut multiple = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut rng = Rng::new(3);
        let mut n_cur = y.len();
        for round in 0..4 {
            let (xc, yc) = data(4, 4, 50 + round);
            let rem = rng.sample_indices(n_cur, 2);
            none.round(&xc, &yc, &rem).unwrap();
            single.round(&xc, &yc, &rem).unwrap();
            multiple.inc_dec(&xc, &yc, &rem).unwrap();
            n_cur = n_cur + 4 - 2;
        }
        let p0 = none.predict(&xt).unwrap();
        let p1 = single.predict(&xt).unwrap();
        let p2 = multiple.predict(&xt).unwrap();
        assert_vec_close(&p1, &p0, 1e-6);
        assert_vec_close(&p2, &p0, 1e-6);
    }

    #[test]
    fn all_three_strategies_agree_empirical_rbf() {
        let (x, y) = data(25, 5, 4);
        let (xt, _) = data(6, 5, 5);
        let kernel = Kernel::rbf_radius(2.0);
        let mut none = Nonincremental::fit(&x, &y, &kernel, 0.5, Space::Empirical).unwrap();
        let mut single = SingleIncremental::fit(&x, &y, &kernel, 0.5, Space::Empirical).unwrap();
        let mut multiple = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut rng = Rng::new(6);
        let mut n_cur = y.len();
        for round in 0..3 {
            let (xc, yc) = data(4, 5, 80 + round);
            let rem = rng.sample_indices(n_cur, 2);
            none.round(&xc, &yc, &rem).unwrap();
            single.round(&xc, &yc, &rem).unwrap();
            multiple.inc_dec(&xc, &yc, &rem).unwrap();
            n_cur = n_cur + 4 - 2;
        }
        let p0 = none.predict(&xt).unwrap();
        let p1 = single.predict(&xt).unwrap();
        let p2 = multiple.predict(&xt).unwrap();
        assert_vec_close(&p1, &p0, 1e-5);
        assert_vec_close(&p2, &p0, 1e-5);
    }

    #[test]
    fn kbr_single_matches_multiple() {
        let (x, y) = data(30, 3, 7);
        let kernel = Kernel::poly(2, 1.0);
        let mut single = SingleIncKbr::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let mut multiple = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let (xc, yc) = data(4, 3, 8);
        let rem = [2usize, 19];
        single.round(&xc, &yc, &rem).unwrap();
        multiple.inc_dec(&xc, &yc, &rem).unwrap();
        assert_vec_close(
            single.model().posterior_mean(),
            multiple.posterior_mean(),
            1e-6,
        );
    }

    #[test]
    fn sizes_track() {
        let (x, y) = data(10, 3, 9);
        let kernel = Kernel::poly(2, 1.0);
        let mut none = Nonincremental::fit(&x, &y, &kernel, 0.5, Space::Intrinsic).unwrap();
        let (xc, yc) = data(4, 3, 10);
        none.round(&xc, &yc, &[0, 1]).unwrap();
        assert_eq!(none.n_samples(), 12);
    }
}
