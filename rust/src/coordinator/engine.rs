//! The coordinator's engine: a space-routed KRR model, optionally paired
//! with a KBR posterior for uncertainty serving, with snapshot/rollback.
//!
//! Snapshots are cheap-ish full copies of the maintained state (the state
//! IS the model — S^-1/Q^-1 plus stores); the coordinator takes one before
//! each numerically risky batched update and restores on failure.

use crate::config::Space;
use crate::error::{Error, Result};
use crate::kbr::{KbrHyper, KbrModel, KbrPredictWork};
use crate::kernels::Kernel;
use crate::krr::empirical::{EmpiricalKrr, EmpiricalPredictWork};
use crate::krr::intrinsic::{IntrinsicKrr, IntrinsicPredictWork};
use crate::krr::KrrModel;
use crate::linalg::Mat;

/// Caller-owned workspace for the engine's `*_into` prediction paths:
/// holds the per-variant scratch so a warm serving loop predicts without
/// touching the heap regardless of which space the engine routes to
/// (measured in `rust/tests/alloc_count.rs`, 1-thread path).
#[derive(Clone, Default)]
pub struct EnginePredictWork {
    intr: IntrinsicPredictWork,
    emp: EmpiricalPredictWork,
    kbr: KbrPredictWork,
}

/// Engine variants by operating space.
#[derive(Clone)]
enum KrrEngine {
    Intrinsic(IntrinsicKrr),
    Empirical(EmpiricalKrr),
}

/// The routed engine (KRR + optional KBR twin).
#[derive(Clone)]
pub struct Engine {
    krr: KrrEngine,
    kbr: Option<KbrModel>,
    space: Space,
    /// Raw training features, kept in engine order (for outlier scoring
    /// and the empirical cross-kernels).
    x: Mat,
    y: Vec<f64>,
    kernel: Kernel,
    ridge: f64,
    /// Reused sorted-removal scratch for the mirror-store edits.
    rem_scratch: Vec<usize>,
}

/// Opaque snapshot for rollback.
pub struct Snapshot {
    state: Box<Engine>,
}

impl Engine {
    /// Fit in the given space.
    pub fn fit(
        x: &Mat,
        y: &[f64],
        kernel: &Kernel,
        ridge: f64,
        space: Space,
        with_uncertainty: bool,
    ) -> Result<Self> {
        let krr = match space {
            Space::Intrinsic => KrrEngine::Intrinsic(IntrinsicKrr::fit(x, y, kernel, ridge)?),
            Space::Empirical => KrrEngine::Empirical(EmpiricalKrr::fit(x, y, kernel, ridge)?),
        };
        let kbr = if with_uncertainty {
            Some(KbrModel::fit(x, y, kernel, KbrHyper::default())?)
        } else {
            None
        };
        Ok(Self {
            krr,
            kbr,
            space,
            x: x.clone(),
            y: y.to_vec(),
            kernel: kernel.clone(),
            ridge,
            rem_scratch: Vec::new(),
        })
    }

    /// Operating space.
    pub fn space(&self) -> Space {
        self.space
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Ridge.
    pub fn ridge(&self) -> f64 {
        self.ridge
    }

    /// Borrow the KRR model for read-side operations (outlier scoring).
    pub fn krr(&self) -> &dyn KrrModel {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m,
            KrrEngine::Empirical(m) => m,
        }
    }

    /// Borrow the current training set (engine order). Borrowed, not
    /// cloned: the outlier-scoring hot path reads it every round, and an
    /// owned copy was an O(N M) allocation per call.
    pub fn training_view(&self) -> (&Mat, &[f64]) {
        (&self.x, &self.y)
    }

    /// Borrow the training targets (engine order).
    pub fn targets(&self) -> &[f64] {
        &self.y
    }

    /// Predict point estimates.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        self.krr().predict(x)
    }

    /// Predict mean + variance (requires the KBR twin).
    pub fn predict_with_uncertainty(&self, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        let kbr = self.kbr.as_ref().ok_or_else(|| {
            Error::Config("uncertainty serving requires with_uncertainty=true".into())
        })?;
        let p = kbr.predict(x)?;
        Ok((p.mean, p.var))
    }

    /// [`Engine::predict`] written into a caller-provided buffer through a
    /// warm workspace — the serving layer's allocation-free read path.
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m.predict_into(x, out, &mut work.intr),
            KrrEngine::Empirical(m) => m.predict_into(x, out, &mut work.emp),
        }
    }

    /// [`Engine::predict_with_uncertainty`] written into caller-provided
    /// buffers through a warm workspace (requires the KBR twin).
    pub fn predict_with_uncertainty_into(
        &self,
        x: &Mat,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        let kbr = self.kbr.as_ref().ok_or_else(|| {
            Error::Config("uncertainty serving requires with_uncertainty=true".into())
        })?;
        kbr.predict_into(x, mean, var, &mut work.kbr)
    }

    /// One batched multiple inc/dec round across KRR (and KBR if present),
    /// keeping the raw stores in sync. The engines and the mirror stores
    /// all edit in place inside reserved capacity, so a steady-state round
    /// leaves no allocation traffic behind.
    pub fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        match &mut self.krr {
            KrrEngine::Intrinsic(m) => m.inc_dec(x_new, y_new, remove_idx)?,
            KrrEngine::Empirical(m) => m.inc_dec(x_new, y_new, remove_idx)?,
        }
        if let Some(kbr) = &mut self.kbr {
            kbr.inc_dec(x_new, y_new, remove_idx)?;
        }
        // mirror into the raw stores
        self.rem_scratch.clear();
        self.rem_scratch.extend_from_slice(remove_idx);
        self.rem_scratch.sort_unstable();
        self.rem_scratch.dedup();
        self.x.drop_rows_sorted(&self.rem_scratch)?;
        for (i, &ri) in self.rem_scratch.iter().enumerate() {
            self.y.remove(ri - i);
        }
        if x_new.rows() > 0 {
            self.x.push_rows(x_new)?;
            self.y.extend_from_slice(y_new);
        }
        Ok(())
    }

    /// Take a rollback snapshot — a deep copy of the maintained state
    /// (memcpy-bound, no refit; see EXPERIMENTS.md §Perf).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { state: Box::new(self.clone()) }
    }

    /// Restore from a snapshot.
    pub fn restore(&mut self, snap: Snapshot) {
        *self = *snap.state;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn fit_and_route_both_spaces() {
        let d = synth::ecg_like(60, 6, 1);
        for space in [Space::Intrinsic, Space::Empirical] {
            let e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, space, false).unwrap();
            assert_eq!(e.space(), space);
            assert_eq!(e.n_samples(), 60);
            let p = e.predict(&d.x.block(0, 5, 0, 6)).unwrap();
            assert_eq!(p.len(), 5);
        }
    }

    #[test]
    fn inc_dec_keeps_stores_in_sync() {
        let d = synth::ecg_like(40, 6, 2);
        let extra = synth::ecg_like(4, 6, 3);
        let mut e =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap();
        e.inc_dec(&extra.x, &extra.y, &[1, 5]).unwrap();
        assert_eq!(e.n_samples(), 42);
        let (xv, yv) = e.training_view();
        assert_eq!(xv.rows(), 42);
        assert_eq!(yv.len(), 42);
        // last rows are the new samples
        assert_eq!(xv.row(41), extra.x.row(3));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let d = synth::ecg_like(30, 5, 4);
        let mut e =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap();
        let p_before = e.predict(&d.x.block(0, 5, 0, 5)).unwrap();
        let snap = e.snapshot();
        let extra = synth::ecg_like(4, 5, 5);
        e.inc_dec(&extra.x, &extra.y, &[]).unwrap();
        assert_eq!(e.n_samples(), 34);
        e.restore(snap);
        assert_eq!(e.n_samples(), 30);
        let p_after = e.predict(&d.x.block(0, 5, 0, 5)).unwrap();
        crate::testutil::assert_vec_close(&p_after, &p_before, 1e-10);
    }

    #[test]
    fn uncertainty_requires_flag() {
        let d = synth::ecg_like(20, 4, 6);
        let e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false)
            .unwrap();
        assert!(e.predict_with_uncertainty(&d.x).is_err());
        let e2 = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        let (mu, var) = e2.predict_with_uncertainty(&d.x.block(0, 3, 0, 4)).unwrap();
        assert_eq!(mu.len(), 3);
        assert!(var.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn kbr_twin_tracks_krr_through_updates() {
        let d = synth::ecg_like(40, 5, 7);
        let extra = synth::ecg_like(6, 5, 8);
        let mut e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        e.inc_dec(&extra.x, &extra.y, &[0, 2]).unwrap();
        let (mu, _) = e.predict_with_uncertainty(&d.x.block(0, 4, 0, 5)).unwrap();
        assert_eq!(mu.len(), 4);
        assert_eq!(e.n_samples(), 44);
    }
}
