//! The coordinator's engine: a space-routed KRR model, optionally paired
//! with a KBR posterior for uncertainty serving, with snapshot/rollback.
//!
//! Snapshots are cheap-ish full copies of the maintained state (the state
//! IS the model — S^-1/Q^-1 plus stores); the coordinator takes one before
//! each numerically risky batched update and restores on failure.
//!
//! The engine carries `D = n_outputs()` target columns end-to-end behind
//! ONE maintained inverse per space, and optionally folds (ε-near)
//! duplicate incoming rows into multiplicity-weighted existing rows
//! instead of growing the store ([`Engine::set_fold_eps`]): the fold plan
//! is computed ONCE per round here, so the KRR engine, the KBR twin, and
//! the raw mirrors all apply the *same* fold decision.

use crate::config::Space;
use crate::error::{Error, Result};
use crate::kbr::{KbrHyper, KbrModel, KbrPredictWork};
use crate::kernels::Kernel;
use crate::krr::empirical::{EmpiricalKrr, EmpiricalPredictWork};
use crate::krr::fold::{plan_folds_into, FoldPlan};
use crate::krr::intrinsic::{IntrinsicKrr, IntrinsicPredictWork};
use crate::krr::KrrModel;
use crate::linalg::Mat;

/// Caller-owned workspace for the engine's `*_into` prediction paths:
/// holds the per-variant scratch so a warm serving loop predicts without
/// touching the heap regardless of which space the engine routes to
/// (measured in `rust/tests/alloc_count.rs`, 1-thread path).
#[derive(Clone, Default)]
pub struct EnginePredictWork {
    intr: IntrinsicPredictWork,
    emp: EmpiricalPredictWork,
    kbr: KbrPredictWork,
}

/// Engine variants by operating space.
#[derive(Clone)]
enum KrrEngine {
    Intrinsic(IntrinsicKrr),
    Empirical(EmpiricalKrr),
}

/// The routed engine (KRR + optional KBR twin).
#[derive(Clone)]
pub struct Engine {
    krr: KrrEngine,
    kbr: Option<KbrModel>,
    space: Space,
    /// Raw training features, kept in engine order (for outlier scoring
    /// and the empirical cross-kernels).
    x: Mat,
    /// Training targets, (N, D), multiplicity-averaged in engine order.
    y: Mat,
    /// Mirror of the engines' per-row duplicate multiplicities.
    mult: Vec<f64>,
    kernel: Kernel,
    ridge: f64,
    /// Duplicate-fold radius: `Some(eps)` folds incoming rows within
    /// `eps` (Euclidean) of a stored row; `None` disables folding.
    fold_eps: Option<f64>,
    /// Reused sorted-removal scratch for the mirror-store edits.
    rem_scratch: Vec<usize>,
    /// Reused fold-plan scratch.
    fold_plan: FoldPlan,
    /// Fresh-row gather scratch for folded rounds.
    x_fresh: Mat,
    y_fresh: Mat,
    /// D=1 shim scratch: `y_new` as a (B, 1) column.
    y_shim: Mat,
}

/// Opaque snapshot for rollback.
pub struct Snapshot {
    state: Box<Engine>,
}

impl Engine {
    /// Fit in the given space (`D = 1`).
    pub fn fit(
        x: &Mat,
        y: &[f64],
        kernel: &Kernel,
        ridge: f64,
        space: Space,
        with_uncertainty: bool,
    ) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::fit_multi(x, &ym, kernel, ridge, space, with_uncertainty)
    }

    /// Fit in the given space with a `(N, D)` target matrix: one
    /// factorization per maintained inverse, `D` coefficient columns.
    pub fn fit_multi(
        x: &Mat,
        y: &Mat,
        kernel: &Kernel,
        ridge: f64,
        space: Space,
        with_uncertainty: bool,
    ) -> Result<Self> {
        let krr = match space {
            Space::Intrinsic => {
                KrrEngine::Intrinsic(IntrinsicKrr::fit_multi(x, y, kernel, ridge)?)
            }
            Space::Empirical => {
                KrrEngine::Empirical(EmpiricalKrr::fit_multi(x, y, kernel, ridge)?)
            }
        };
        let kbr = if with_uncertainty {
            Some(KbrModel::fit_multi(x, y, kernel, KbrHyper::default())?)
        } else {
            None
        };
        Ok(Self {
            krr,
            kbr,
            space,
            x: x.clone(),
            y: y.clone(),
            mult: vec![1.0; y.rows()],
            kernel: kernel.clone(),
            ridge,
            fold_eps: None,
            rem_scratch: Vec::new(),
            fold_plan: FoldPlan::default(),
            x_fresh: Mat::default(),
            y_fresh: Mat::default(),
            y_shim: Mat::default(),
        })
    }

    /// Operating space.
    pub fn space(&self) -> Space {
        self.space
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Training-set size.
    pub fn n_samples(&self) -> usize {
        self.y.rows()
    }

    /// Number of target columns D.
    pub fn n_outputs(&self) -> usize {
        self.y.cols()
    }

    /// Kernel.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Ridge.
    pub fn ridge(&self) -> f64 {
        self.ridge
    }

    /// Enable (`Some(eps)`) or disable (`None`) duplicate-input folding
    /// for subsequent [`Engine::inc_dec`] rounds. `eps = 0.0` folds exact
    /// repeats only.
    pub fn set_fold_eps(&mut self, eps: Option<f64>) {
        self.fold_eps = eps;
    }

    /// The configured fold radius, if folding is enabled.
    pub fn fold_eps(&self) -> Option<f64> {
        self.fold_eps
    }

    /// Per-row duplicate multiplicities, engine order (all 1.0 unless
    /// folding is enabled and duplicates arrived).
    pub fn multiplicities(&self) -> &[f64] {
        &self.mult
    }

    /// Borrow the KRR model for read-side operations (outlier scoring).
    pub fn krr(&self) -> &dyn KrrModel {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m,
            KrrEngine::Empirical(m) => m,
        }
    }

    /// Borrow the current training set (engine order): features and the
    /// `(N, D)` target matrix. Borrowed, not cloned: the outlier-scoring
    /// hot path reads it every round, and an owned copy was an O(N M)
    /// allocation per call. This is THE accessor pair for the training
    /// stores.
    pub fn training_view(&self) -> (&Mat, &Mat) {
        (&self.x, &self.y)
    }

    /// True when the engine carries a KBR twin for uncertainty serving.
    pub fn has_uncertainty(&self) -> bool {
        self.kbr.is_some()
    }

    /// Predict point estimates (`D = 1`).
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        self.krr().predict(x)
    }

    /// Predict all D output columns: `(B, D)` out.
    pub fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        self.krr().predict_multi(x)
    }

    /// Predict mean + variance (requires the KBR twin, `D = 1`).
    pub fn predict_with_uncertainty(&self, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        let kbr = self.kbr.as_ref().ok_or_else(|| {
            Error::Config("uncertainty serving requires with_uncertainty=true".into())
        })?;
        let p = kbr.predict(x)?;
        Ok((p.mean, p.var))
    }

    /// Multi-output mean + shared per-query variance (requires the KBR
    /// twin).
    pub fn predict_with_uncertainty_multi(&self, x: &Mat) -> Result<(Mat, Vec<f64>)> {
        let mut mean = Mat::default();
        let mut var = Vec::new();
        self.predict_with_uncertainty_multi_into(
            x,
            &mut mean,
            &mut var,
            &mut EnginePredictWork::default(),
        )?;
        Ok((mean, var))
    }

    /// [`Engine::predict`] written into a caller-provided buffer through a
    /// warm workspace — the serving layer's allocation-free read path
    /// (`D = 1`).
    pub fn predict_into(
        &self,
        x: &Mat,
        out: &mut Vec<f64>,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m.predict_into(x, out, &mut work.intr),
            KrrEngine::Empirical(m) => m.predict_into(x, out, &mut work.emp),
        }
    }

    /// Multi-output [`Engine::predict_into`]: ONE packed `(B, D)` GEMM
    /// through the warm workspace. Allocation-free once warm.
    pub fn predict_multi_into(
        &self,
        x: &Mat,
        out: &mut Mat,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m.predict_multi_into(x, out, &mut work.intr),
            KrrEngine::Empirical(m) => m.predict_multi_into(x, out, &mut work.emp),
        }
    }

    /// [`Engine::predict_with_uncertainty`] written into caller-provided
    /// buffers through a warm workspace (requires the KBR twin, `D = 1`).
    pub fn predict_with_uncertainty_into(
        &self,
        x: &Mat,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        let kbr = self.kbr.as_ref().ok_or_else(|| {
            Error::Config("uncertainty serving requires with_uncertainty=true".into())
        })?;
        kbr.predict_into(x, mean, var, &mut work.kbr)
    }

    /// Multi-output [`Engine::predict_with_uncertainty_into`]: `(B, D)`
    /// means, ONE shared variance per query row.
    pub fn predict_with_uncertainty_multi_into(
        &self,
        x: &Mat,
        mean: &mut Mat,
        var: &mut Vec<f64>,
        work: &mut EnginePredictWork,
    ) -> Result<()> {
        let kbr = self.kbr.as_ref().ok_or_else(|| {
            Error::Config("uncertainty serving requires with_uncertainty=true".into())
        })?;
        kbr.predict_multi_into(x, mean, var, &mut work.kbr)
    }

    /// One batched multiple inc/dec round across KRR (and KBR if present),
    /// keeping the raw stores in sync (`D = 1` surface). Steady state
    /// performs zero heap allocations.
    pub fn inc_dec(&mut self, x_new: &Mat, y_new: &[f64], remove_idx: &[usize]) -> Result<()> {
        if self.y.cols() != 1 {
            return Err(Error::Config(
                "inc_dec is the D=1 surface; use inc_dec_multi".into(),
            ));
        }
        let mut shim = std::mem::take(&mut self.y_shim);
        shim.resize_scratch(y_new.len(), 1);
        shim.as_mut_slice().copy_from_slice(y_new);
        let out = self.inc_dec_multi(x_new, &shim, remove_idx);
        self.y_shim = shim;
        out
    }

    /// Multi-output inc/dec round: `y_new` is `(B, D)`. When folding is
    /// enabled, incoming rows within `fold_eps` of a surviving stored row
    /// fold into it as a multiplicity bump + rank-1 maintained-inverse
    /// update (numerically equivalent to the unfolded insert) instead of
    /// growing the store; the plan is computed once and shared by the KRR
    /// engine, the KBR twin, and the raw mirrors.
    pub fn inc_dec_multi(&mut self, x_new: &Mat, y_new: &Mat, remove_idx: &[usize]) -> Result<()> {
        if x_new.rows() > 0 && y_new.cols() != self.y.cols() {
            return Err(Error::Config(format!(
                "y_new has {} cols, engine carries D = {}",
                y_new.cols(),
                self.y.cols()
            )));
        }
        self.rem_scratch.clear();
        self.rem_scratch.extend_from_slice(remove_idx);
        self.rem_scratch.sort_unstable();
        self.rem_scratch.dedup();
        if let Some(&mx) = self.rem_scratch.last() {
            if mx >= self.y.rows() {
                return Err(Error::InvalidUpdate(format!(
                    "remove index {mx} >= n {}",
                    self.y.rows()
                )));
            }
        }
        let mut plan = std::mem::take(&mut self.fold_plan);
        let folding = match self.fold_eps {
            Some(eps) if x_new.rows() > 0 => {
                plan_folds_into(&mut plan, &self.x, &self.rem_scratch, x_new, eps);
                !plan.is_trivial()
            }
            _ => {
                plan.fresh.clear();
                plan.folds.clear();
                false
            }
        };
        let out = self.inc_dec_planned(x_new, y_new, &plan, folding);
        self.fold_plan = plan;
        out
    }

    /// How many incoming rows the most recent [`Engine::inc_dec`] round
    /// folded into existing rows (0 when folding is disabled).
    pub fn last_round_folds(&self) -> usize {
        self.fold_plan.folds.len()
    }

    fn inc_dec_planned(
        &mut self,
        x_new: &Mat,
        y_new: &Mat,
        plan: &FoldPlan,
        folding: bool,
    ) -> Result<()> {
        if folding {
            // gather the fresh (non-folding) rows into warm scratch blocks
            let m = x_new.cols();
            let d = y_new.cols();
            self.x_fresh.resize_scratch(plan.fresh.len(), m);
            self.y_fresh.resize_scratch(plan.fresh.len(), d);
            for (k, &b) in plan.fresh.iter().enumerate() {
                self.x_fresh.row_mut(k).copy_from_slice(x_new.row(b));
                self.y_fresh.row_mut(k).copy_from_slice(y_new.row(b));
            }
        }
        let (xf, yf) = if folding {
            (&self.x_fresh, &self.y_fresh)
        } else {
            (x_new, y_new)
        };
        match &mut self.krr {
            KrrEngine::Intrinsic(mdl) => mdl.inc_dec_multi(xf, yf, &self.rem_scratch)?,
            KrrEngine::Empirical(mdl) => mdl.inc_dec_multi(xf, yf, &self.rem_scratch)?,
        }
        if let Some(kbr) = &mut self.kbr {
            kbr.inc_dec_multi(xf, yf, &self.rem_scratch)?;
        }
        // mirror the round into the raw stores
        self.x.drop_rows_sorted(&self.rem_scratch)?;
        self.y.drop_rows_sorted(&self.rem_scratch)?;
        for (i, &ri) in self.rem_scratch.iter().enumerate() {
            self.mult.remove(ri - i);
        }
        if xf.rows() > 0 {
            self.x.push_rows(xf)?;
            self.y.push_rows(yf)?;
            self.mult.resize(self.mult.len() + xf.rows(), 1.0);
        }
        if folding {
            match &mut self.krr {
                KrrEngine::Intrinsic(mdl) => mdl.apply_folds(&plan.folds, x_new, y_new)?,
                KrrEngine::Empirical(mdl) => mdl.apply_folds(&plan.folds, x_new, y_new)?,
            }
            if let Some(kbr) = &mut self.kbr {
                kbr.apply_folds(&plan.folds, x_new, y_new)?;
            }
            // mirror the multiplicity bumps and target averaging
            let d = self.y.cols();
            for &(i, br) in &plan.folds {
                let c = self.mult[i];
                for dc in 0..d {
                    self.y[(i, dc)] = (c * self.y[(i, dc)] + y_new[(br, dc)]) / (c + 1.0);
                }
                self.mult[i] = c + 1.0;
            }
        }
        Ok(())
    }

    /// Take a rollback snapshot — a deep copy of the maintained state
    /// (memcpy-bound, no refit; see EXPERIMENTS.md §Perf).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { state: Box::new(self.clone()) }
    }

    /// Restore from a snapshot.
    pub fn restore(&mut self, snap: Snapshot) {
        *self = *snap.state;
    }

    /// Number of probe-able residual indices for [`Engine::probe_residual_into`]:
    /// the maintained inverse's side (N for the empirical `Q⁻¹`, J for the
    /// intrinsic `S⁻¹`).
    pub fn probe_dim(&self) -> usize {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m.j(),
            KrrEngine::Empirical(_) => self.y.rows(),
        }
    }

    /// Numerical health probe on the maintained inverse: ∞-norm of row `i`
    /// of `A·A⁻¹ − I` where `A` is rebuilt exactly from the retained
    /// stores (`K + ρC⁻¹` empirical, `ΦᵀCΦ + ρI` intrinsic). Exactly 0 in
    /// exact arithmetic; drift accumulated over incremental rounds shows
    /// up here long before predictions go visibly wrong. Allocation-free
    /// once `g`/`r` are warm.
    pub fn probe_residual_into(
        &self,
        i: usize,
        g: &mut Vec<f64>,
        r: &mut Vec<f64>,
    ) -> Result<f64> {
        match &self.krr {
            KrrEngine::Intrinsic(m) => m.probe_residual_into(i, g, r),
            KrrEngine::Empirical(m) => m.probe_residual_into(i, g, r),
        }
    }

    /// Self-heal: rebuild every maintained inverse from the retained
    /// training stores (full refactorization), then replay the duplicate
    /// multiplicities as rank-1 folds so the healed engine carries the
    /// exact same `C = diag(c_i)` weighting as the drifted one. Replaying
    /// a row's own averaged target leaves the target fixed
    /// (`(c·ȳ + ȳ)/(c + 1) = ȳ`) while each fold bumps the weight — so the
    /// healed state matches what a never-drifted engine would hold.
    /// O(N·J² + J³) (or O(N³) empirical): the slow path by design; the
    /// serving layer runs it on the writer copy while readers keep serving
    /// the last published epoch.
    pub fn refit(&mut self) -> Result<()> {
        let mut healed = Engine::fit_multi(
            &self.x,
            &self.y,
            &self.kernel,
            self.ridge,
            self.space,
            self.kbr.is_some(),
        )?;
        healed.fold_eps = self.fold_eps;
        healed.replay_multiplicities(&self.mult)?;
        *self = healed;
        Ok(())
    }

    /// Rebuild an engine from captured parts: the retained training stores
    /// plus their per-row duplicate multiplicities — the decode half of the
    /// durability layer's snapshot codec ([`crate::persist::snapshot`]).
    ///
    /// `y` is the multiplicity-*averaged* target matrix exactly as
    /// [`Engine::training_view`] exposes it, and `mult` the matching
    /// [`Engine::multiplicities`] mirror, so `capture → rebuild` commutes
    /// with the maintained update rules: fitting on the averaged stores and
    /// replaying each row's folds reproduces the same `C = diag(c_i)`
    /// weighting a never-restarted engine carries (the same invariant
    /// [`Engine::refit`] relies on, verified against the incremental path
    /// in the self-heal tests).
    pub fn from_parts(
        x: &Mat,
        y: &Mat,
        mult: &[f64],
        kernel: &Kernel,
        ridge: f64,
        space: Space,
        with_uncertainty: bool,
        fold_eps: Option<f64>,
    ) -> Result<Self> {
        if mult.len() != y.rows() || x.rows() != y.rows() {
            return Err(Error::shape(
                "Engine::from_parts",
                format!(
                    "x rows {}, y rows {}, mult len {} must all agree",
                    x.rows(),
                    y.rows(),
                    mult.len()
                ),
            ));
        }
        if let Some(bad) = mult.iter().find(|&&m| !(m.is_finite() && m >= 1.0)) {
            return Err(Error::InvalidUpdate(format!(
                "multiplicity {bad} is not a finite count >= 1"
            )));
        }
        let mut e = Engine::fit_multi(x, y, kernel, ridge, space, with_uncertainty)?;
        e.fold_eps = fold_eps;
        e.replay_multiplicities(mult)?;
        Ok(e)
    }

    /// Replay duplicate multiplicities onto a freshly fit engine (all
    /// `mult == 1.0`): each row `i` gets `mult[i] - 1` rank-1 folds of its
    /// own averaged target, which leaves the target fixed while bumping the
    /// per-row weight — shared by [`Engine::refit`] and
    /// [`Engine::from_parts`].
    fn replay_multiplicities(&mut self, mult: &[f64]) -> Result<()> {
        debug_assert_eq!(mult.len(), self.mult.len());
        let d = self.y.cols();
        let mut y_row = Mat::default();
        y_row.resize_scratch(1, d);
        let x_row = Mat::default(); // apply_folds never reads features
        for i in 0..mult.len() {
            let reps = (mult[i] - 1.0).round() as usize;
            for _ in 0..reps {
                y_row.as_mut_slice().copy_from_slice(self.y.row(i));
                match &mut self.krr {
                    KrrEngine::Intrinsic(m) => m.apply_folds(&[(i, 0)], &x_row, &y_row)?,
                    KrrEngine::Empirical(m) => m.apply_folds(&[(i, 0)], &x_row, &y_row)?,
                }
                if let Some(kbr) = &mut self.kbr {
                    kbr.apply_folds(&[(i, 0)], &x_row, &y_row)?;
                }
                self.mult[i] += 1.0;
            }
        }
        Ok(())
    }

    /// Chaos-only hook: multiplicatively corrupt one entry of the
    /// maintained inverse so health probes have real drift to detect
    /// (compiled out of non-chaos builds).
    #[cfg(feature = "chaos")]
    pub fn chaos_corrupt_inverse(&mut self, factor: f64) {
        match &mut self.krr {
            KrrEngine::Intrinsic(m) => m.chaos_scale_inverse(factor),
            KrrEngine::Empirical(m) => m.chaos_scale_inverse(factor),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn fit_and_route_both_spaces() {
        let d = synth::ecg_like(60, 6, 1);
        for space in [Space::Intrinsic, Space::Empirical] {
            let e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, space, false).unwrap();
            assert_eq!(e.space(), space);
            assert_eq!(e.n_samples(), 60);
            assert_eq!(e.n_outputs(), 1);
            let p = e.predict(&d.x.block(0, 5, 0, 6)).unwrap();
            assert_eq!(p.len(), 5);
        }
    }

    #[test]
    fn inc_dec_keeps_stores_in_sync() {
        let d = synth::ecg_like(40, 6, 2);
        let extra = synth::ecg_like(4, 6, 3);
        let mut e =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap();
        e.inc_dec(&extra.x, &extra.y, &[1, 5]).unwrap();
        assert_eq!(e.n_samples(), 42);
        let (xv, yv) = e.training_view();
        assert_eq!(xv.rows(), 42);
        assert_eq!(yv.rows(), 42);
        // last rows are the new samples
        assert_eq!(xv.row(41), extra.x.row(3));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let d = synth::ecg_like(30, 5, 4);
        let mut e =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap();
        let p_before = e.predict(&d.x.block(0, 5, 0, 5)).unwrap();
        let snap = e.snapshot();
        let extra = synth::ecg_like(4, 5, 5);
        e.inc_dec(&extra.x, &extra.y, &[]).unwrap();
        assert_eq!(e.n_samples(), 34);
        e.restore(snap);
        assert_eq!(e.n_samples(), 30);
        let p_after = e.predict(&d.x.block(0, 5, 0, 5)).unwrap();
        crate::testutil::assert_vec_close(&p_after, &p_before, 1e-10);
    }

    #[test]
    fn uncertainty_requires_flag() {
        let d = synth::ecg_like(20, 4, 6);
        let e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false)
            .unwrap();
        assert!(e.predict_with_uncertainty(&d.x).is_err());
        let e2 = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        let (mu, var) = e2.predict_with_uncertainty(&d.x.block(0, 3, 0, 4)).unwrap();
        assert_eq!(mu.len(), 3);
        assert!(var.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn kbr_twin_tracks_krr_through_updates() {
        let d = synth::ecg_like(40, 5, 7);
        let extra = synth::ecg_like(6, 5, 8);
        let mut e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        e.inc_dec(&extra.x, &extra.y, &[0, 2]).unwrap();
        let (mu, _) = e.predict_with_uncertainty(&d.x.block(0, 4, 0, 5)).unwrap();
        assert_eq!(mu.len(), 4);
        assert_eq!(e.n_samples(), 44);
    }

    #[test]
    fn folding_matches_unfolded_engine_and_keeps_n() {
        let d = synth::ecg_like(30, 5, 9);
        let mut folded = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        folded.set_fold_eps(Some(0.0));
        let mut unfolded =
            Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true).unwrap();
        // a batch where rows 0 and 2 repeat stored rows 4 and 7
        let fresh = synth::ecg_like(1, 5, 10);
        let xb = Mat::from_fn(3, 5, |r, c| match r {
            0 => d.x[(4, c)],
            1 => fresh.x[(0, c)],
            _ => d.x[(7, c)],
        });
        let yb = vec![0.3, fresh.y[0], -0.4];
        folded.inc_dec(&xb, &yb, &[]).unwrap();
        unfolded.inc_dec(&xb, &yb, &[]).unwrap();
        assert_eq!(folded.n_samples(), 31, "two rows must fold");
        assert_eq!(unfolded.n_samples(), 33);
        assert_eq!(folded.multiplicities()[4], 2.0);
        let q = d.x.block(0, 8, 0, 5);
        let pf = folded.predict(&q).unwrap();
        let pu = unfolded.predict(&q).unwrap();
        crate::testutil::assert_vec_close(&pf, &pu, 1e-10);
        let (mf, vf) = folded.predict_with_uncertainty(&q).unwrap();
        let (mu, vu) = unfolded.predict_with_uncertainty(&q).unwrap();
        crate::testutil::assert_vec_close(&mf, &mu, 1e-10);
        crate::testutil::assert_vec_close(&vf, &vu, 1e-10);
    }

    #[test]
    fn multi_output_engine_round_trip() {
        let d = synth::ecg_like(30, 5, 11);
        let d2 = synth::ecg_like(30, 5, 12);
        let ym = Mat::from_fn(30, 2, |r, c| if c == 0 { d.y[r] } else { d2.y[r] });
        let mut e = Engine::fit_multi(&d.x, &ym, &Kernel::poly(2, 1.0), 0.5, Space::Empirical, true)
            .unwrap();
        assert_eq!(e.n_outputs(), 2);
        // D=1 surface must refuse on a multi-output engine
        assert!(e.predict(&d.x.block(0, 3, 0, 5)).is_err());
        let extra = synth::ecg_like(3, 5, 13);
        let yb = Mat::from_fn(3, 2, |r, c| extra.y[r] * if c == 0 { 1.0 } else { -1.0 });
        e.inc_dec_multi(&extra.x, &yb, &[1, 4]).unwrap();
        assert_eq!(e.n_samples(), 31);
        let p = e.predict_multi(&d.x.block(0, 4, 0, 5)).unwrap();
        assert_eq!(p.shape(), (4, 2));
        let (mean, var) = e.predict_with_uncertainty_multi(&d.x.block(0, 4, 0, 5)).unwrap();
        assert_eq!(mean.shape(), (4, 2));
        assert_eq!(var.len(), 4);
        assert!(var.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn probe_residual_tiny_on_fresh_fit_both_spaces() {
        let d = synth::ecg_like(40, 5, 21);
        let mut g = Vec::new();
        let mut r = Vec::new();
        for space in [Space::Intrinsic, Space::Empirical] {
            let e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, space, false).unwrap();
            assert!(e.probe_dim() > 0);
            for i in 0..e.probe_dim() {
                let res = e.probe_residual_into(i, &mut g, &mut r).unwrap();
                assert!(res < 1e-8, "{space:?} probe {i} residual {res}");
            }
            assert!(e.probe_residual_into(e.probe_dim(), &mut g, &mut r).is_err());
        }
    }

    #[test]
    fn refit_reproduces_folded_engine_exactly() {
        let d = synth::ecg_like(30, 5, 22);
        let mut e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        e.set_fold_eps(Some(0.0));
        // fold stored rows 4 and 7 plus a fresh row, then a removal round
        let fresh = synth::ecg_like(1, 5, 23);
        let xb = Mat::from_fn(3, 5, |r, c| match r {
            0 => d.x[(4, c)],
            1 => fresh.x[(0, c)],
            _ => d.x[(7, c)],
        });
        e.inc_dec(&xb, &[0.3, fresh.y[0], -0.4], &[]).unwrap();
        e.inc_dec(&Mat::zeros(0, 5), &[], &[2]).unwrap();
        let q = d.x.block(0, 8, 0, 5);
        let p_before = e.predict(&q).unwrap();
        let (m_before, v_before) = e.predict_with_uncertainty(&q).unwrap();
        let mult_before = e.multiplicities().to_vec();
        e.refit().unwrap();
        assert_eq!(e.multiplicities(), &mult_before[..], "refit must replay C");
        let p_after = e.predict(&q).unwrap();
        crate::testutil::assert_vec_close(&p_after, &p_before, 1e-9);
        let (m_after, v_after) = e.predict_with_uncertainty(&q).unwrap();
        crate::testutil::assert_vec_close(&m_after, &m_before, 1e-9);
        crate::testutil::assert_vec_close(&v_after, &v_before, 1e-9);
        // and the healed inverse probes clean
        let mut g = Vec::new();
        let mut r = Vec::new();
        for i in 0..e.probe_dim() {
            assert!(e.probe_residual_into(i, &mut g, &mut r).unwrap() < 1e-8);
        }
    }

    #[test]
    fn from_parts_matches_incremental_engine() {
        let d = synth::ecg_like(30, 5, 22);
        let mut e = Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
            .unwrap();
        e.set_fold_eps(Some(0.0));
        let fresh = synth::ecg_like(1, 5, 23);
        let xb = Mat::from_fn(3, 5, |r, c| match r {
            0 => d.x[(4, c)],
            1 => fresh.x[(0, c)],
            _ => d.x[(7, c)],
        });
        e.inc_dec(&xb, &[0.3, fresh.y[0], -0.4], &[]).unwrap();
        let (xv, yv) = e.training_view();
        let rebuilt = Engine::from_parts(
            &xv.clone(),
            &yv.clone(),
            e.multiplicities(),
            e.kernel(),
            e.ridge(),
            e.space(),
            e.has_uncertainty(),
            e.fold_eps(),
        )
        .unwrap();
        assert!(rebuilt.has_uncertainty());
        assert_eq!(rebuilt.fold_eps(), Some(0.0));
        assert_eq!(rebuilt.multiplicities(), e.multiplicities());
        let q = d.x.block(0, 8, 0, 5);
        let p = e.predict(&q).unwrap();
        let pr = rebuilt.predict(&q).unwrap();
        crate::testutil::assert_vec_close(&pr, &p, 1e-9);
        let (m, v) = e.predict_with_uncertainty(&q).unwrap();
        let (mr, vr) = rebuilt.predict_with_uncertainty(&q).unwrap();
        crate::testutil::assert_vec_close(&mr, &m, 1e-9);
        crate::testutil::assert_vec_close(&vr, &v, 1e-9);
    }

    #[test]
    fn from_parts_rejects_malformed_parts() {
        let d = synth::ecg_like(10, 3, 5);
        let ym = Mat::from_vec(10, 1, d.y.clone()).unwrap();
        let k = Kernel::poly(2, 1.0);
        let short = vec![1.0; 9];
        assert!(Engine::from_parts(
            &d.x, &ym, &short, &k, 0.5, Space::Intrinsic, false, None
        )
        .is_err());
        let bad = {
            let mut m = vec![1.0; 10];
            m[3] = 0.0;
            m
        };
        assert!(Engine::from_parts(
            &d.x, &ym, &bad, &k, 0.5, Space::Intrinsic, false, None
        )
        .is_err());
    }
}
