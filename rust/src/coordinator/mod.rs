//! The L3 coordinator: the event loop that turns a pooled sensor stream
//! into batched incremental/decremental model updates while serving
//! predictions.
//!
//! Responsibilities (DESIGN.md §2):
//! * **Routing** — pick intrinsic vs empirical space via the
//!   [`crate::krr::advisor::Advisor`] cost model.
//! * **Batching** — group arrivals into one rank-|H| update per round
//!   ([`crate::streaming::batcher`]).
//! * **Decremental integration** — fold outlier removals into the SAME
//!   batched update (the paper's eq. 15 / eq. 30 fused form).
//! * **State management** — snapshot/rollback of the engine state around
//!   numerically risky updates, counters, timing.
//!
//! The engine state sits behind a `RwLock`, so prediction traffic keeps
//! flowing between (not during) updates — the write lock is held only for
//! the O(J^2 H) update itself. At serving scale even that window is too
//! wide: [`bootstrap_sharded`] delegates the same round policy to the
//! [`crate::serve`] layer, which partitions the stream across K engine
//! replicas (per-shard fused updates + per-shard rollback) and serves
//! reads from epoch-published snapshots that never touch the write path.

pub mod engine;
pub mod experiment;

use crate::config::Space;
use crate::error::Result;
use crate::kernels::Kernel;
use crate::krr::advisor::Advisor;
use crate::metrics::{Counters, LatencyHist, RoundRecord, Timer};
use crate::streaming::batcher::{BatchPolicy, Batcher};
use crate::streaming::outlier::{detect_scored_multi, OutlierConfig};
use crate::streaming::sink::SinkNode;
use crate::streaming::StreamEvent;
use engine::Engine;

use crate::linalg::Mat;
use std::sync::{Arc, RwLock};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Kernel for the model.
    pub kernel: Kernel,
    /// Ridge rho (KRR) — also drives the KBR prior when uncertainty is on.
    pub ridge: f64,
    /// Space override; `None` lets the advisor decide.
    pub space: Option<Space>,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Outlier / decremental policy; `None` disables removals.
    pub outlier: Option<OutlierConfig>,
    /// Track a KBR posterior alongside KRR for uncertainty serving.
    pub with_uncertainty: bool,
    /// Take a full state snapshot before each update for rollback.  The
    /// engines fail *before* mutating state for every realistic error
    /// (shape errors, singular Woodbury core), so this is belt-and-braces;
    /// off by default — it costs an O(N J) deep copy per round.
    pub snapshot_rollback: bool,
    /// Duplicate-input fold radius: `Some(eps)` folds incoming rows within
    /// `eps` (Euclidean) of a stored row into a multiplicity-weighted
    /// existing row instead of growing the store (`0.0` = exact repeats
    /// only); `None` disables folding.
    pub fold_eps: Option<f64>,
}

impl CoordinatorConfig {
    /// Reasonable defaults for the ECG-like workload.
    pub fn default_for(kernel: Kernel) -> Self {
        Self {
            kernel,
            ridge: 0.5,
            space: None,
            batch: BatchPolicy::default(),
            outlier: Some(OutlierConfig::default()),
            with_uncertainty: false,
            snapshot_rollback: false,
            fold_eps: None,
        }
    }
}

/// Shared handle for prediction traffic while the coordinator updates.
#[derive(Clone)]
pub struct ModelHandle {
    inner: Arc<RwLock<Engine>>,
}

impl ModelHandle {
    /// Predict through the current model state.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f64>> {
        self.inner.read().expect("engine lock poisoned").predict(x)
    }

    /// Predict all D output columns: `(B, D)` out.
    pub fn predict_multi(&self, x: &Mat) -> Result<Mat> {
        self.inner.read().expect("engine lock poisoned").predict_multi(x)
    }

    /// Predictive mean + variance (requires `with_uncertainty`).
    pub fn predict_with_uncertainty(&self, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        self.inner
            .read()
            .expect("engine lock poisoned")
            .predict_with_uncertainty(x)
    }

    /// Current training-set size.
    pub fn n_samples(&self) -> usize {
        self.inner.read().expect("engine lock poisoned").n_samples()
    }
}

/// Outcome of one coordinator round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Samples added.
    pub added: usize,
    /// Samples removed (outliers).
    pub removed: usize,
    /// Seconds spent in the batched update.
    pub update_secs: f64,
    /// Training-set size after the round.
    pub n_after: usize,
}

/// The streaming coordinator.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    engine: Arc<RwLock<Engine>>,
    batcher: Batcher,
    /// Counters: rounds, added, removed, rollbacks...
    pub counters: Counters,
    /// Update-latency histogram.
    pub update_latency: LatencyHist,
    /// Per-round record (feeds the paper-style reports).
    pub record: RoundRecord,
}

impl Coordinator {
    /// Bootstrap from an initial training set (`D = 1`).  Space is chosen
    /// by the advisor unless overridden.
    pub fn bootstrap(x: &Mat, y: &[f64], cfg: CoordinatorConfig) -> Result<Self> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec())?;
        Self::bootstrap_multi(x, &ym, cfg)
    }

    /// Bootstrap from an initial `(N, D)` training set.  Space is chosen
    /// by the advisor unless overridden.
    pub fn bootstrap_multi(x: &Mat, y: &Mat, cfg: CoordinatorConfig) -> Result<Self> {
        let advisor = Advisor::default();
        let space = cfg.space.unwrap_or_else(|| {
            advisor
                .choose_space(&cfg.kernel, x.rows(), x.cols(), 4, 2)
                .space
        });
        let mut engine =
            Engine::fit_multi(x, y, &cfg.kernel, cfg.ridge, space, cfg.with_uncertainty)?;
        engine.set_fold_eps(cfg.fold_eps);
        let batcher = Batcher::new(cfg.batch.clone());
        Ok(Self {
            cfg,
            engine: Arc::new(RwLock::new(engine)),
            batcher,
            counters: Counters::default(),
            update_latency: LatencyHist::new(),
            record: RoundRecord::default(),
        })
    }

    /// A cloneable prediction handle.
    pub fn handle(&self) -> ModelHandle {
        ModelHandle { inner: Arc::clone(&self.engine) }
    }

    /// The operating space the engine runs in.
    pub fn space(&self) -> Space {
        self.engine.read().expect("engine lock poisoned").space()
    }

    /// Run one round from a pre-formed batch of events (the bench and test
    /// entry; `run` pulls from a sink).  Applies outlier removals and the
    /// insertion batch as ONE multiple inc/dec update, with rollback on
    /// numerical failure.
    pub fn apply_batch(&mut self, batch: &[StreamEvent]) -> Result<RoundOutcome> {
        let t = Timer::start();
        let mut engine = self.engine.write().expect("engine lock poisoned");
        // 1) nominate decremental candidates on the CURRENT set
        let removals: Vec<usize> = match &self.cfg.outlier {
            Some(ocfg) => {
                let pred = engine.krr().predict_training_multi()?;
                detect_scored_multi(&pred, engine.training_view().1, ocfg)?
                    .into_iter()
                    .map(|v| v.index)
                    .collect()
            }
            None => Vec::new(),
        };
        // 2) assemble the insertion block across all D target columns
        let dim = engine.dim();
        let d = engine.n_outputs();
        let mut x_new = Mat::zeros(0, dim);
        let mut y_new = Mat::zeros(0, d);
        let mut y_row = Vec::with_capacity(d);
        for ev in batch {
            if ev.n_outputs() != d {
                return Err(crate::error::Error::Config(format!(
                    "event carries {} target columns, engine expects D = {d}",
                    ev.n_outputs()
                )));
            }
            x_new.push_row(&ev.x)?;
            y_row.clear();
            y_row.push(ev.y);
            y_row.extend_from_slice(&ev.y_tail);
            y_new.push_row(&y_row)?;
        }
        // 3) one fused multiple inc/dec update (opt-in snapshot rollback;
        //    engines fail before mutation for all realistic error paths)
        let snapshot = self.cfg.snapshot_rollback.then(|| engine.snapshot());
        match engine.inc_dec_multi(&x_new, &y_new, &removals) {
            Ok(()) => {}
            Err(e) => {
                if let Some(snap) = snapshot {
                    engine.restore(snap);
                    self.counters.inc("rollbacks");
                }
                return Err(e);
            }
        }
        let folded = engine.last_round_folds();
        let dt = t.elapsed();
        let outcome = RoundOutcome {
            added: batch.len(),
            removed: removals.len(),
            update_secs: dt,
            n_after: engine.n_samples(),
        };
        drop(engine);
        self.counters.inc("rounds");
        self.counters.add("added", outcome.added as u64);
        self.counters.add("removed", outcome.removed as u64);
        self.counters.add("folded", folded as u64);
        self.update_latency.record(dt);
        self.record.push("multiple", dt);
        self.record.labels.push(outcome.n_after.to_string());
        Ok(outcome)
    }

    /// Pull-and-apply loop over a sink until the stream goes quiet or
    /// `max_rounds` is reached.  Returns the outcomes.
    pub fn run(&mut self, sink: &mut SinkNode, max_rounds: usize) -> Result<Vec<RoundOutcome>> {
        let mut outcomes = Vec::new();
        for _ in 0..max_rounds {
            let batch = self.batcher.next_batch(sink);
            if batch.is_empty() {
                break;
            }
            outcomes.push(self.apply_batch(&batch)?);
        }
        Ok(outcomes)
    }
}

/// Delegate a coordinator-style deployment to the sharded serving layer:
/// the same round policy (`cfg`), but partitioned across `shards`
/// independent engines with per-shard batching, per-shard rollback, and
/// epoch-published reads. See [`crate::serve`] for the read/write
/// semantics; this is the upgrade path once a single engine's update
/// window starts gating prediction throughput.
pub fn bootstrap_sharded(
    x: &Mat,
    y: &[f64],
    cfg: CoordinatorConfig,
    shards: usize,
    placement: crate::serve::Placement,
) -> Result<crate::serve::ShardRouter> {
    crate::serve::ShardRouter::bootstrap(
        x,
        y,
        crate::serve::ServeConfig { shards, placement, base: cfg },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::streaming::source::{SensorNode, SourceConfig};
    use std::time::Duration;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            kernel: Kernel::poly(2, 1.0),
            ridge: 0.5,
            space: None,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
            outlier: Some(OutlierConfig { z_threshold: 5.0, max_removals: 2 }),
            with_uncertainty: false,
            snapshot_rollback: true,
            fold_eps: None,
        }
    }

    #[test]
    fn bootstrap_routes_to_intrinsic_for_ecg_regime() {
        let d = synth::ecg_like(300, 21, 1);
        let c = Coordinator::bootstrap(&d.x, &d.y, cfg()).unwrap();
        assert_eq!(c.space(), Space::Intrinsic);
    }

    #[test]
    fn apply_batch_updates_model() {
        let d = synth::ecg_like(200, 8, 2);
        let extra = synth::ecg_like(4, 8, 3);
        let mut c = Coordinator::bootstrap(&d.x, &d.y, cfg()).unwrap();
        let events: Vec<StreamEvent> = (0..4)
            .map(|i| StreamEvent::single(extra.x.row(i).to_vec(), extra.y[i], 0, i as u64))
            .collect();
        let before = c.handle().n_samples();
        let out = c.apply_batch(&events).unwrap();
        assert_eq!(out.added, 4);
        assert_eq!(c.handle().n_samples(), before + 4 - out.removed);
        assert_eq!(c.counters.get("rounds"), 1);
    }

    #[test]
    fn run_consumes_stream_end_to_end() {
        let base = synth::ecg_like(150, 8, 4);
        let streamed = synth::ecg_like(24, 8, 5);
        let mut sink = SinkNode::new(32);
        let h = SensorNode::new(streamed, SourceConfig::default()).spawn(sink.sender());
        let mut c = Coordinator::bootstrap(&base.x, &base.y, cfg()).unwrap();
        let outcomes = c.run(&mut sink, 100).unwrap();
        h.join().unwrap();
        let added: usize = outcomes.iter().map(|o| o.added).sum();
        assert_eq!(added, 24);
        assert!(c.record.rounds.get("multiple").unwrap().len() >= 6);
    }

    #[test]
    fn handle_predicts_concurrently() {
        let d = synth::ecg_like(120, 8, 6);
        let c = Coordinator::bootstrap(&d.x, &d.y, cfg()).unwrap();
        let handle = c.handle();
        let test = synth::ecg_like(10, 8, 7);
        let preds = handle.predict(&test.x).unwrap();
        assert_eq!(preds.len(), 10);
    }

    #[test]
    fn bootstrap_sharded_delegates_round_policy() {
        let d = synth::ecg_like(120, 8, 9);
        let r =
            bootstrap_sharded(&d.x, &d.y, cfg(), 3, crate::serve::Placement::RoundRobin)
                .unwrap();
        assert_eq!(r.num_shards(), 3);
        assert_eq!(r.n_samples(), 120);
        let p = r.handle().predict(&d.x.block(0, 4, 0, 8)).unwrap();
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn uncertainty_handle_works() {
        let d = synth::ecg_like(80, 6, 8);
        let mut config = cfg();
        config.with_uncertainty = true;
        let c = Coordinator::bootstrap(&d.x, &d.y, config).unwrap();
        let (mu, var) = c
            .handle()
            .predict_with_uncertainty(&d.x.block(0, 5, 0, 6))
            .unwrap();
        assert_eq!(mu.len(), 5);
        assert!(var.iter().all(|&v| v > 0.0));
    }
}
