//! The paper-evaluation driver: run the three update strategies over the
//! same stream of +|C|/−|R| rounds, timing each round per strategy and
//! checking the accuracy-invariance claim.
//!
//! This is shared by `mikrr eval`, `examples/paper_eval.rs` and
//! `rust/benches/paper_tables.rs`, so every table/figure comes from one
//! code path.

use crate::baselines::{Nonincremental, SingleIncKbr, SingleIncremental};
use crate::config::Space;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kbr::{KbrHyper, KbrModel};
use crate::kernels::Kernel;
use crate::krr::empirical::EmpiricalKrr;
use crate::krr::intrinsic::IntrinsicKrr;
use crate::krr::{classification_accuracy, KrrModel};
use crate::linalg::Mat;
use crate::metrics::{RoundRecord, Timer};
use crate::util::prng::Rng;

/// Which strategies to run (all by default; the nonincremental baseline can
/// be skipped for quick passes — it dominates wall-clock).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// The proposed batched update (one rank-|H| op per round).
    Multiple,
    /// Rank-1 updates, one per insertion/removal.
    Single,
    /// Full retrain per round.
    None,
}

impl Strategy {
    /// Metric-row name.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Multiple => "multiple",
            Strategy::Single => "single",
            Strategy::None => "none",
        }
    }
}

/// The result of one experiment cell.
pub struct StrategyReport {
    /// Per-strategy per-round seconds (+ labels = sample counts).
    pub record: RoundRecord,
    /// Held-out classification accuracy after the final round (multiple
    /// strategy; the others are asserted equal when run).
    pub accuracy: f64,
    /// Did all executed strategies end with matching predictions?
    pub strategies_agree: bool,
}

/// Pre-drawn round plan so every strategy sees the identical operations.
struct RoundPlan {
    x_new: Mat,
    y_new: Vec<f64>,
    remove: Vec<usize>,
}

fn plan_rounds(
    data: &Dataset,
    train: usize,
    rounds: usize,
    inc: usize,
    dec: usize,
    seed: u64,
) -> Result<(Dataset, Dataset, Vec<RoundPlan>)> {
    let need = train + rounds * inc;
    if data.len() < need + 1 {
        return Err(Error::Config(format!(
            "dataset has {} samples, need {need}+ for train={train}, {rounds} rounds",
            data.len()
        )));
    }
    let base_idx: Vec<usize> = (0..train).collect();
    let base = data.subset(&base_idx);
    let test_idx: Vec<usize> = (need..data.len()).collect();
    let test = data.subset(&test_idx);
    let mut rng = Rng::new(seed ^ 0x9D5);
    let mut n_cur = train;
    let mut next = train;
    let mut plans = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let idx: Vec<usize> = (next..next + inc).collect();
        next += inc;
        let x_new = data.x.select_rows(&idx);
        let y_new: Vec<f64> = idx.iter().map(|&i| data.y[i]).collect();
        let mut remove = rng.sample_indices(n_cur, dec.min(n_cur));
        remove.sort_unstable();
        n_cur = n_cur + inc - remove.len();
        plans.push(RoundPlan { x_new, y_new, remove });
    }
    Ok((base, test, plans))
}

/// Run a KRR experiment cell over the given strategies.
#[allow(clippy::too_many_arguments)]
pub fn run_krr(
    data: &Dataset,
    kernel: &Kernel,
    ridge: f64,
    space: Space,
    train: usize,
    rounds: usize,
    inc: usize,
    dec: usize,
    seed: u64,
    strategies: &[Strategy],
) -> Result<StrategyReport> {
    let (base, test, plans) = plan_rounds(data, train, rounds, inc, dec, seed)?;
    let mut record = RoundRecord::default();
    let mut n_label = train;
    for p in &plans {
        n_label = n_label + p.y_new.len() - p.remove.len();
        record.labels.push(n_label.to_string());
    }

    let mut final_preds: Vec<Vec<f64>> = Vec::new();

    for &strat in strategies {
        match strat {
            Strategy::Multiple => {
                let mut model: Box<dyn KrrModel> = match space {
                    Space::Intrinsic => {
                        Box::new(IntrinsicKrr::fit(&base.x, &base.y, kernel, ridge)?)
                    }
                    Space::Empirical => {
                        Box::new(EmpiricalKrr::fit(&base.x, &base.y, kernel, ridge)?)
                    }
                };
                for p in &plans {
                    let t = Timer::start();
                    model.inc_dec(&p.x_new, &p.y_new, &p.remove)?;
                    record.push(strat.name(), t.elapsed());
                }
                final_preds.push(model.predict(&test.x)?);
            }
            Strategy::Single => {
                let mut model =
                    SingleIncremental::fit(&base.x, &base.y, kernel, ridge, space)?;
                for p in &plans {
                    let t = Timer::start();
                    model.round(&p.x_new, &p.y_new, &p.remove)?;
                    record.push(strat.name(), t.elapsed());
                }
                final_preds.push(model.predict(&test.x)?);
            }
            Strategy::None => {
                let mut model = Nonincremental::fit(&base.x, &base.y, kernel, ridge, space)?;
                for p in &plans {
                    let t = Timer::start();
                    model.round(&p.x_new, &p.y_new, &p.remove)?;
                    record.push(strat.name(), t.elapsed());
                }
                final_preds.push(model.predict(&test.x)?);
            }
        }
    }

    let accuracy = final_preds
        .first()
        .map(|p| classification_accuracy(p, &test.y))
        .unwrap_or(0.0);
    let strategies_agree = final_preds.windows(2).all(|w| {
        w[0].iter()
            .zip(&w[1])
            .all(|(a, b)| (a - b).abs() < 1e-5 * a.abs().max(1.0))
    });
    Ok(StrategyReport { record, accuracy, strategies_agree })
}

/// Run a KBR experiment cell (paper Figs. 7-8 / Tables X-XII: multiple vs
/// single only).
#[allow(clippy::too_many_arguments)]
pub fn run_kbr(
    data: &Dataset,
    kernel: &Kernel,
    hyper: KbrHyper,
    train: usize,
    rounds: usize,
    inc: usize,
    dec: usize,
    seed: u64,
    run_single: bool,
) -> Result<StrategyReport> {
    let (base, test, plans) = plan_rounds(data, train, rounds, inc, dec, seed)?;
    let mut record = RoundRecord::default();
    let mut n_label = train;
    for p in &plans {
        n_label = n_label + p.y_new.len() - p.remove.len();
        record.labels.push(n_label.to_string());
    }

    let mut multiple = KbrModel::fit(&base.x, &base.y, kernel, hyper)?;
    for p in &plans {
        let t = Timer::start();
        multiple.inc_dec(&p.x_new, &p.y_new, &p.remove)?;
        record.push("multiple", t.elapsed());
    }
    let pm = multiple.predict(&test.x)?;

    let mut strategies_agree = true;
    if run_single {
        let mut single = SingleIncKbr::fit(&base.x, &base.y, kernel, hyper)?;
        for p in &plans {
            let t = Timer::start();
            single.round(&p.x_new, &p.y_new, &p.remove)?;
            record.push("single", t.elapsed());
        }
        let ps = single.model().predict(&test.x)?;
        strategies_agree = pm
            .mean
            .iter()
            .zip(&ps.mean)
            .all(|(a, b)| (a - b).abs() < 1e-5 * a.abs().max(1.0));
    }

    let accuracy = classification_accuracy(&pm.mean, &test.y);
    Ok(StrategyReport { record, accuracy, strategies_agree })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn krr_experiment_runs_and_agrees() {
        let data = synth::ecg_like(400, 8, 1);
        let report = run_krr(
            &data,
            &Kernel::poly(2, 1.0),
            0.5,
            Space::Intrinsic,
            200,
            3,
            4,
            2,
            7,
            &[Strategy::Multiple, Strategy::Single, Strategy::None],
        )
        .unwrap();
        assert!(report.strategies_agree, "strategies disagree");
        assert!(report.accuracy > 0.8, "accuracy {}", report.accuracy);
        assert_eq!(report.record.rounds.len(), 3);
        assert_eq!(report.record.log10_rounds("multiple").len(), 3);
        assert_eq!(report.record.labels.len(), 3);
    }

    #[test]
    fn krr_experiment_empirical() {
        let data = synth::drt_like(260, 500, 0.02, 2);
        let report = run_krr(
            &data,
            &Kernel::rbf_radius(50.0),
            0.5,
            Space::Empirical,
            150,
            3,
            4,
            2,
            3,
            &[Strategy::Multiple, Strategy::Single],
        )
        .unwrap();
        assert!(report.strategies_agree);
    }

    #[test]
    fn kbr_experiment_runs() {
        let data = synth::ecg_like(300, 6, 4);
        let report = run_kbr(
            &data,
            &Kernel::poly(2, 1.0),
            KbrHyper::default(),
            150,
            3,
            4,
            2,
            5,
            true,
        )
        .unwrap();
        assert!(report.strategies_agree);
        assert_eq!(report.record.rounds.len(), 2);
    }

    #[test]
    fn insufficient_data_errors() {
        let data = synth::ecg_like(50, 6, 6);
        assert!(run_krr(
            &data,
            &Kernel::poly(2, 1.0),
            0.5,
            Space::Intrinsic,
            45,
            10,
            4,
            2,
            7,
            &[Strategy::Multiple],
        )
        .is_err());
    }
}
