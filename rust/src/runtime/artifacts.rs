//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `manifest.txt` with one line per
//! artifact:
//!
//! ```text
//! artifact woodbury_incdec inputs=f32[253,253];f32[253,6];f32[6] outputs=f32[253,253]
//! ```

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Dtype + dims of one tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type ("f32", "f64", "i32").
    pub dtype: String,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse "f32[253,6]" or "f32[]".
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| Error::Artifact(format!("bad tensor spec {s:?}")))?;
        let dims_s = rest
            .strip_suffix(']')
            .ok_or_else(|| Error::Artifact(format!("bad tensor spec {s:?}")))?;
        let dims = if dims_s.is_empty() {
            Vec::new()
        } else {
            dims_s
                .split(',')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::Artifact(format!("bad dim {d:?} in {s:?}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self { dtype: dtype.to_string(), dims })
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// One artifact's interface.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact / entry name.
    pub name: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs (the HLO returns a tuple).
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// name -> spec
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut artifacts = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next();
            if tag != Some("artifact") {
                return Err(Error::Artifact(format!(
                    "line {}: expected 'artifact', got {tag:?}",
                    lineno + 1
                )));
            }
            let name = parts
                .next()
                .ok_or_else(|| Error::Artifact(format!("line {}: missing name", lineno + 1)))?
                .to_string();
            let mut inputs = Vec::new();
            let mut outputs = Vec::new();
            for p in parts {
                if let Some(v) = p.strip_prefix("inputs=") {
                    inputs = parse_specs(v)?;
                } else if let Some(v) = p.strip_prefix("outputs=") {
                    outputs = parse_specs(v)?;
                } else {
                    return Err(Error::Artifact(format!(
                        "line {}: unknown field {p:?}",
                        lineno + 1
                    )));
                }
            }
            artifacts.insert(name.clone(), ArtifactSpec { name, inputs, outputs });
        }
        Ok(Self { artifacts })
    }

    /// Load from `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text)
    }

    /// Lookup.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }
}

fn parse_specs(v: &str) -> Result<Vec<TensorSpec>> {
    if v.is_empty() {
        return Ok(Vec::new());
    }
    v.split(';').map(TensorSpec::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tensor_specs() {
        let t = TensorSpec::parse("f32[253,6]").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.dims, vec![253, 6]);
        assert_eq!(t.numel(), 1518);
        let s = TensorSpec::parse("f32[]").unwrap();
        assert!(s.dims.is_empty());
        assert_eq!(s.numel(), 1);
        assert!(TensorSpec::parse("f32253").is_err());
    }

    #[test]
    fn parses_manifest() {
        let text = "# comment\n\
            artifact woodbury_incdec inputs=f32[253,253];f32[253,6];f32[6] outputs=f32[253,253]\n\
            artifact krr_refresh inputs=f32[253,253];f32[253];f32[253];f32[];f32[] outputs=f32[253];f32[]\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let w = m.get("woodbury_incdec").unwrap();
        assert_eq!(w.inputs.len(), 3);
        assert_eq!(w.outputs[0].dims, vec![253, 253]);
        let k = m.get("krr_refresh").unwrap();
        assert_eq!(k.inputs[3].dims, Vec::<usize>::new());
        assert_eq!(k.outputs.len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("nonsense line\n").is_err());
        assert!(Manifest::parse("artifact x bogus=1\n").is_err());
    }
}
