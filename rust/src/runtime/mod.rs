//! The AOT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client
//! (`xla` crate, behind the off-by-default `pjrt` cargo feature — without
//! it a stub runtime reports itself unavailable and everything runs on the
//! native linalg path), and executes them from the L3 hot path.
//!
//! [`hybrid::HybridExec`] is the piece the engines actually use: it
//! dispatches to an AOT executable when the live shapes match the
//! artifact's canonical shapes (padding batches with zero columns, which
//! eq. 15 treats as no-ops) and falls back to the native [`crate::linalg`]
//! path otherwise.  Integration tests assert the two paths agree.

pub mod artifacts;
pub mod hybrid;
pub mod pjrt;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use hybrid::HybridExec;
pub use pjrt::PjrtRuntime;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `MIKRR_ARTIFACTS` env override, else
/// `artifacts/` relative to the current dir or the crate manifest dir.
pub fn artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("MIKRR_ARTIFACTS") {
        let pb = std::path::PathBuf::from(p);
        if pb.join("manifest.txt").exists() {
            return Some(pb);
        }
    }
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let pb = std::path::Path::new(base).join(DEFAULT_ARTIFACT_DIR);
        if pb.join("manifest.txt").exists() {
            return Some(pb);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    #[test]
    fn artifact_dir_resolves_when_built() {
        // `make artifacts` must have run for the integration suite; the
        // unit test only checks the lookup does not panic.
        let _ = super::artifact_dir();
    }
}
