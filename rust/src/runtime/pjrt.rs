//! PJRT CPU wrapper over the `xla` crate: load HLO text, compile once,
//! execute many times.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids which xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The real `xla` crate is not part of the offline crate set, so the whole
//! runtime sits behind the off-by-default `pjrt` cargo feature. Without it
//! this module compiles a **stub** [`PjrtRuntime`] whose `load_dir` always
//! fails with a descriptive error — [`crate::runtime::HybridExec`] then
//! stays on the native f64 linalg path, which is the production
//! configuration in this container. With the feature on, the `xla`
//! dependency resolves to the in-tree API stub (`rust/vendor/xla`) unless
//! repointed at the real wrapper — CI's `cargo check --features pjrt` lane
//! type-checks this module against that surface so it cannot rot, and at
//! run time the stub fails client construction, keeping the same native
//! fallback. The host-side [`Tensor`] type is feature-independent (tests
//! and the hybrid dispatch use it either way).

use crate::error::{Error, Result};
#[cfg(feature = "pjrt")]
use crate::runtime::artifacts::ArtifactSpec;
use crate::runtime::artifacts::Manifest;
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::Path;

/// Host-side tensor (f32, row-major) crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dims (empty = scalar).
    pub dims: Vec<usize>,
    /// Row-major data.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Self { dims: vec![], data: vec![v] }
    }

    /// From an f64 slice (converted) with dims.
    pub fn from_f64(dims: Vec<usize>, data: &[f64]) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        Self { dims, data: data.iter().map(|&v| v as f32).collect() }
    }

    /// From a row-major [`crate::linalg::Mat`].
    pub fn from_mat(m: &crate::linalg::Mat) -> Self {
        Self::from_f64(vec![m.rows(), m.cols()], m.as_slice())
    }

    /// Into f64 data.
    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&v| v as f64).collect()
    }

    /// Into a [`crate::linalg::Mat`] (requires 2 dims).
    pub fn to_mat(&self) -> Result<crate::linalg::Mat> {
        if self.dims.len() != 2 {
            return Err(Error::Runtime(format!("tensor dims {:?} not a matrix", self.dims)));
        }
        crate::linalg::Mat::from_vec(self.dims[0], self.dims[1], self.to_f64())
    }
}

#[cfg(feature = "pjrt")]
fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.dims.is_empty() {
        // 0-d scalar: reshape to []
        lit.reshape(&[]).map_err(wrap)
    } else {
        let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).map_err(wrap)
    }
}

#[cfg(feature = "pjrt")]
fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(wrap)?;
    Ok(Tensor { dims, data })
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A compiled artifact.
#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The PJRT runtime: one CPU client + all compiled artifacts.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    compiled: BTreeMap<String, Compiled>,
    /// The manifest the artifacts were loaded from.
    pub manifest: Manifest,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load every artifact in `dir` (per its manifest) and compile.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let mut compiled = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap)?;
            compiled.insert(name.clone(), Compiled { exe, spec: spec.clone() });
        }
        Ok(Self { client, compiled, manifest })
    }

    /// Names of loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact with host tensors; returns the output tuple.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))?;
        if inputs.len() != c.spec.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                c.spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&c.spec.inputs).enumerate() {
            if t.dims != s.dims {
                return Err(Error::Artifact(format!(
                    "{name}: input {i} dims {:?} != manifest {:?}",
                    t.dims, s.dims
                )));
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let bufs = c.exe.execute::<xla::Literal>(&lits).map_err(wrap)?;
        let result = bufs[0][0].to_literal_sync().map_err(wrap)?;
        // AOT lowers with return_tuple=True — decompose
        let parts = result.to_tuple().map_err(wrap)?;
        parts.iter().map(from_literal).collect()
    }
}

/// Feature-off stub: same API surface, but can never be constructed —
/// [`PjrtRuntime::load_dir`] always errors, so `HybridExec::auto()` falls
/// back to the native path and the accessors below are statically
/// unreachable (the uninhabited field proves it to the compiler).
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    /// The manifest the artifacts were loaded from.
    pub manifest: Manifest,
    never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load_dir(_dir: &Path) -> Result<Self> {
        Err(Error::Runtime(
            "built without the `pjrt` feature: enable it (and vendor the \
             offline `xla` crate — see rust/Cargo.toml) to load AOT artifacts"
                .to_string(),
        ))
    }

    /// Names of loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        match self.never {}
    }

    /// Execute an artifact with host tensors; returns the output tuple.
    pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let m = crate::linalg::Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f64);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.dims, vec![3, 2]);
        let back = t.to_mat().unwrap();
        assert!(back.max_abs_diff(&m) < 1e-6);
        assert!(Tensor::scalar(1.5).to_mat().is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_dir_always_errors() {
        let err = PjrtRuntime::load_dir(Path::new("/nonexistent")).err();
        let msg = err.expect("stub must refuse to load").to_string();
        assert!(msg.contains("pjrt"), "unhelpful stub error: {msg}");
    }
}
