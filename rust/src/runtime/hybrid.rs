//! Hybrid execution: AOT artifact when shapes match, native linalg
//! otherwise.
//!
//! The canonical artifact shapes (DESIGN.md §6) target the ECG/poly2
//! configuration (J = 253, H_max = 6).  Batches with |H| < 6 are padded
//! with zero columns — an exact no-op under eq. (15) — so every paper-
//! default round (+4/−2) hits the artifact path when artifacts are
//! present.  Everything else (poly3's J = 2024, empirical mode, odd batch
//! sizes) falls back to native f64 linalg.
//!
//! The same object also exposes the artifact-backed predict head and the
//! Gram block kernels, with the same dispatch rule.

use crate::error::Result;
use crate::linalg::woodbury::{incdec, IncDecWork};
use crate::linalg::Mat;
use crate::runtime::pjrt::{PjrtRuntime, Tensor};
use std::sync::atomic::{AtomicU64, Ordering};

/// Dispatching executor with hit/miss counters.
pub struct HybridExec {
    runtime: Option<PjrtRuntime>,
    /// Artifact-path invocations.
    pub aot_hits: AtomicU64,
    /// Native-path invocations.
    pub native_hits: AtomicU64,
}

impl HybridExec {
    /// With a loaded runtime.
    pub fn new(runtime: Option<PjrtRuntime>) -> Self {
        Self { runtime, aot_hits: AtomicU64::new(0), native_hits: AtomicU64::new(0) }
    }

    /// Try to load the default artifact dir; native-only on failure.
    pub fn auto() -> Self {
        let runtime = crate::runtime::artifact_dir()
            .and_then(|dir| PjrtRuntime::load_dir(&dir).ok());
        Self::new(runtime)
    }

    /// Is the AOT path available at all?
    pub fn has_aot(&self) -> bool {
        self.runtime.is_some()
    }

    /// (aot, native) hit counts.
    pub fn stats(&self) -> (u64, u64) {
        (self.aot_hits.load(Ordering::Relaxed), self.native_hits.load(Ordering::Relaxed))
    }

    /// Batched Woodbury update (eq. 15) with artifact dispatch.
    pub fn woodbury_incdec(&self, s_inv: &Mat, phi_h: &Mat, signs: &[f64]) -> Result<Mat> {
        if let Some(rt) = &self.runtime {
            if let Some(spec) = rt.manifest.get("woodbury_incdec") {
                let j = spec.inputs[0].dims[0];
                let h_max = spec.inputs[1].dims[1];
                if s_inv.rows() == j && phi_h.cols() <= h_max {
                    // pad to H_max with zero columns (no-ops)
                    let mut phi_p = Mat::zeros(j, h_max);
                    for r in 0..j {
                        let src = phi_h.row(r);
                        phi_p.row_mut(r)[..src.len()].copy_from_slice(src);
                    }
                    let mut signs_p = signs.to_vec();
                    signs_p.resize(h_max, 1.0);
                    let out = rt.execute(
                        "woodbury_incdec",
                        &[
                            Tensor::from_mat(s_inv),
                            Tensor::from_mat(&phi_p),
                            Tensor::from_f64(vec![h_max], &signs_p),
                        ],
                    )?;
                    self.aot_hits.fetch_add(1, Ordering::Relaxed);
                    return out[0].to_mat();
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        let mut work = IncDecWork::default();
        let mut out = s_inv.clone();
        crate::linalg::woodbury::incdec_into(&mut out, phi_h, signs, &mut work)?;
        Ok(out)
    }

    /// Native-only reference for cross-checking in tests.
    pub fn woodbury_native(&self, s_inv: &Mat, phi_h: &Mat, signs: &[f64]) -> Result<Mat> {
        incdec(s_inv, phi_h, signs)
    }

    /// Head refresh (u, b) via the `krr_refresh` artifact when shapes fit.
    pub fn krr_refresh(
        &self,
        s_inv: &Mat,
        psum: &[f64],
        py: &[f64],
        sy: f64,
        n: f64,
    ) -> Result<(Vec<f64>, f64)> {
        if let Some(rt) = &self.runtime {
            if let Some(spec) = rt.manifest.get("krr_refresh") {
                let j = spec.inputs[0].dims[0];
                if s_inv.rows() == j {
                    let out = rt.execute(
                        "krr_refresh",
                        &[
                            Tensor::from_mat(s_inv),
                            Tensor::from_f64(vec![j], psum),
                            Tensor::from_f64(vec![j], py),
                            Tensor::scalar(sy as f32),
                            Tensor::scalar(n as f32),
                        ],
                    )?;
                    self.aot_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((out[0].to_f64(), out[1].data[0] as f64));
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        // native: same math as IntrinsicKrr::refresh_head
        let sp = crate::linalg::gemm::gemv(s_inv, psum)?;
        let denom = n - crate::linalg::matrix::dot(psum, &sp);
        let b = (sy - crate::linalg::matrix::dot(&sp, py)) / denom;
        let spy = crate::linalg::gemm::gemv(s_inv, py)?;
        let u = spy.iter().zip(&sp).map(|(a, s)| a - s * b).collect();
        Ok((u, b))
    }

    /// Gram block through the `gram_poly2`/`gram_rbf` artifacts when the
    /// block is exactly the canonical (128, M) shape.
    pub fn gram_block(
        &self,
        kernel: &crate::kernels::Kernel,
        x: &Mat,
        y: &Mat,
    ) -> Result<Mat> {
        use crate::kernels::Kernel;
        if let Some(rt) = &self.runtime {
            let name = match kernel {
                Kernel::Poly { degree: 2, .. } => Some("gram_poly2"),
                Kernel::Rbf { .. } => Some("gram_rbf"),
                _ => None,
            };
            if let Some(name) = name {
                if let Some(spec) = rt.manifest.get(name) {
                    if x.rows() == spec.inputs[0].dims[0]
                        && x.cols() == spec.inputs[0].dims[1]
                        && y.rows() == spec.inputs[1].dims[0]
                        && y.cols() == spec.inputs[1].dims[1]
                    {
                        let out = rt.execute(
                            name,
                            &[Tensor::from_mat(x), Tensor::from_mat(y)],
                        )?;
                        self.aot_hits.fetch_add(1, Ordering::Relaxed);
                        return out[0].to_mat();
                    }
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        Ok(kernel.gram(x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{random_mat, random_spd};
    use crate::util::prng::Rng;

    #[test]
    fn native_fallback_without_runtime() {
        let ex = HybridExec::new(None);
        assert!(!ex.has_aot());
        let mut rng = Rng::new(1);
        let s = random_spd(&mut rng, 20, 20.0);
        let s_inv = crate::linalg::solve::spd_inverse(&s).unwrap();
        let phi = random_mat(&mut rng, 20, 3, 0.2);
        let got = ex.woodbury_incdec(&s_inv, &phi, &[1.0, 1.0, -1.0]).unwrap();
        let want = ex.woodbury_native(&s_inv, &phi, &[1.0, 1.0, -1.0]).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-12);
        assert_eq!(ex.stats().0, 0);
        assert!(ex.stats().1 >= 1);
    }

    #[test]
    fn refresh_native_matches_model() {
        let ex = HybridExec::new(None);
        let mut rng = Rng::new(2);
        let s = random_spd(&mut rng, 10, 10.0);
        let s_inv = crate::linalg::solve::spd_inverse(&s).unwrap();
        let psum = rng.gaussian_vec(10);
        let py = rng.gaussian_vec(10);
        let (u, b) = ex.krr_refresh(&s_inv, &psum, &py, 1.5, 50.0).unwrap();
        assert_eq!(u.len(), 10);
        assert!(b.is_finite());
    }
}
