//! Metrics: wall-clock timers, per-round records matching the paper's
//! log10-seconds reporting, cumulative curves (the figures) and table
//! renderers (the tables).

use crate::util::{fmt_secs, log10_time};
use std::collections::BTreeMap;
use std::time::Instant;

/// A simple scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart, returning the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let dt = self.elapsed();
        self.start = Instant::now();
        dt
    }
}

/// Per-strategy per-round timing record for one experiment
/// (one paper table: rows = strategies, columns = rounds).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    /// Strategy name -> per-round seconds.
    pub rounds: BTreeMap<String, Vec<f64>>,
    /// Column labels (the paper uses the post-round sample counts).
    pub labels: Vec<String>,
}

impl RoundRecord {
    /// Record one round's time for a strategy.
    pub fn push(&mut self, strategy: &str, seconds: f64) {
        self.rounds.entry(strategy.to_string()).or_default().push(seconds);
    }

    /// Per-round log10 seconds for a strategy (paper table rows).
    pub fn log10_rounds(&self, strategy: &str) -> Vec<f64> {
        self.rounds
            .get(strategy)
            .map(|v| v.iter().map(|&s| log10_time(s)).collect())
            .unwrap_or_default()
    }

    /// Cumulative log10 seconds (paper figure curves).
    pub fn cumulative_log10(&self, strategy: &str) -> Vec<f64> {
        let mut out = Vec::new();
        let mut acc = 0.0;
        if let Some(v) = self.rounds.get(strategy) {
            for &s in v {
                acc += s;
                out.push(log10_time(acc));
            }
        }
        out
    }

    /// Mean per-round seconds (paper Table IX / XII cells).
    pub fn mean_seconds(&self, strategy: &str) -> f64 {
        self.rounds
            .get(strategy)
            .map(|v| crate::util::stats::mean(v))
            .unwrap_or(0.0)
    }

    /// Improvement fold of `a` over `b` (paper: multiple vs single).
    pub fn improvement_fold(&self, fast: &str, slow: &str) -> f64 {
        let f = self.mean_seconds(fast);
        let s = self.mean_seconds(slow);
        if f <= 0.0 {
            0.0
        } else {
            s / f
        }
    }

    /// Render as a paper-style table (log10 per round).
    pub fn render_table(&self, title: &str) -> String {
        let mut t = crate::benchlib::Table::new(title, self.labels.clone());
        for name in self.rounds.keys() {
            t.row(name.clone(), self.log10_rounds(name));
        }
        t.render()
    }

    /// Render cumulative curves as ASCII series (one line per strategy).
    pub fn render_curves(&self, title: &str) -> String {
        let mut out = format!("\n--- {title} (cumulative log10 s) ---\n");
        for name in self.rounds.keys() {
            let c = self.cumulative_log10(name);
            let cells: Vec<String> = c.iter().map(|v| format!("{v:>9.4}")).collect();
            out.push_str(&format!("{:<10} {}\n", name, cells.join(" ")));
        }
        out
    }
}

/// Multi-output regression error report: one entry per target column
/// plus the pooled (all columns flattened) figure.
#[derive(Clone, Debug, Default)]
pub struct MultiOutputError {
    /// Per-column errors, length D.
    pub per_column: Vec<f64>,
    /// Pooled error over all N*D residuals.
    pub pooled: f64,
}

fn multi_output_error(
    pred: &crate::linalg::Mat,
    truth: &crate::linalg::Mat,
    rmse: bool,
) -> crate::error::Result<MultiOutputError> {
    if pred.shape() != truth.shape() {
        return Err(crate::error::Error::Config(format!(
            "metrics: prediction shape {:?} != truth shape {:?}",
            pred.shape(),
            truth.shape()
        )));
    }
    let (n, d) = pred.shape();
    if n == 0 || d == 0 {
        return Err(crate::error::Error::Config(
            "metrics: empty prediction matrix".into(),
        ));
    }
    let mut per_column = vec![0.0; d];
    for i in 0..n {
        let (pr, tr) = (pred.row(i), truth.row(i));
        for j in 0..d {
            let e = pr[j] - tr[j];
            per_column[j] += if rmse { e * e } else { e.abs() };
        }
    }
    let pooled_sum: f64 = per_column.iter().sum();
    let pooled = if rmse {
        (pooled_sum / (n * d) as f64).sqrt()
    } else {
        pooled_sum / (n * d) as f64
    };
    for c in per_column.iter_mut() {
        *c = if rmse { (*c / n as f64).sqrt() } else { *c / n as f64 };
    }
    Ok(MultiOutputError { per_column, pooled })
}

/// Root-mean-square error of an (N, D) prediction against (N, D) truth,
/// per target column and pooled.
pub fn rmse_multi(
    pred: &crate::linalg::Mat,
    truth: &crate::linalg::Mat,
) -> crate::error::Result<MultiOutputError> {
    multi_output_error(pred, truth, true)
}

/// Mean absolute error of an (N, D) prediction against (N, D) truth,
/// per target column and pooled.
pub fn mae_multi(
    pred: &crate::linalg::Mat,
    truth: &crate::linalg::Mat,
) -> crate::error::Result<MultiOutputError> {
    multi_output_error(pred, truth, false)
}

/// Lightweight named-counter registry — the string-keyed
/// **aggregation/rendering surface** for fleet views.
///
/// Since the telemetry PR this type is *deprecated for hot-path
/// recording*: serve/net/persist increments go through the lock-free
/// [`crate::telemetry::Registry`] (`MetricId` slots, relaxed atomics,
/// zero-alloc), and owners expose `counters()` views built from their
/// registries via [`crate::telemetry::Registry::counters`]. `inc`/`add`
/// here allocate (`BTreeMap` + `String`) and require `&mut`, which is
/// exactly what a hot path must not do — CI greps forbid new
/// string-keyed increments outside `metrics/` and the coordinator/sink
/// legacy call sites. Merging, `get`, `iter`, and `render` remain the
/// supported aggregation API.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    /// Increment a counter. Aggregation surface only — hot paths record
    /// through `telemetry::Registry` (see the type docs).
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    /// Add to a counter. Aggregation surface only — hot paths record
    /// through `telemetry::Registry` (see the type docs).
    pub fn add(&mut self, name: &str, v: u64) {
        *self.map.entry(name.to_string()).or_default() += v;
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    /// Iterate `(name, value)` pairs in name order (inspection /
    /// aggregation across shards).
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Merge another registry into this one, summing shared names — the
    /// aggregation primitive for fleet-wide views (per-shard durability
    /// counters rolled up by `ShardRouter::durability_counters`).
    pub fn merge_from(&mut self, other: &Counters) {
        for (k, v) in other.iter() {
            self.add(k, v);
        }
    }

    /// Render all counters.
    pub fn render(&self) -> String {
        self.map
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Histogram of durations with fixed log-spaced buckets (for latency
/// reporting in the serving examples and `NetStats`).
///
/// **O(1) memory forever**: only the fixed bucket counts plus running
/// count/sum/min/max are kept — the old unbounded `samples: Vec<f64>`
/// (a slow leak on a serving path) is gone, and a warm `record` is
/// allocation-free (asserted in `rust/tests/alloc_count.rs`).
/// Percentiles are derived from the bucket counts: the covering
/// bucket's upper edge clamped to the observed `[min, max]`, which
/// bounds the relative error at one bucket ratio (10^0.25 ≈ 1.78×) and
/// is exact at the extremes.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// New histogram with ns..10s log buckets.
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1e-7;
        while b < 10.0 {
            bounds.push(b);
            b *= 10.0_f64.powf(0.25);
        }
        let n = bounds.len();
        Self {
            bounds,
            counts: vec![0; n + 1],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one duration (seconds). O(1), allocation-free.
    pub fn record(&mut self, seconds: f64) {
        let idx = self.bounds.partition_point(|&b| b < seconds);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
        self.min = self.min.min(seconds);
        self.max = self.max.max(seconds);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.total as usize
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Percentile derived from the bucket counts: the upper edge of the
    /// bucket covering the rank, clamped to the observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let hi = self.bounds.get(idx).copied().unwrap_or(self.max);
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} p50={} p95={} p99={} max={}",
            self.count(),
            fmt_secs(self.percentile(50.0)),
            fmt_secs(self.percentile(95.0)),
            fmt_secs(self.percentile(99.0)),
            fmt_secs(self.max()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_record_math() {
        let mut r = RoundRecord::default();
        r.labels = vec!["100".into(), "102".into()];
        r.push("multiple", 0.1);
        r.push("multiple", 0.1);
        r.push("single", 0.4);
        r.push("single", 0.4);
        let l = r.log10_rounds("multiple");
        assert!((l[0] + 1.0).abs() < 1e-9);
        let c = r.cumulative_log10("multiple");
        assert!((c[1] - (0.2f64).log10()).abs() < 1e-9);
        assert!((r.improvement_fold("multiple", "single") - 4.0).abs() < 1e-9);
        let tbl = r.render_table("Table T");
        assert!(tbl.contains("multiple"));
        let curves = r.render_curves("Fig F");
        assert!(curves.contains("single"));
    }

    #[test]
    fn counters() {
        let mut c = Counters::default();
        c.inc("updates");
        c.add("updates", 2);
        assert_eq!(c.get("updates"), 3);
        assert_eq!(c.get("missing"), 0);
        assert!(c.render().contains("updates=3"));
    }

    #[test]
    fn counters_merge_sums_shared_names() {
        let mut a = Counters::default();
        a.add("rounds", 3);
        a.add("heals", 1);
        let mut b = Counters::default();
        b.add("rounds", 2);
        b.add("snapshots_written", 4);
        a.merge_from(&b);
        assert_eq!(a.get("rounds"), 5);
        assert_eq!(a.get("heals"), 1);
        assert_eq!(a.get("snapshots_written"), 4);
        assert_eq!(b.get("rounds"), 2, "source is untouched");
    }

    #[test]
    fn latency_hist() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        assert!(p50 > 4e-4 && p50 < 6e-4, "p50={p50}");
        assert!(h.summary().contains("p99"));
        // extremes are exact: the clamp pins p100 to the true max and
        // low quantiles to at least the true min
        assert_eq!(h.percentile(100.0), 1e-3);
        assert!(h.percentile(0.0) >= 1e-5);
        assert_eq!(h.max(), 1e-3);
        assert!((h.mean() - 50.5e-5).abs() < 1e-12);
    }

    #[test]
    fn latency_hist_memory_is_bounded() {
        // regression for the unbounded `samples: Vec<f64>`: a histogram
        // that has seen a million samples is byte-for-byte the same size
        // as a fresh one — only the fixed bucket counts grow in value
        let fresh = LatencyHist::new();
        let mut h = LatencyHist::new();
        for i in 0..1_000_000u64 {
            h.record((i % 977) as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1_000_000);
        assert_eq!(h.counts.len(), fresh.counts.len());
        assert_eq!(h.counts.capacity(), fresh.counts.capacity());
        assert_eq!(h.bounds.len(), fresh.bounds.len());
        assert_eq!(h.counts.iter().sum::<u64>(), 1_000_000);
        // quantiles still answer sanely off the bucket counts
        let p99 = h.percentile(99.0);
        assert!(p99 > 9e-4 && p99 <= 976e-6, "p99={p99}");
        assert_eq!(h.percentile(100.0), 976e-6);
    }

    #[test]
    fn multi_output_rmse_and_mae() {
        use crate::linalg::Mat;
        let pred = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let truth = Mat::from_vec(2, 2, vec![0.0, 2.0, 3.0, 2.0]).unwrap();
        let r = rmse_multi(&pred, &truth).unwrap();
        // col0 residuals (1, 0) -> rmse sqrt(0.5); col1 residuals (0, 2) -> sqrt(2)
        assert!((r.per_column[0] - 0.5f64.sqrt()).abs() < 1e-12);
        assert!((r.per_column[1] - 2.0f64.sqrt()).abs() < 1e-12);
        assert!((r.pooled - (5.0f64 / 4.0).sqrt()).abs() < 1e-12);
        let m = mae_multi(&pred, &truth).unwrap();
        assert!((m.per_column[0] - 0.5).abs() < 1e-12);
        assert!((m.per_column[1] - 1.0).abs() < 1e-12);
        assert!((m.pooled - 0.75).abs() < 1e-12);
        // shape mismatch rejected
        let bad = Mat::zeros(3, 2);
        assert!(rmse_multi(&pred, &bad).is_err());
    }

    #[test]
    fn timer_measures() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap();
        assert!(lap >= 0.002);
        assert!(t.elapsed() < lap);
    }
}
