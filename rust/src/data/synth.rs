//! Synthetic stand-ins for the paper's datasets (DESIGN.md §3).
//!
//! * [`ecg_like`] — MIT/BIH-ECG-shaped: N large, M = 21 morphology-style
//!   features, 2 classes.  Class-conditional structure: each class is a
//!   mixture of "beat templates" with AR(1)-correlated deviations, so the
//!   features are correlated like real beat descriptors and the classes are
//!   separable-but-not-trivially (paper reports 94.7-97.4% accuracy).
//! * [`drt_like`] — Dorothea-shaped: N small (800), M huge, sparse binary
//!   features, a small informative subset; the M ≫ N regime that forces
//!   empirical-space operation.
//!
//! Both return ±1 targets, matching the sign-threshold classification the
//! paper evaluates.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::prng::Rng;

/// ECG-like generator: `n` samples, `m` features (paper: 21), two classes.
pub fn ecg_like(n: usize, m: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xEC6);
    // two classes x three beat templates each, smooth morphology shapes
    let n_templates = 3;
    let mut templates: Vec<Vec<f64>> = Vec::with_capacity(2 * n_templates);
    for class in 0..2 {
        for t in 0..n_templates {
            let phase = rng.range(0.0, std::f64::consts::PI);
            let sharp = rng.range(1.0, 3.0);
            let tmpl: Vec<f64> = (0..m)
                .map(|k| {
                    let pos = k as f64 / m as f64;
                    // QRS-ish bump + class-dependent ST shift
                    let bump = (-sharp * (pos - 0.4 - 0.05 * t as f64).powi(2) * 40.0).exp();
                    let st = if class == 0 { 0.3 } else { -0.3 };
                    2.0 * bump + st * (pos * 6.0 + phase).sin()
                })
                .collect();
            templates.push(tmpl);
        }
    }
    let mut x = Mat::zeros(n, m);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let class = if rng.coin(0.5) { 1 } else { 0 };
        let t = rng.below(n_templates);
        let tmpl = &templates[class * n_templates + t];
        // AR(1)-correlated deviation, like neighbouring morphology samples
        let mut dev = 0.0;
        let row = x.row_mut(r);
        for k in 0..m {
            dev = 0.7 * dev + 0.3 * rng.gaussian();
            row[k] = tmpl[k] + 0.35 * dev + 0.1 * rng.gaussian();
        }
        y.push(if class == 0 { 1.0 } else { -1.0 });
    }
    Dataset { x, y, name: format!("ecg-like(n={n},m={m})") }
}

/// Dorothea-like generator: `n` samples (paper: 800), `m` sparse binary
/// features (paper: 10^6; scaled default 10^5), `density` fraction active,
/// with `n_informative` features carrying the class signal.
pub fn drt_like(n: usize, m: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xD27);
    let n_informative = (m / 100).clamp(8, 2000);
    let mut x = Mat::zeros(n, m);
    let mut y = Vec::with_capacity(n);
    // informative feature directions: which class turns them on more often
    let bias: Vec<bool> = (0..n_informative).map(|_| rng.coin(0.5)).collect();
    for r in 0..n {
        let class = rng.coin(0.5);
        y.push(if class { 1.0 } else { -1.0 });
        let row = x.row_mut(r);
        // background sparsity
        let n_active = ((m as f64) * density) as usize;
        for _ in 0..n_active {
            row[rng.below(m)] = 1.0;
        }
        // informative block: class-dependent activation probability
        for (f, &b) in bias.iter().enumerate() {
            let p_on = if class == b { 0.35 } else { 0.05 };
            if rng.coin(p_on) {
                row[f] = 1.0;
            } else {
                row[f] = 0.0;
            }
        }
    }
    Dataset { x, y, name: format!("drt-like(n={n},m={m})") }
}

/// Dorothea at TRUE paper scale: sparse CSR, N samples, M features
/// (default the paper's 10^6), ~`density` active.  Returns the sparse
/// features and ±1 targets — used by the full-scale empirical benches
/// where a dense store would need 6.4 GB.
pub fn drt_like_sparse(
    n: usize,
    m: usize,
    density: f64,
    seed: u64,
) -> (crate::linalg::SparseMat, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x5BA);
    let n_informative = (m / 100).clamp(8, 2000);
    let bias: Vec<bool> = (0..n_informative).map(|_| rng.coin(0.5)).collect();
    let mut y = Vec::with_capacity(n);
    let mut entries: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.coin(0.5);
        y.push(if class { 1.0 } else { -1.0 });
        let n_active = ((m as f64) * density) as usize;
        let mut row: Vec<(u32, f64)> = Vec::with_capacity(n_active);
        for _ in 0..n_active {
            let c = rng.below(m);
            if c >= n_informative {
                row.push((c as u32, 1.0));
            }
        }
        for (f, &b) in bias.iter().enumerate() {
            let p_on = if class == b { 0.35 } else { 0.05 };
            if rng.coin(p_on) {
                row.push((f as u32, 1.0));
            }
        }
        entries.push(row);
    }
    let x = crate::linalg::SparseMat::from_rows(n, m, entries).expect("valid entries");
    (x, y)
}

/// Paper-scale defaults for the ECG experiment (scaled; pass
/// `--full-scale` in the binaries to use 104 033 x 21).
pub fn ecg_default(seed: u64) -> Dataset {
    ecg_like(20_000, 21, seed)
}

/// Paper-scale defaults for the DRT experiment (scaled M; full is 10^6).
pub fn drt_default(seed: u64) -> Dataset {
    drt_like(800, 100_000, 0.009, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Space;
    use crate::kernels::Kernel;
    use crate::krr::{classification_accuracy, KrrModel};

    #[test]
    fn ecg_shapes_and_labels() {
        let d = ecg_like(500, 21, 1);
        assert_eq!(d.len(), 500);
        assert_eq!(d.dim(), 21);
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        // both classes present
        assert!(d.y.iter().any(|&v| v > 0.0) && d.y.iter().any(|&v| v < 0.0));
        assert!(d.x.is_finite());
    }

    #[test]
    fn ecg_is_learnable() {
        // KRR on the generator must reach paper-like accuracy (> 90%)
        let d = ecg_like(1200, 21, 2);
        let (tr, te) = d.split(0.8, 3);
        let model = crate::krr::intrinsic::IntrinsicKrr::fit(
            &tr.x,
            &tr.y,
            &Kernel::poly(2, 1.0),
            0.5,
        )
        .unwrap();
        let pred = model.predict(&te.x).unwrap();
        let acc = classification_accuracy(&pred, &te.y);
        assert!(acc > 0.9, "accuracy {acc}");
    }

    #[test]
    fn drt_shapes_and_sparsity() {
        let d = drt_like(100, 2_000, 0.01, 4);
        assert_eq!(d.len(), 100);
        assert_eq!(d.dim(), 2_000);
        let nnz: usize = d
            .x
            .as_slice()
            .iter()
            .filter(|&&v| v != 0.0)
            .count();
        let density = nnz as f64 / (100.0 * 2000.0);
        assert!(density < 0.1, "density {density}");
        assert!(density > 0.001, "density {density}");
    }

    #[test]
    fn drt_is_learnable_empirical() {
        let d = drt_like(240, 3_000, 0.01, 5);
        let (tr, te) = d.split(0.8, 6);
        let model = crate::krr::empirical::EmpiricalKrr::fit(
            &tr.x,
            &tr.y,
            &Kernel::poly(2, 1.0),
            0.5,
        )
        .unwrap();
        let pred = model.predict(&te.x).unwrap();
        let acc = classification_accuracy(&pred, &te.y);
        assert!(acc > 0.8, "accuracy {acc}");
        let _ = Space::Empirical;
    }

    #[test]
    fn generators_deterministic() {
        let a = ecg_like(50, 21, 9);
        let b = ecg_like(50, 21, 9);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
    }
}
