//! Datasets: synthetic generators standing in for the paper's ECG and
//! Dorothea benchmarks (see DESIGN.md §3 for the substitution rationale),
//! dataset containers, splits, and stream replay.

pub mod synth;

use crate::linalg::Mat;
use crate::util::prng::Rng;

/// An in-memory labelled dataset (rows = samples).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature rows (N, M).
    pub x: Mat,
    /// Targets (±1 for the 2-class benchmarks).
    pub y: Vec<f64>,
    /// Dataset name.
    pub name: String,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Select rows by index into a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            name: self.name.clone(),
        }
    }

    /// Convenience: rows of x by index (used in doc examples).
    pub fn x_rows(&self, idx: &[usize]) -> Mat {
        self.x.select_rows(idx)
    }

    /// Convenience: y values by index.
    pub fn y_rows(&self, idx: &[usize]) -> Vec<f64> {
        idx.iter().map(|&i| self.y[i]).collect()
    }

    /// Deterministic shuffled train/test split (train_frac in (0,1)).
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let (tr, te) = idx.split_at(n_train.min(n));
        (self.subset(tr), self.subset(te))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: Mat::from_fn(10, 3, |r, c| (r * 3 + c) as f64),
            y: (0..10).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
            name: "tiny".into(),
        }
    }

    #[test]
    fn split_partitions() {
        let d = tiny();
        let (tr, te) = d.split(0.8, 1);
        assert_eq!(tr.len(), 8);
        assert_eq!(te.len(), 2);
        assert_eq!(tr.dim(), 3);
    }

    #[test]
    fn subset_selects() {
        let d = tiny();
        let s = d.subset(&[9, 0]);
        assert_eq!(s.y, vec![-1.0, 1.0]);
        assert_eq!(s.x.row(0)[0], 27.0);
    }

    #[test]
    fn split_deterministic() {
        let d = tiny();
        let (a, _) = d.split(0.5, 7);
        let (b, _) = d.split(0.5, 7);
        assert_eq!(a.y, b.y);
    }
}
