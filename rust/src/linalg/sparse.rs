//! CSR sparse matrices — the substrate that makes the paper's full-scale
//! Dorothea regime (N=800, M=10^6, ~0.9% dense) feasible: the dense store
//! would be 6.4 GB, the sparse one ~60 MB, and Gram construction drops from
//! O(N^2 M) to O(N^2 * nnz/row).
//!
//! Only the operations the empirical-space engine needs are provided:
//! sparse row dot products, squared norms, and dense Gram blocks under the
//! poly/RBF/linear kernels (empirical space never needs the feature map).

use crate::ensure_shape;
use crate::error::Result;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::par;

/// Compressed sparse row matrix (f64 values).
#[derive(Clone, Debug)]
pub struct SparseMat {
    rows: usize,
    cols: usize,
    /// Row start offsets into `idx`/`val`, length rows+1.
    indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    idx: Vec<u32>,
    /// Values aligned with `idx`.
    val: Vec<f64>,
}

impl SparseMat {
    /// Build from per-row (col, value) lists; columns need not be sorted.
    pub fn from_rows(rows: usize, cols: usize, entries: Vec<Vec<(u32, f64)>>) -> Result<Self> {
        ensure_shape!(
            entries.len() == rows,
            "SparseMat::from_rows",
            "{} row lists for {} rows",
            entries.len(),
            rows
        );
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut idx = Vec::new();
        let mut val = Vec::new();
        indptr.push(0);
        for mut row in entries {
            row.sort_by_key(|e| e.0);
            row.dedup_by(|a, b| {
                if a.0 == b.0 {
                    b.1 += a.1;
                    true
                } else {
                    false
                }
            });
            for (c, v) in row {
                ensure_shape!(
                    (c as usize) < cols,
                    "SparseMat::from_rows",
                    "col {} >= {}",
                    c,
                    cols
                );
                if v != 0.0 {
                    idx.push(c);
                    val.push(v);
                }
            }
            indptr.push(idx.len());
        }
        Ok(Self { rows, cols, indptr, idx, val })
    }

    /// From a dense matrix (test helper).
    pub fn from_dense(m: &Mat) -> Self {
        let entries = (0..m.rows())
            .map(|r| {
                m.row(r)
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| (c as u32, v))
                    .collect()
            })
            .collect();
        Self::from_rows(m.rows(), m.cols(), entries).expect("valid dense source")
    }

    /// Rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// One row as (cols, vals).
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        (&self.idx[lo..hi], &self.val[lo..hi])
    }

    /// Sparse-sparse row dot product (merge join on sorted indices).
    pub fn row_dot(&self, r: usize, other: &SparseMat, q: usize) -> f64 {
        let (ia, va) = self.row(r);
        let (ib, vb) = other.row(q);
        let mut s = 0.0;
        let (mut a, mut b) = (0usize, 0usize);
        while a < ia.len() && b < ib.len() {
            match ia[a].cmp(&ib[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += va[a] * vb[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    /// Squared L2 norm of a row.
    pub fn row_norm2(&self, r: usize) -> f64 {
        let (_, v) = self.row(r);
        v.iter().map(|x| x * x).sum()
    }

    /// Densify (small matrices / tests).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (ix, vx) = self.row(r);
            let row = out.row_mut(r);
            for (c, v) in ix.iter().zip(vx) {
                row[*c as usize] = *v;
            }
        }
        out
    }

    /// Dense Gram block K[i,j] = k(self_i, other_j) under `kernel`.
    /// Cost O(rows * other.rows * nnz/row) — independent of M.
    pub fn gram(&self, other: &SparseMat, kernel: &Kernel) -> Result<Mat> {
        ensure_shape!(
            self.cols == other.cols,
            "SparseMat::gram",
            "cols {} != {}",
            self.cols,
            other.cols
        );
        let n = self.rows;
        let p = other.rows;
        let other_norms: Vec<f64> = (0..p).map(|q| other.row_norm2(q)).collect();
        let mut k = Mat::zeros(n, p);
        let kptr = SendPtr(k.as_mut_slice().as_mut_ptr());
        par::parallel_for(n, 8, |lo, hi| {
            let ptr = kptr;
            for i in lo..hi {
                let ni = self.row_norm2(i);
                // SAFETY: disjoint rows per chunk.
                let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * p), p) };
                for (j, out) in row.iter_mut().enumerate() {
                    let d = self.row_dot(i, other, j);
                    *out = match *kernel {
                        Kernel::Linear => d,
                        Kernel::Poly { degree, coef0 } => (d + coef0).powi(degree as i32),
                        Kernel::Rbf { gamma } => {
                            let d2 = (ni + other_norms[j] - 2.0 * d).max(0.0);
                            (-gamma * d2).exp()
                        }
                    };
                }
            }
        });
        Ok(k)
    }
}

struct SendPtr(*mut f64);
impl Clone for SendPtr {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl Copy for SendPtr {}
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_sparse(rows: usize, cols: usize, density: f64, seed: u64) -> SparseMat {
        let mut rng = Rng::new(seed);
        let mut entries = Vec::with_capacity(rows);
        for _ in 0..rows {
            let mut row = Vec::new();
            for c in 0..cols {
                if rng.coin(density) {
                    row.push((c as u32, rng.gaussian()));
                }
            }
            entries.push(row);
        }
        SparseMat::from_rows(rows, cols, entries).unwrap()
    }

    #[test]
    fn dense_roundtrip() {
        let s = random_sparse(13, 40, 0.2, 1);
        let d = s.to_dense();
        let s2 = SparseMat::from_dense(&d);
        assert_eq!(s2.to_dense().max_abs_diff(&d), 0.0);
        assert_eq!(s.nnz(), s2.nnz());
    }

    #[test]
    fn row_dot_matches_dense() {
        let a = random_sparse(8, 50, 0.3, 2);
        let b = random_sparse(6, 50, 0.3, 3);
        let da = a.to_dense();
        let db = b.to_dense();
        for i in 0..8 {
            for j in 0..6 {
                let want = crate::linalg::matrix::dot(da.row(i), db.row(j));
                assert!((a.row_dot(i, &b, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_matches_dense_kernels() {
        let a = random_sparse(12, 80, 0.15, 4);
        let b = random_sparse(9, 80, 0.15, 5);
        let da = a.to_dense();
        let db = b.to_dense();
        for kernel in [
            Kernel::Linear,
            Kernel::poly(2, 1.0),
            Kernel::poly(3, 1.0),
            Kernel::rbf_radius(5.0),
        ] {
            let ks = a.gram(&b, &kernel).unwrap();
            let kd = kernel.gram(&da, &db);
            assert!(
                ks.max_abs_diff(&kd) < 1e-10,
                "{kernel:?}: diff {}",
                ks.max_abs_diff(&kd)
            );
        }
    }

    #[test]
    fn duplicate_and_unsorted_entries_fold() {
        let s = SparseMat::from_rows(
            1,
            5,
            vec![vec![(3, 1.0), (1, 2.0), (3, 0.5)]],
        )
        .unwrap();
        let d = s.to_dense();
        assert_eq!(d.row(0), &[0.0, 2.0, 0.0, 1.5, 0.0]);
    }

    #[test]
    fn full_scale_drt_gram_is_tractable() {
        // N=64 slice of the paper's M=1e6 regime: dense would be 512 MB,
        // sparse is tiny and the Gram takes milliseconds.
        let s = random_sparse(64, 1_000_000, 0.0005, 6);
        let k = s.gram(&s, &Kernel::poly(2, 1.0)).unwrap();
        assert_eq!(k.shape(), (64, 64));
        assert!(k.is_finite());
    }

    #[test]
    fn shape_errors() {
        let a = random_sparse(3, 10, 0.5, 7);
        let b = random_sparse(3, 11, 0.5, 8);
        assert!(a.gram(&b, &Kernel::Linear).is_err());
        assert!(SparseMat::from_rows(2, 4, vec![vec![(9, 1.0)], vec![]]).is_err());
    }
}
