//! Blocked, multi-threaded dense matrix products.
//!
//! The hot loop is a row-major micro-kernel over a packed B panel; rows of C
//! are distributed across threads via [`crate::par::parallel_for`].  This is
//! the native fallback for the AOT GEMM artifacts and the engine used by all
//! maintained-inverse updates (J up to 2024 in the paper's configs).

use crate::ensure_shape;
use crate::error::Result;
use crate::linalg::matrix::{dot, Mat};
use crate::par;

/// Cache-block sizes for the packed GEMM (tuned on this container; see
/// EXPERIMENTS.md §Perf).
const MC: usize = 64; // rows of A per panel
const KC: usize = 256; // depth per panel
const MIN_PAR_ROWS: usize = 16;

/// `C = A * B` (new allocation).
pub fn matmul(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B` written into a caller-provided matrix (reshaped as needed;
/// allocation-free once `c`'s capacity is warm).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows(),
        "gemm::matmul",
        "a is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    c.resize_scratch(a.rows(), b.cols());
    gemm_into(1.0, a, b, 0.0, c)
}

/// `C = A * B^T` (new allocation).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_nt_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B^T` written into a caller-provided matrix (reshaped as
/// needed; allocation-free once `c`'s capacity is warm).
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols(),
        "gemm::matmul_nt",
        "a is {:?}, b^T is {:?}",
        a.shape(),
        b.shape()
    );
    // B^T in row-major == rows of B are columns of B^T: inner product of
    // rows, which is the cache-friendly case — no packing needed.
    let m = a.rows();
    let n = b.rows();
    c.resize_scratch(m, n);
    let a_ref = &a;
    let b_ref = &b;
    let cols = n;
    let data = c.as_mut_slice();
    let dptr = SendSlice(data.as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        let p = dptr;
        for i in lo..hi {
            let ai = a_ref.row(i);
            for j in 0..n {
                // SAFETY: disjoint row ranges per chunk.
                unsafe { *p.0.add(i * cols + j) = dot(ai, b_ref.row(j)) };
            }
        }
    });
    Ok(())
}

/// `C[0..A.rows, 0..B.rows] += alpha * A B^T` — accumulate into the leading
/// block of a (possibly larger) `C`. This is the in-place bordered-grow's
/// top-left rank-|C| correction: the maintained inverse has already been
/// restrided to its grown shape and the update lands directly in it.
pub fn gemm_nt_acc_block(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols() && c.rows() >= a.rows() && c.cols() >= b.rows(),
        "gemm::gemm_nt_acc_block",
        "a {:?}, b^T {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let n = b.rows();
    let c_cols = c.cols();
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(a.rows(), MIN_PAR_ROWS, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            let ai = a.row(i);
            // SAFETY: disjoint C rows per chunk.
            let crow = unsafe { std::slice::from_raw_parts_mut(p.0.add(i * c_cols), n) };
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += alpha * dot(ai, b.row(j));
            }
        }
    });
    Ok(())
}

/// `C += alpha * A^T B` with A: (k, m), B: (k, n), C: (m, n). Serial —
/// used for the small Schur blocks of the bordered updates.
pub fn gemm_tn_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.rows() == b.rows() && c.rows() == a.cols() && c.cols() == b.cols(),
        "gemm::gemm_tn_acc",
        "a^T {:?}, b {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    for k in 0..a.rows() {
        for i in 0..a.cols() {
            let f = alpha * a[(k, i)];
            if f != 0.0 {
                let base = k * b.cols();
                let brow = &b.as_slice()[base..base + b.cols()];
                for (cv, bv) in c.row_mut(i).iter_mut().zip(brow) {
                    *cv += f * bv;
                }
            }
        }
    }
    Ok(())
}

/// `C = A^T * B` (new allocation), A: (k, m), B: (k, n) -> C: (m, n).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Result<Mat> {
    ensure_shape!(
        a.rows() == b.rows(),
        "gemm::matmul_tn",
        "a^T is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    let at = a.transpose();
    matmul(&at, b)
}

/// General `C = alpha * A * B + beta * C`, blocked and parallel over C rows.
pub fn gemm_into(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols(),
        "gemm::gemm_into",
        "a {:?} * b {:?} -> c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let (m, k) = a.shape();
    let n = b.cols();
    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |row_lo, row_hi| {
        let p = cptr;
        // panel over K for cache reuse of B rows
        for kb in (0..k).step_by(KC) {
            let k_hi = (kb + KC).min(k);
            for ib in (row_lo..row_hi).step_by(MC) {
                let i_hi = (ib + MC).min(row_hi);
                for i in ib..i_hi {
                    let arow = a.row(i);
                    // SAFETY: each thread owns disjoint C rows.
                    let crow =
                        unsafe { std::slice::from_raw_parts_mut(p.0.add(i * n), n) };
                    for kk in kb..k_hi {
                        let aik = alpha * arow[kk];
                        if aik != 0.0 {
                            let brow = b.row(kk);
                            // axpy: crow += aik * brow  (vectorizes)
                            for (cv, bv) in crow.iter_mut().zip(brow) {
                                *cv += aik * bv;
                            }
                        }
                    }
                }
            }
        }
    });
    Ok(())
}

/// Symmetric rank-N update: `C = A * A^T` (C symmetric, computed fully).
pub fn syrk(a: &Mat) -> Result<Mat> {
    let m = a.rows();
    let mut c = Mat::zeros(m, m);
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            let ai = a.row(i);
            for j in 0..=i {
                let v = dot(ai, a.row(j));
                // SAFETY: row i written only by its owner; (j,i) mirror may
                // belong to another thread's row j — handled after the loop.
                unsafe { *p.0.add(i * m + j) = v };
            }
        }
    });
    // mirror lower triangle to upper
    for i in 0..m {
        for j in 0..i {
            c[(j, i)] = c[(i, j)];
        }
    }
    Ok(c)
}

/// Matrix-vector product `y = A x`.
pub fn gemv(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    let mut y = Vec::new();
    gemv_into(a, x, &mut y)?;
    Ok(y)
}

/// `y = A x` written into a caller-provided buffer (resized; no allocation
/// once its capacity is warm).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut Vec<f64>) -> Result<()> {
    ensure_shape!(
        a.cols() == x.len(),
        "gemm::gemv",
        "a is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let m = a.rows();
    y.clear();
    y.resize(m, 0.0);
    let yptr = SendSlice(y.as_mut_ptr());
    par::parallel_for(m, 512, |lo, hi| {
        let p = yptr;
        for i in lo..hi {
            // SAFETY: disjoint index ranges per chunk.
            unsafe { *p.0.add(i) = dot(a.row(i), x) };
        }
    });
    Ok(())
}

/// `y = A^T x` with A: (n, m), x: (n,) -> y: (m,).
pub fn gemv_t(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    ensure_shape!(
        a.rows() == x.len(),
        "gemm::gemv_t",
        "a^T is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        if xi != 0.0 {
            for (yv, av) in y.iter_mut().zip(a.row(i)) {
                *yv += xi * av;
            }
        }
    }
    Ok(y)
}

/// Outer-product accumulate: `C += alpha * x y^T`.
pub fn ger(c: &mut Mat, alpha: f64, x: &[f64], y: &[f64]) -> Result<()> {
    ensure_shape!(
        c.rows() == x.len() && c.cols() == y.len(),
        "gemm::ger",
        "c is {:?}, x has {}, y has {}",
        c.shape(),
        x.len(),
        y.len()
    );
    for (i, &xi) in x.iter().enumerate() {
        let axi = alpha * xi;
        if axi != 0.0 {
            for (cv, yv) in c.row_mut(i).iter_mut().zip(y) {
                *cv += axi * yv;
            }
        }
    }
    Ok(())
}

/// Raw-pointer Send wrapper (disjoint writes guaranteed by the callers).
#[derive(Clone, Copy)]
struct SendSlice(*mut f64);
unsafe impl Send for SendSlice {}
unsafe impl Sync for SendSlice {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (7, 3, 5), (65, 130, 33), (128, 64, 256)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-9, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_nt_matches() {
        let a = randm(33, 21, 3);
        let b = randm(47, 21, 4);
        let got = matmul_nt(&a, &b).unwrap();
        let want = naive(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matmul_tn_matches() {
        let a = randm(21, 33, 5);
        let b = randm(21, 13, 6);
        let got = matmul_tn(&a, &b).unwrap();
        let want = naive(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gemm_alpha_beta() {
        let a = randm(10, 8, 7);
        let b = randm(8, 6, 8);
        let mut c = randm(10, 6, 9);
        let c0 = c.clone();
        gemm_into(2.0, &a, &b, 0.5, &mut c).unwrap();
        let mut want = naive(&a, &b);
        want.scale(2.0);
        let mut c0s = c0;
        c0s.scale(0.5);
        want.axpy(1.0, &c0s).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn syrk_matches() {
        let a = randm(37, 12, 10);
        let got = syrk(&a).unwrap();
        let want = naive(&a, &a.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gemv_matches() {
        let a = randm(23, 17, 11);
        let mut rng = Rng::new(12);
        let x = rng.gaussian_vec(17);
        let y = gemv(&a, &x).unwrap();
        for i in 0..23 {
            let want = dot(a.row(i), &x);
            assert!((y[i] - want).abs() < 1e-10);
        }
        let xt = rng.gaussian_vec(23);
        let yt = gemv_t(&a, &xt).unwrap();
        let want = gemv(&a.transpose(), &xt).unwrap();
        for (g, w) in yt.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn ger_accumulates() {
        let mut c = Mat::zeros(3, 4);
        let x = [1.0, 2.0, 3.0];
        let y = [1.0, 0.0, -1.0, 2.0];
        ger(&mut c, 2.0, &x, &y).unwrap();
        assert_eq!(c[(2, 3)], 12.0);
        assert_eq!(c[(1, 2)], -4.0);
    }

    #[test]
    fn shape_errors() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(matmul(&a, &b).is_err());
        assert!(gemv(&a, &[0.0; 2]).is_err());
    }

    #[test]
    fn empty_dims() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 4);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), (0, 4));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let a = randm(12, 9, 20);
        let b = randm(9, 7, 21);
        let bt = randm(14, 9, 22);
        let mut c = Mat::default();
        matmul_into(&a, &b, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
        // reuse the same scratch for a different shape
        matmul_nt_into(&a, &bt, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &bt.transpose())) < 1e-9);
        let mut y = Vec::new();
        let mut rng = Rng::new(23);
        let x = rng.gaussian_vec(9);
        gemv_into(&a, &x, &mut y).unwrap();
        assert_eq!(y, gemv(&a, &x).unwrap());
    }

    #[test]
    fn nt_acc_block_updates_leading_block() {
        let a = randm(5, 3, 24);
        let b = randm(4, 3, 25);
        let mut c = Mat::from_fn(8, 8, |_, _| 1.0);
        gemm_nt_acc_block(2.0, &a, &b, &mut c).unwrap();
        let want = naive(&a, &b.transpose());
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i < 5 && j < 4 { 1.0 + 2.0 * want[(i, j)] } else { 1.0 };
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        assert!(gemm_nt_acc_block(1.0, &randm(9, 3, 1), &b, &mut c).is_err());
    }

    #[test]
    fn tn_acc_matches_naive() {
        let a = randm(6, 4, 26);
        let b = randm(6, 5, 27);
        let mut c = Mat::from_fn(4, 5, |_, _| 0.5);
        gemm_tn_acc(3.0, &a, &b, &mut c).unwrap();
        let mut want = naive(&a.transpose(), &b);
        want.scale(3.0);
        for i in 0..4 {
            for j in 0..5 {
                assert!((c[(i, j)] - 0.5 - want[(i, j)]).abs() < 1e-9);
            }
        }
    }
}
