//! Blocked, multi-threaded dense BLAS-3: one shape-adaptive packed engine
//! behind every product, triangular solve, and symmetric update.
//!
//! # The two engines
//!
//! * the **packed engine** ([`gemm_packed_raw`]) — operands are repacked
//!   into contiguous MR×kc / kc×NR micro-panels (zero-padded at the edges,
//!   transpose-aware: either side can be read as itself or its transpose)
//!   and multiplied by an explicitly unrolled 4×8 register-tile
//!   micro-kernel. The 32 accumulators fill exactly the 16-ymm AVX2
//!   register budget, and the portable `f64` array form lowers to two
//!   256-bit FMAs per row on any autovectorizing backend. Blocking is
//!   MC×KC×NC (A panel resident in L2, B panel packed once and shared
//!   across the row-parallel sweep, C streamed). The same driver serves
//!   NN, NT, TN products and — with `lower_only` — the SYRK macro-kernel
//!   and the factorizations' trailing updates, so a J=2024 Gram build or
//!   trailing panel no longer re-reads its operand from memory per tile;
//! * the **streaming fallbacks** — axpy row sweeps (NN/TN), row-dot loops
//!   (NT), and 4×4 dot tiles (SYRK) for the small/skinny products of the
//!   rank-|H| update algebra, where packing overhead would dominate and
//!   the operands are already cache-resident.
//!
//! Which engine runs is decided centrally by [`dispatch`] — the single
//! reference for every crossover threshold in this crate. The blocked,
//! parallel TRSM family ([`trsm_lower_into`], [`trsm_lower_t_into`],
//! [`trsm_right_into`]) solves triangular systems block by block and
//! routes its trailing rank-NB updates through the same dispatch, which is
//! what `solve.rs`'s blocked Cholesky/LU panel phases and the BLAS-3 SPD
//! inverse call instead of per-column scalar substitution.
//!
//! Packing buffers are thread-local and reused, so steady-state calls
//! perform no heap allocation on any path (measured before/after numbers
//! in EXPERIMENTS.md §Perf).
//!
//! This is the native fallback for the AOT GEMM artifacts and the engine
//! used by all maintained-inverse updates (J up to 2024 in the paper's
//! configs).

use crate::ensure_shape;
use crate::error::Result;
use crate::linalg::matrix::{dot, Mat};
use crate::par;
use std::cell::RefCell;

/// Micro-tile rows (A panel height).
const MR: usize = 4;
/// Micro-tile columns (B panel width); MR×NR accumulators = 16 ymm.
const NR: usize = 8;
/// Cache-block sizes for the packed engine (tuned on this container; see
/// EXPERIMENTS.md §Perf). MC is a multiple of MR, NC a multiple of NR.
const MC: usize = 64; // rows of A per packed panel
const KC: usize = 256; // depth per panel
const NC: usize = 256; // cols of B per packed panel
const MIN_PAR_ROWS: usize = 16;
/// Diagonal-block width for the blocked triangular solves: one
/// TRSM_NB×TRSM_NB block is solved in cache, then the remaining
/// right-hand-side rows take a rank-TRSM_NB GEMM update through
/// [`dispatch`].
const TRSM_NB: usize = 64;
/// Minimum RHS columns per parallel stripe in the TRSM diagonal solves.
const TRSM_MIN_COLS: usize = 64;

/// Kernel-selection thresholds — **the** crossover reference for every
/// dense BLAS-3 entry point in the crate.
///
/// A product `C (m×n) += A' (m×k) B' (k×n)` takes the packed micro-kernel
/// path iff [`use_packed`]`(m, n, k)`:
///
/// * `m·n·k ≥ 2^21` multiply-adds ([`PACKED_MIN_FLOPS`]): packing costs
///   O(mk + kn) extra writes plus panel bookkeeping, which only amortizes
///   over a deep k sweep — below ~2M flops the streaming kernels win on
///   measured wall clock (`core/gemm_nt_packed_vs_axpy` et al. in
///   `BENCH_microbench.json`);
/// * `k ≥ 32` ([`PACKED_MIN_K`]): shallower products never reuse a packed
///   element often enough to pay for its two copies (the rank-|H| update
///   algebra has k = |H| ≤ a few dozen — it stays on the axpy/dot path by
///   design);
/// * `m ≥ MR = 4` and `n ≥ NR = 8`: anything smaller cannot fill one
///   register tile.
///
/// Per-kernel shapes route as:
///
/// | kernel | (m, n, k) passed to [`use_packed`] |
/// |---|---|
/// | `gemm_into` (NN), `matmul_nt_into` (NT), `gemm_tn_acc` (TN) | product shape |
/// | `syrk_into` / `syrk_t_into` | (m, m, k) — the full square, half of which is computed |
/// | TRSM trailing update | (remaining rows, nrhs, TRSM_NB = 64) |
/// | Cholesky/LU trailing update (`solve.rs`) | (trailing rows, trailing cols, NB = 64) |
///
/// Consequences worth knowing: a J=2024 maintained-inverse round with
/// |H| = 6 keeps every product on the streaming path (k = 6), while the
/// same round's bootstrap factorization (k = 64 panels over a 2024² tile)
/// is entirely packed. The measured crossovers are tracked by the
/// `core/*` microbenches; re-tune the constants against
/// `BENCH_microbench.json` when the container hardware changes.
pub mod dispatch {
    /// Code-default minimum `m·n·k` multiply-add volume for the packed
    /// engine (overridable via the [`tune`] table).
    pub const PACKED_MIN_FLOPS: usize = 1 << 21;
    /// Code-default minimum product depth k for the packed engine
    /// (overridable via the [`tune`] table).
    pub const PACKED_MIN_K: usize = 32;

    /// Should a `(m×k)·(k×n)` product take the packed micro-kernel path?
    /// (See the module docs for the rationale behind each term.) The
    /// crossover constants come from the startup calibration table
    /// ([`tune::table`]); the register-tile minima `MR`/`NR` are
    /// structural and never tuned.
    #[inline]
    pub fn use_packed(m: usize, n: usize, k: usize) -> bool {
        let t = tune::table();
        k >= t.packed_min_k
            && m >= super::MR
            && n >= super::NR
            && m.saturating_mul(n).saturating_mul(k) >= t.packed_min_flops
    }

    pub mod tune {
        //! Startup calibration for the dispatch crossovers.
        //!
        //! The checked-in `rust/tuning.toml` carries the measured (or, until
        //! the first CI bench run, default) crossover constants, so the
        //! first `BENCH_microbench.json` produced by the CI `bench` job can
        //! re-tune [`use_packed`](super::use_packed) **without touching
        //! code**: edit the table, commit, done. The parser is hand-rolled
        //! (the offline crate set has no toml/serde) and accepts the subset
        //! the table uses — `[section]` headers, integer `key = value`
        //! pairs, `#` comments. Unknown keys are ignored (forward
        //! compatibility); unparsable or zero values keep their code
        //! default, so a mangled table can never turn a kernel off.
        //!
        //! Resolution order, frozen on first use (like `par::num_threads`):
        //! `MIKRR_TUNING=<path>` explicit override (`0`/`off`/`none` forces
        //! the code defaults), then `tuning.toml` in the working directory
        //! (bench/CI runs from `rust/`), then `rust/tuning.toml` (repo
        //! root), then the build-time manifest directory. When nothing is
        //! found the code defaults in [`Tuning::defaults`] apply — deleting
        //! the table is always safe.

        use std::sync::OnceLock;

        /// Code-default LU-panel pivot-search parallel threshold (column
        /// height).
        pub const LU_PIVOT_PAR_ROWS: usize = 512;
        /// Code-default LU-panel fused scale+rank-1 parallel threshold
        /// (column height).
        pub const LU_GER_PAR_ROWS: usize = 96;

        /// The calibration constants read by the dispatch decisions.
        #[derive(Clone, Debug, PartialEq, Eq)]
        pub struct Tuning {
            /// Minimum `m·n·k` volume for the packed engine.
            pub packed_min_flops: usize,
            /// Minimum product depth k for the packed engine.
            pub packed_min_k: usize,
            /// LU panel: pivot search reduces per-lane partial maxima
            /// above this column height.
            pub lu_pivot_par_rows: usize,
            /// LU panel: the fused scale+rank-1 update dispatches on the
            /// pool above this column height.
            pub lu_ger_par_rows: usize,
        }

        impl Tuning {
            /// The compiled-in defaults (used verbatim when no table is
            /// found).
            pub const fn defaults() -> Self {
                Self {
                    packed_min_flops: super::PACKED_MIN_FLOPS,
                    packed_min_k: super::PACKED_MIN_K,
                    lu_pivot_par_rows: LU_PIVOT_PAR_ROWS,
                    lu_ger_par_rows: LU_GER_PAR_ROWS,
                }
            }
        }

        /// Parse a tuning table. Exposed at crate level for the unit
        /// tests; production callers go through [`table`].
        pub(crate) fn parse(text: &str) -> Tuning {
            let mut t = Tuning::defaults();
            let mut section = "";
            for raw in text.lines() {
                let line = raw.split('#').next().unwrap_or("").trim();
                if line.is_empty() {
                    continue;
                }
                if let Some(s) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                    section = s.trim();
                    continue;
                }
                let Some((key, val)) = line.split_once('=') else {
                    continue;
                };
                let Ok(v) = val.trim().parse::<usize>() else {
                    continue;
                };
                if v == 0 {
                    // zero thresholds are never meaningful; keep the default
                    continue;
                }
                match (section, key.trim()) {
                    ("dispatch", "packed_min_flops") => t.packed_min_flops = v,
                    ("dispatch", "packed_min_k") => t.packed_min_k = v,
                    ("lu_panel", "pivot_par_rows") => t.lu_pivot_par_rows = v,
                    ("lu_panel", "ger_par_rows") => t.lu_ger_par_rows = v,
                    _ => {}
                }
            }
            t
        }

        fn load() -> (Tuning, String) {
            if let Ok(p) = std::env::var("MIKRR_TUNING") {
                if matches!(p.as_str(), "0" | "off" | "none") {
                    return (Tuning::defaults(), "defaults (MIKRR_TUNING=off)".into());
                }
                return match std::fs::read_to_string(&p) {
                    Ok(text) => (parse(&text), p),
                    Err(_) => (Tuning::defaults(), format!("defaults ({p} unreadable)")),
                };
            }
            let candidates = [
                "tuning.toml",
                "rust/tuning.toml",
                concat!(env!("CARGO_MANIFEST_DIR"), "/tuning.toml"),
            ];
            for p in candidates {
                if let Ok(text) = std::fs::read_to_string(p) {
                    return (parse(&text), p.to_string());
                }
            }
            (Tuning::defaults(), "defaults (no tuning.toml)".into())
        }

        fn entry() -> &'static (Tuning, String) {
            static TABLE: OnceLock<(Tuning, String)> = OnceLock::new();
            TABLE.get_or_init(load)
        }

        /// The process-wide table, read once on the first dispatch
        /// decision and frozen thereafter.
        pub fn table() -> &'static Tuning {
            &entry().0
        }

        /// Where [`table`] came from — a path, or a `defaults (...)`
        /// marker. Recorded in the bench reports' `env` block so
        /// trajectory entries are comparable.
        pub fn source() -> &'static str {
            &entry().1
        }
    }
}

thread_local! {
    /// Per-thread packed-A panel (MC×KC), reused across calls.
    static PACK_A: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    /// Per-thread packed-B panel (KC×NC), reused across calls.
    static PACK_B: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Read-only raw view of a row-major block: base pointer + leading
/// dimension. The packed engine and the TRSM family use it to address
/// disjoint blocks of a buffer that is concurrently written elsewhere
/// (callers guarantee the disjointness).
#[derive(Clone, Copy)]
pub(crate) struct RawMat {
    ptr: *const f64,
    ld: usize,
}
unsafe impl Send for RawMat {}
unsafe impl Sync for RawMat {}

impl RawMat {
    /// View of a whole matrix.
    pub(crate) fn of(m: &Mat) -> Self {
        Self { ptr: m.as_slice().as_ptr(), ld: m.cols() }
    }

    /// View rooted at `(r0, c0)` of a row-major buffer with leading
    /// dimension `ld`.
    ///
    /// # Safety
    /// `ptr` must point at a live buffer of at least `(r0+1)·ld` elements;
    /// every index later passed to the view must stay inside the buffer.
    pub(crate) unsafe fn from_raw(ptr: *const f64, ld: usize, r0: usize, c0: usize) -> Self {
        Self { ptr: ptr.add(r0 * ld + c0), ld }
    }

    #[inline(always)]
    unsafe fn at(self, r: usize, c: usize) -> f64 {
        *self.ptr.add(r * self.ld + c)
    }

    /// Row segment `[c0, c0+len)` of row `r`. The slice borrows `self` so
    /// it cannot (visibly) outlive the view — the caller still guarantees
    /// the underlying buffer outlives the view itself.
    #[inline(always)]
    unsafe fn row(&self, r: usize, c0: usize, len: usize) -> &[f64] {
        std::slice::from_raw_parts(self.ptr.add(r * self.ld + c0), len)
    }
}

/// `C = A * B` (new allocation).
pub fn matmul(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B` written into a caller-provided matrix (reshaped as needed;
/// allocation-free once `c`'s capacity is warm).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows(),
        "gemm::matmul",
        "a is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    c.resize_scratch(a.rows(), b.cols());
    gemm_into(1.0, a, b, 0.0, c)
}

/// `C = A * B^T` (new allocation).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_nt_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B^T` written into a caller-provided matrix (reshaped as
/// needed; allocation-free once `c`'s capacity is warm). Above the
/// [`dispatch`] crossover B is packed transpose-aware and the product runs
/// on the 4×8 micro-kernel; below it the row-dot kernel
/// ([`matmul_nt_dots_into`]) streams rows of both operands.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols(),
        "gemm::matmul_nt",
        "a is {:?}, b^T is {:?}",
        a.shape(),
        b.shape()
    );
    let (m, k) = a.shape();
    let n = b.rows();
    if dispatch::use_packed(m, n, k) {
        c.resize_scratch(m, n);
        c.as_mut_slice().fill(0.0);
        let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
        // SAFETY: a and b are distinct (immutable) matrices; c rows are
        // written by exactly one chunk each.
        unsafe {
            gemm_packed_raw(
                1.0,
                RawMat::of(a),
                false,
                RawMat::of(b),
                true,
                m,
                n,
                k,
                cptr,
                n,
                false,
            );
        }
        return Ok(());
    }
    matmul_nt_dots_into(a, b, c)
}

/// The NT row-dot kernel: `C = A * B^T` as inner products of rows, which
/// is already the cache-friendly case — no packing. This is the
/// below-crossover fallback of [`matmul_nt_into`], public as the reference
/// side of the `core/gemm_nt_packed_vs_axpy` microbench.
pub fn matmul_nt_dots_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols(),
        "gemm::matmul_nt_dots",
        "a is {:?}, b^T is {:?}",
        a.shape(),
        b.shape()
    );
    let m = a.rows();
    let n = b.rows();
    c.resize_scratch(m, n);
    let a_ref = &a;
    let b_ref = &b;
    let cols = n;
    let data = c.as_mut_slice();
    let dptr = SendSlice(data.as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        let p = dptr;
        for i in lo..hi {
            let ai = a_ref.row(i);
            for j in 0..n {
                // SAFETY: disjoint row ranges per chunk.
                unsafe { *p.0.add(i * cols + j) = dot(ai, b_ref.row(j)) };
            }
        }
    });
    Ok(())
}

/// `C[0..A.rows, 0..B.rows] += alpha * A B^T` — accumulate into the leading
/// block of a (possibly larger) `C`. This is the in-place bordered-grow's
/// top-left rank-|C| correction: the maintained inverse has already been
/// restrided to its grown shape and the update lands directly in it.
/// Routes through [`dispatch`] like every other product (large grow blocks
/// take the packed engine; the typical small-|C| rounds stay on row dots).
pub fn gemm_nt_acc_block(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols() && c.rows() >= a.rows() && c.cols() >= b.rows(),
        "gemm::gemm_nt_acc_block",
        "a {:?}, b^T {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let (m, k) = a.shape();
    let n = b.rows();
    let c_cols = c.cols();
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    if dispatch::use_packed(m, n, k) {
        // SAFETY: operands distinct from c; disjoint C rows per chunk.
        unsafe {
            gemm_packed_raw(
                alpha,
                RawMat::of(a),
                false,
                RawMat::of(b),
                true,
                m,
                n,
                k,
                cptr,
                c_cols,
                false,
            );
        }
        return Ok(());
    }
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            let ai = a.row(i);
            // SAFETY: disjoint C rows per chunk.
            let crow = unsafe { std::slice::from_raw_parts_mut(p.0.add(i * c_cols), n) };
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += alpha * dot(ai, b.row(j));
            }
        }
    });
    Ok(())
}

/// `C += alpha * A^T B` with A: (k, m), B: (k, n), C: (m, n). Above the
/// [`dispatch`] crossover A is packed transpose-aware (contiguous copies —
/// Aᵀ's micro-panel rows are A's stored rows) and the product runs on the
/// packed engine; the small Schur blocks of the bordered updates stay on
/// the serial axpy sweep.
pub fn gemm_tn_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.rows() == b.rows() && c.rows() == a.cols() && c.cols() == b.cols(),
        "gemm::gemm_tn_acc",
        "a^T {:?}, b {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let (k, m) = a.shape();
    let n = b.cols();
    if dispatch::use_packed(m, n, k) {
        let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
        // SAFETY: operands distinct from c; disjoint C rows per chunk.
        unsafe {
            gemm_packed_raw(
                alpha,
                RawMat::of(a),
                true,
                RawMat::of(b),
                false,
                m,
                n,
                k,
                cptr,
                n,
                false,
            );
        }
        return Ok(());
    }
    for kk in 0..k {
        for i in 0..m {
            let f = alpha * a[(kk, i)];
            if f != 0.0 {
                let base = kk * b.cols();
                let brow = &b.as_slice()[base..base + b.cols()];
                for (cv, bv) in c.row_mut(i).iter_mut().zip(brow) {
                    *cv += f * bv;
                }
            }
        }
    }
    Ok(())
}

/// `C = A^T * B` (new allocation), A: (k, m), B: (k, n) -> C: (m, n).
/// Above the [`dispatch`] crossover the transpose-aware packed engine runs
/// directly off A's storage; below it, the (allocating) explicit transpose
/// keeps the product on the row-parallel axpy engine — `gemm_tn_acc`'s
/// serial sweep is sized for the tiny Schur cores, not for a wide shallow
/// product.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Result<Mat> {
    ensure_shape!(
        a.rows() == b.rows(),
        "gemm::matmul_tn",
        "a^T is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    let (k, m) = a.shape();
    if dispatch::use_packed(m, b.cols(), k) {
        let mut c = Mat::zeros(m, b.cols());
        gemm_tn_acc(1.0, a, b, &mut c)?;
        return Ok(c);
    }
    let at = a.transpose();
    matmul(&at, b)
}

/// General `C = alpha * A * B + beta * C`, blocked and parallel over C rows.
/// Products over the [`dispatch`] crossover take the packed 4×8
/// micro-kernel path; small/skinny ones (the update algebra) the streaming
/// axpy path.
pub fn gemm_into(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols(),
        "gemm::gemm_into",
        "a {:?} * b {:?} -> c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let (m, k) = a.shape();
    let n = b.cols();
    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return Ok(());
    }
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    if dispatch::use_packed(m, n, k) {
        // SAFETY: a and b are distinct (immutable) matrices; c rows are
        // written by exactly one chunk each.
        unsafe {
            gemm_packed_raw(
                alpha,
                RawMat::of(a),
                false,
                RawMat::of(b),
                false,
                m,
                n,
                k,
                cptr,
                n,
                false,
            );
        }
    } else {
        par::parallel_for(m, MIN_PAR_ROWS, |row_lo, row_hi| {
            gemm_axpy_rows(alpha, a, b, cptr, n, row_lo, row_hi);
        });
    }
    Ok(())
}

/// Streaming axpy kernel: `C[rows] += alpha * A[rows] * B`, KC/MC panel
/// loop over B rows. Wins for small k where packing cannot amortize.
fn gemm_axpy_rows(
    alpha: f64,
    a: &Mat,
    b: &Mat,
    cptr: SendSlice,
    n: usize,
    row_lo: usize,
    row_hi: usize,
) {
    let k = a.cols();
    for kb in (0..k).step_by(KC) {
        let k_hi = (kb + KC).min(k);
        for ib in (row_lo..row_hi).step_by(MC) {
            let i_hi = (ib + MC).min(row_hi);
            for i in ib..i_hi {
                let arow = a.row(i);
                // SAFETY: each thread owns disjoint C rows.
                let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
                for kk in kb..k_hi {
                    let aik = alpha * arow[kk];
                    if aik != 0.0 {
                        let brow = b.row(kk);
                        // axpy: crow += aik * brow  (vectorizes)
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// The packed engine: `C[i, j] += alpha * Σ_kk A'[i, kk] * B'[kk, j]` with
/// `A' = A` (or `Aᵀ` when `ta`) and `B' = B` (or `Bᵀ` when `tb`), all
/// indices local to the views' roots. The caller packs each KC×NC B panel
/// **once** into its thread-local buffer and shares it (read-only) across
/// a row-parallel sweep — one dispatch per panel is cheap on the
/// persistent pool, and it avoids multiplying the packing bandwidth by the
/// lane count. Each lane packs only its own MC×KC A blocks.
///
/// With `lower_only`, only elements with local `i >= j` are written — the
/// SYRK macro path and the factorizations' trailing updates, whose C block
/// is rooted on the diagonal so the local condition is exactly the global
/// triangle.
///
/// # Safety
/// * `a` must cover `(m, k)` (or `(k, m)` when `ta`) and `b` `(k, n)` (or
///   `(n, k)` when `tb`) readable elements;
/// * `c` must cover `m` rows of stride `ldc >= n` writable elements, and
///   no other thread may read or write them for the duration of the call;
/// * the regions read through `a`/`b` must be disjoint from the region
///   written through `c` (they may share one allocation).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_packed_raw(
    alpha: f64,
    a: RawMat,
    ta: bool,
    b: RawMat,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    c: SendSlice,
    ldc: usize,
    lower_only: bool,
) {
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    PACK_B.with(|pb| {
        let mut bpack = pb.borrow_mut();
        if bpack.len() < NC * KC {
            bpack.resize(NC * KC, 0.0);
        }
        let mut kb = 0;
        while kb < k {
            let kc = KC.min(k - kb);
            let mut nb = 0;
            while nb < n {
                if lower_only && nb >= m {
                    // every remaining panel sits strictly above the diagonal
                    break;
                }
                let nc = NC.min(n - nb);
                // SAFETY: forwarded from the caller's contract.
                unsafe { pack_b_panel(b, tb, kb, kc, nb, nc, &mut bpack) };
                let bshared: &[f64] = &bpack;
                let row_start = if lower_only { nb } else { 0 };
                par::parallel_for(m - row_start, MIN_PAR_ROWS, |lo, hi| {
                    PACK_A.with(|pa| {
                        let mut apack = pa.borrow_mut();
                        if apack.len() < MC * KC {
                            apack.resize(MC * KC, 0.0);
                        }
                        let mut ib = row_start + lo;
                        let row_hi = row_start + hi;
                        while ib < row_hi {
                            let mc = MC.min(row_hi - ib);
                            // SAFETY: forwarded from the caller's contract;
                            // rows [ib, ib+mc) belong to this chunk alone.
                            unsafe {
                                pack_a_panel(a, ta, ib, mc, kb, kc, &mut apack);
                                macro_kernel(
                                    alpha, &apack, bshared, mc, nc, kc, c, ldc, ib, nb,
                                    lower_only,
                                );
                            }
                            ib += MC;
                        }
                    });
                });
                nb += NC;
            }
            kb += KC;
        }
    });
}

/// Pack logical `A'[ib..ib+mc, kb..kb+kc]` into MR-row micro-panels,
/// k-major within a panel (`panel[kk*MR + r]`), zero-padding partial row
/// panels so the micro-kernel never branches on height. With `trans`, the
/// logical element `(i, kk)` is `src[kk, i]`, which makes each panel fill
/// a contiguous copy of `src`'s stored rows.
///
/// # Safety
/// Every addressed `src` element must be in bounds and readable.
unsafe fn pack_a_panel(
    src: RawMat,
    trans: bool,
    ib: usize,
    mc: usize,
    kb: usize,
    kc: usize,
    apack: &mut [f64],
) {
    let mut p = 0;
    while p < mc {
        let pr = MR.min(mc - p);
        let panel = &mut apack[(p / MR) * MR * kc..][..MR * kc];
        if pr < MR {
            panel.fill(0.0);
        }
        if trans {
            for kk in 0..kc {
                let srow = src.row(kb + kk, ib + p, pr);
                panel[kk * MR..kk * MR + pr].copy_from_slice(srow);
            }
        } else {
            for r in 0..pr {
                let arow = src.row(ib + p + r, kb, kc);
                for (kk, &v) in arow.iter().enumerate() {
                    panel[kk * MR + r] = v;
                }
            }
        }
        p += MR;
    }
}

/// Pack logical `B'[kb..kb+kc, nb..nb+nc]` into NR-column micro-panels,
/// k-major within a panel (`panel[kk*NR + j]`), zero-padding partial
/// column panels. With `trans`, the logical element `(kk, j)` is
/// `src[j, kk]` — the NT case, where B's stored rows are the columns of
/// `Bᵀ`.
///
/// # Safety
/// Every addressed `src` element must be in bounds and readable.
unsafe fn pack_b_panel(
    src: RawMat,
    trans: bool,
    kb: usize,
    kc: usize,
    nb: usize,
    nc: usize,
    bpack: &mut [f64],
) {
    let mut q = 0;
    while q < nc {
        let pn = NR.min(nc - q);
        let panel = &mut bpack[(q / NR) * NR * kc..][..NR * kc];
        if pn < NR {
            panel.fill(0.0);
        }
        if trans {
            for j in 0..pn {
                let srow = src.row(nb + q + j, kb, kc);
                for (kk, &v) in srow.iter().enumerate() {
                    panel[kk * NR + j] = v;
                }
            }
        } else {
            for kk in 0..kc {
                let brow = src.row(kb + kk, nb + q, pn);
                panel[kk * NR..kk * NR + pn].copy_from_slice(brow);
            }
        }
        q += NR;
    }
}

/// The register-tile micro-kernel: a full MR×NR rank-kc product from packed
/// panels. 32 f64 accumulators (exactly the AVX2 ymm budget); the j loop
/// lowers to two 256-bit FMAs per row.
#[inline(always)]
fn micro_kernel_4x8(apanel: &[f64], bpanel: &[f64], kc: usize) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for (a4, b8) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)).take(kc) {
        for r in 0..MR {
            let ar = a4[r];
            for j in 0..NR {
                acc[r][j] += ar * b8[j];
            }
        }
    }
    acc
}

/// Sweep the packed panels with the micro-kernel and accumulate
/// `alpha * acc` into C (partial edge tiles write only their live cells;
/// with `lower_only`, each row additionally clips to local columns
/// `j <= i`).
///
/// # Safety
/// Forwarded from [`gemm_packed_raw`]: the addressed C rows belong to the
/// calling chunk alone.
#[allow(clippy::too_many_arguments)]
unsafe fn macro_kernel(
    alpha: f64,
    apack: &[f64],
    bpack: &[f64],
    mc: usize,
    nc: usize,
    kc: usize,
    cptr: SendSlice,
    ldc: usize,
    ib: usize,
    nb: usize,
    lower_only: bool,
) {
    let mut p = 0;
    while p < mc {
        let pr = MR.min(mc - p);
        let apanel = &apack[(p / MR) * MR * kc..][..MR * kc];
        let mut q = 0;
        while q < nc {
            if lower_only && nb + q > ib + p + pr - 1 {
                // the whole tile (and every later one in this row block)
                // sits strictly above the diagonal
                break;
            }
            let pn = NR.min(nc - q);
            let bpanel = &bpack[(q / NR) * NR * kc..][..NR * kc];
            let acc = micro_kernel_4x8(apanel, bpanel, kc);
            for (r, acc_row) in acc.iter().enumerate().take(pr) {
                let gi = ib + p + r;
                let gj0 = nb + q;
                let live = if lower_only {
                    if gj0 > gi {
                        continue;
                    }
                    pn.min(gi + 1 - gj0)
                } else {
                    pn
                };
                // SAFETY: row gi lies inside this thread's exclusive row
                // range.
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(cptr.0.add(gi * ldc + gj0), live) };
                for (cv, av) in crow.iter_mut().zip(&acc_row[..live]) {
                    *cv += alpha * av;
                }
            }
            q += NR;
        }
        p += MR;
    }
}

/// Mirror the strict lower triangle into the strict upper one (pass 2 of
/// the SYRK family: writes only `j > i`, reads only the completed `j < i`).
fn mirror_lower_to_upper(cptr: SendSlice, m: usize) {
    par::parallel_for(m, 256, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            for j in i + 1..m {
                // SAFETY: disjoint (i, j>i) writes; reads are from pass 1.
                unsafe { *p.0.add(i * m + j) = *p.0.add(j * m + i) };
            }
        }
    });
}

/// Symmetric rank-k update `C = alpha * A * A^T + beta * C` (C symmetric,
/// fully mirrored on return) at **half the flops** of the general product:
/// only the lower triangle is computed, then mirrored in a second parallel
/// pass. Above the [`dispatch`] crossover the triangle runs on the packed
/// macro-kernel (A is packed once per panel as both operands — the J=2024
/// Gram build stops re-reading A from memory per tile); below it, on 4×4
/// register-tiled row dots ([`syrk_tiled_into`]).
///
/// With `beta == 0` the output is reshaped (`resize_scratch`) so warm
/// buffers are reused allocation-free; with `beta != 0` the shape must
/// already match.
pub fn syrk_into(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    let (m, k) = a.shape();
    if !dispatch::use_packed(m, m, k) {
        return syrk_tiled_into(alpha, a, beta, c);
    }
    if beta == 0.0 {
        c.resize_scratch(m, m);
        c.as_mut_slice().fill(0.0);
    } else {
        ensure_shape!(
            c.rows() == m && c.cols() == m,
            "gemm::syrk_into",
            "a {:?} -> c {:?} with beta {beta}",
            a.shape(),
            c.shape()
        );
        if beta != 1.0 {
            c.scale(beta);
        }
    }
    if alpha == 0.0 {
        // C = beta*C already applied; mirror not needed (input symmetric)
        return Ok(());
    }
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    // SAFETY: a is a distinct (immutable) matrix; C rows are written by
    // exactly one chunk each; the C block is rooted on the diagonal.
    unsafe {
        gemm_packed_raw(
            alpha,
            RawMat::of(a),
            false,
            RawMat::of(a),
            true,
            m,
            m,
            k,
            cptr,
            m,
            true,
        );
    }
    mirror_lower_to_upper(cptr, m);
    Ok(())
}

/// [`syrk_into`] pinned to the 4×4 dot-tile kernel regardless of shape —
/// the below-crossover path, public as the reference side of the
/// `core/syrk_macro_1024` microbench and the property tests.
pub fn syrk_tiled_into(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    let m = a.rows();
    if beta == 0.0 {
        c.resize_scratch(m, m);
        c.as_mut_slice().fill(0.0);
    } else {
        ensure_shape!(
            c.rows() == m && c.cols() == m,
            "gemm::syrk_tiled_into",
            "a {:?} -> c {:?} with beta {beta}",
            a.shape(),
            c.shape()
        );
        if beta != 1.0 {
            c.scale(beta);
        }
    }
    if m == 0 || a.cols() == 0 || alpha == 0.0 {
        return Ok(());
    }
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        syrk_lower_rows(alpha, a, cptr, m, lo, hi);
    });
    mirror_lower_to_upper(cptr, m);
    Ok(())
}

/// Transpose-side symmetric rank-k update `C = alpha * A^T A + beta * C`
/// with A: (k, m) -> C: (m, m), fully mirrored. This is the Gram/scatter
/// build straight off a row-major sample store (`S = Φ^T Φ`): no
/// transposed copy of Φ is materialized — above the [`dispatch`] crossover
/// the packed engine reads A transpose-aware (its micro-panels are
/// contiguous copies of A's stored rows), below it a serial rank-1 row
/// sweep accumulates the lower triangle.
pub fn syrk_t_into(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    let (k, m) = a.shape();
    if beta == 0.0 {
        c.resize_scratch(m, m);
        c.as_mut_slice().fill(0.0);
    } else {
        ensure_shape!(
            c.rows() == m && c.cols() == m,
            "gemm::syrk_t_into",
            "a^T {:?} -> c {:?} with beta {beta}",
            a.shape(),
            c.shape()
        );
        if beta != 1.0 {
            c.scale(beta);
        }
    }
    if m == 0 || k == 0 || alpha == 0.0 {
        return Ok(());
    }
    if dispatch::use_packed(m, m, k) {
        let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
        // SAFETY: a is a distinct (immutable) matrix; C rows are written by
        // exactly one chunk each; the C block is rooted on the diagonal.
        unsafe {
            gemm_packed_raw(
                alpha,
                RawMat::of(a),
                true,
                RawMat::of(a),
                false,
                m,
                m,
                k,
                cptr,
                m,
                true,
            );
        }
        mirror_lower_to_upper(cptr, m);
        return Ok(());
    }
    // below the crossover (shallow k or a small product): axpy sweep over
    // the stored rows of A, lower triangle only, parallel over C rows —
    // the k-gate argues against packing, not against using the pool (a
    // wide-m, few-sample scatter build is still O(k·m²/2) work)
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        for i in lo..hi {
            // SAFETY: row i belongs to this chunk alone; `a` is read-only.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * m), i + 1) };
            for kk in 0..k {
                let row = a.row(kk);
                let f = alpha * row[i];
                if f != 0.0 {
                    for (cv, &v) in crow.iter_mut().zip(&row[..=i]) {
                        *cv += f * v;
                    }
                }
            }
        }
    });
    mirror_lower_to_upper(cptr, m);
    Ok(())
}

/// Lower-triangle accumulation for rows `[lo, hi)`: 4×4 blocks of row dots
/// sharing operand loads across the tile.
fn syrk_lower_rows(alpha: f64, a: &Mat, cptr: SendSlice, m: usize, lo: usize, hi: usize) {
    const BR: usize = 4;
    let mut i0 = lo;
    while i0 < hi {
        let ir = BR.min(hi - i0);
        let mut j0 = 0;
        while j0 < i0 + ir {
            let jr = BR.min(i0 + ir - j0);
            let acc = syrk_dot_block(a, i0, ir, j0, jr);
            for (r, acc_row) in acc.iter().enumerate().take(ir) {
                let i = i0 + r;
                for (s, acc_v) in acc_row.iter().enumerate().take(jr) {
                    let j = j0 + s;
                    if j <= i {
                        // SAFETY: row i belongs to this thread's range.
                        unsafe {
                            *cptr.0.add(i * m + j) += alpha * acc_v;
                        }
                    }
                }
            }
            j0 += BR;
        }
        i0 += BR;
    }
}

/// 4×4 block of row dot products `A[i0+r] · A[j0+s]` (edge blocks duplicate
/// the last live row; callers ignore the dead lanes).
#[inline(always)]
fn syrk_dot_block(a: &Mat, i0: usize, ir: usize, j0: usize, jr: usize) -> [[f64; 4]; 4] {
    let k = a.cols();
    let ai: [&[f64]; 4] = std::array::from_fn(|r| &a.row(i0 + r.min(ir - 1))[..k]);
    let aj: [&[f64]; 4] = std::array::from_fn(|s| &a.row(j0 + s.min(jr - 1))[..k]);
    let mut acc = [[0.0f64; 4]; 4];
    for kk in 0..k {
        let av: [f64; 4] = std::array::from_fn(|r| ai[r][kk]);
        let bv: [f64; 4] = std::array::from_fn(|s| aj[s][kk]);
        for r in 0..4 {
            for s in 0..4 {
                acc[r][s] += av[r] * bv[s];
            }
        }
    }
    acc
}

/// Symmetric rank-N update: `C = A * A^T` (new allocation, fully mirrored).
pub fn syrk(a: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    syrk_into(1.0, a, 0.0, &mut c)?;
    Ok(c)
}

/// Solve `L X = B` in place (the solution overwrites `b`) with `L`
/// lower-triangular; `unit` selects an implicit unit diagonal. Blocked and
/// parallel: each TRSM_NB diagonal block is solved with the RHS split over
/// parallel column stripes, then the remaining RHS rows take one
/// rank-TRSM_NB GEMM update that routes through [`dispatch`] — so a large
/// triangular solve spends almost all its flops in the packed micro-kernel
/// instead of per-column scalar substitution.
pub fn trsm_lower_into(l: &Mat, unit: bool, b: &mut Mat) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.rows(),
        "gemm::trsm_lower",
        "l {:?}, b {:?}",
        l.shape(),
        b.shape()
    );
    let n = l.rows();
    let nrhs = b.cols();
    if n == 0 || nrhs == 0 {
        return Ok(());
    }
    // SAFETY: l and b are distinct matrices; internal writes are disjoint.
    unsafe {
        trsm_lower_raw(
            RawMat::of(l),
            n,
            unit,
            SendSlice(b.as_mut_slice().as_mut_ptr()),
            nrhs,
            nrhs,
        );
    }
    Ok(())
}

/// Solve `L^T X = B` in place (backward counterpart of
/// [`trsm_lower_into`]; `L` is still stored lower-triangular).
pub fn trsm_lower_t_into(l: &Mat, unit: bool, b: &mut Mat) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.rows(),
        "gemm::trsm_lower_t",
        "l {:?}, b {:?}",
        l.shape(),
        b.shape()
    );
    let n = l.rows();
    let nrhs = b.cols();
    if n == 0 || nrhs == 0 {
        return Ok(());
    }
    // SAFETY: l and b are distinct matrices; internal writes are disjoint.
    unsafe {
        trsm_lower_t_raw(
            RawMat::of(l),
            n,
            unit,
            SendSlice(b.as_mut_slice().as_mut_ptr()),
            nrhs,
            nrhs,
        );
    }
    Ok(())
}

/// Solve `X L^T = B` in place on the rows of `b` (each row independently
/// solves `L x^T = b^T` by forward substitution) — the Cholesky panel
/// solve, parallel over rows.
pub fn trsm_right_into(b: &mut Mat, l: &Mat, unit: bool) -> Result<()> {
    ensure_shape!(
        l.is_square() && b.cols() == l.rows(),
        "gemm::trsm_right",
        "b {:?}, l {:?}",
        b.shape(),
        l.shape()
    );
    let n = l.rows();
    let rows = b.rows();
    if n == 0 || rows == 0 {
        return Ok(());
    }
    // SAFETY: l and b are distinct matrices; each row is written by exactly
    // one chunk.
    unsafe {
        trsm_right_raw(
            RawMat::of(l),
            n,
            unit,
            SendSlice(b.as_mut_slice().as_mut_ptr()),
            n,
            rows,
        );
    }
    Ok(())
}

/// Raw [`trsm_lower_into`]: `b` is `n` rows of `nrhs` live columns with
/// row stride `ldb`.
///
/// # Safety
/// `l` must cover an (n, n) readable block, `b` `n` writable rows of
/// stride `ldb >= nrhs`; the region read through `l` must be disjoint from
/// the region written through `b` (they may share one allocation), and no
/// other thread may touch either for the duration of the call.
pub(crate) unsafe fn trsm_lower_raw(
    l: RawMat,
    n: usize,
    unit: bool,
    b: SendSlice,
    ldb: usize,
    nrhs: usize,
) {
    let mut kb = 0;
    while kb < n {
        let nbk = TRSM_NB.min(n - kb);
        // diagonal-block solve on rows [kb, kb+nbk), parallel over disjoint
        // RHS column stripes
        par::parallel_for(nrhs, TRSM_MIN_COLS, |c0, c1| {
            for i in kb..kb + nbk {
                // SAFETY: columns [c0, c1) of every row belong to this
                // chunk alone; row j below is already fully solved.
                let brow =
                    unsafe { std::slice::from_raw_parts_mut(b.0.add(i * ldb + c0), c1 - c0) };
                for j in kb..i {
                    let f = unsafe { l.at(i, j) };
                    if f != 0.0 {
                        let bj = unsafe {
                            std::slice::from_raw_parts(b.0.add(j * ldb + c0), c1 - c0)
                        };
                        for (x, &v) in brow.iter_mut().zip(bj) {
                            *x -= f * v;
                        }
                    }
                }
                if !unit {
                    let d = unsafe { l.at(i, i) };
                    for x in brow.iter_mut() {
                        *x /= d;
                    }
                }
            }
        });
        let pe = kb + nbk;
        if pe < n {
            // trailing update: B[pe.., :] -= L[pe.., kb..pe] * B[kb..pe, :]
            let m2 = n - pe;
            // SAFETY: the solved rows [kb, pe) are read-only from here on;
            // the written rows [pe, n) are disjoint from them and from l.
            let a2 = unsafe { RawMat::from_raw(l.ptr, l.ld, pe, kb) };
            let b2 = unsafe { RawMat::from_raw(b.0 as *const f64, ldb, kb, 0) };
            let c2 = SendSlice(unsafe { b.0.add(pe * ldb) });
            if dispatch::use_packed(m2, nrhs, nbk) {
                unsafe {
                    gemm_packed_raw(-1.0, a2, false, b2, false, m2, nrhs, nbk, c2, ldb, false);
                }
            } else {
                unsafe { trsm_trailing_axpy(a2, false, b2, m2, nbk, c2, ldb, nrhs) };
            }
        }
        kb = pe;
    }
}

/// Trailing-update fallback shared by the blocked TRSMs: `C -= A' * B` as
/// a parallel axpy row sweep, with `A' = A` or `Aᵀ` when `ta` — mirroring
/// the flag the packed arm passes to [`gemm_packed_raw`].
///
/// # Safety
/// Same disjointness contract as [`gemm_packed_raw`] (with `alpha = -1`).
#[allow(clippy::too_many_arguments)]
unsafe fn trsm_trailing_axpy(
    a: RawMat,
    ta: bool,
    b: RawMat,
    m: usize,
    k: usize,
    c: SendSlice,
    ldc: usize,
    nrhs: usize,
) {
    par::parallel_for(m, 8, |lo, hi| {
        for i in lo..hi {
            // SAFETY: row i belongs to this chunk alone; a and b are
            // read-only here.
            let crow = unsafe { std::slice::from_raw_parts_mut(c.0.add(i * ldc), nrhs) };
            for kk in 0..k {
                let f = unsafe { if ta { a.at(kk, i) } else { a.at(i, kk) } };
                if f != 0.0 {
                    let brow = unsafe { b.row(kk, 0, nrhs) };
                    for (cv, &v) in crow.iter_mut().zip(brow) {
                        *cv -= f * v;
                    }
                }
            }
        }
    });
}

/// Raw [`trsm_lower_t_into`] (solves `L^T X = B`), blocked bottom-up.
///
/// # Safety
/// Same contract as [`trsm_lower_raw`].
pub(crate) unsafe fn trsm_lower_t_raw(
    l: RawMat,
    n: usize,
    unit: bool,
    b: SendSlice,
    ldb: usize,
    nrhs: usize,
) {
    let mut ke = n;
    while ke > 0 {
        let kb = ke.saturating_sub(TRSM_NB);
        // diagonal-block backward solve on rows [kb, ke)
        par::parallel_for(nrhs, TRSM_MIN_COLS, |c0, c1| {
            for i in (kb..ke).rev() {
                // SAFETY: columns [c0, c1) of every row belong to this
                // chunk alone; row j below is already fully solved.
                let brow =
                    unsafe { std::slice::from_raw_parts_mut(b.0.add(i * ldb + c0), c1 - c0) };
                for j in i + 1..ke {
                    let f = unsafe { l.at(j, i) };
                    if f != 0.0 {
                        let bj = unsafe {
                            std::slice::from_raw_parts(b.0.add(j * ldb + c0), c1 - c0)
                        };
                        for (x, &v) in brow.iter_mut().zip(bj) {
                            *x -= f * v;
                        }
                    }
                }
                if !unit {
                    let d = unsafe { l.at(i, i) };
                    for x in brow.iter_mut() {
                        *x /= d;
                    }
                }
            }
        });
        if kb > 0 {
            // trailing update: B[0..kb, :] -= L[kb..ke, 0..kb]^T * X[kb..ke, :]
            let k2 = ke - kb;
            // SAFETY: the solved rows [kb, ke) are read-only from here on;
            // the written rows [0, kb) are disjoint from them and from l.
            let a2 = unsafe { RawMat::from_raw(l.ptr, l.ld, kb, 0) };
            let b2 = unsafe { RawMat::from_raw(b.0 as *const f64, ldb, kb, 0) };
            let c2 = SendSlice(b.0);
            if dispatch::use_packed(kb, nrhs, k2) {
                unsafe {
                    gemm_packed_raw(-1.0, a2, true, b2, false, kb, nrhs, k2, c2, ldb, false);
                }
            } else {
                unsafe { trsm_trailing_axpy(a2, true, b2, kb, k2, c2, ldb, nrhs) };
            }
        }
        ke = kb;
    }
}

/// Raw [`trsm_right_into`]: each of the `rows` rows of `b` (width `n`,
/// row stride `ldb`) independently solves `L x^T = b^T` by forward
/// substitution against the (n, n) lower-triangular `l`.
///
/// # Safety
/// Same contract as [`trsm_lower_raw`] (with `b` holding `rows` rows of
/// `n` live columns).
pub(crate) unsafe fn trsm_right_raw(
    l: RawMat,
    n: usize,
    unit: bool,
    b: SendSlice,
    ldb: usize,
    rows: usize,
) {
    par::parallel_for(rows, 8, |lo, hi| {
        for i in lo..hi {
            // SAFETY: row i belongs to this chunk alone; l is read-only.
            let xrow = unsafe { std::slice::from_raw_parts_mut(b.0.add(i * ldb), n) };
            for j in 0..n {
                let lrow = unsafe { l.row(j, 0, j) };
                let s = dot(&xrow[..j], lrow);
                let v = xrow[j] - s;
                xrow[j] = if unit { v } else { v / unsafe { l.at(j, j) } };
            }
        }
    });
}

/// Matrix-vector product `y = A x`.
pub fn gemv(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    let mut y = Vec::new();
    gemv_into(a, x, &mut y)?;
    Ok(y)
}

/// `y = A x` written into a caller-provided buffer (resized; no allocation
/// once its capacity is warm).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut Vec<f64>) -> Result<()> {
    ensure_shape!(
        a.cols() == x.len(),
        "gemm::gemv",
        "a is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let m = a.rows();
    y.clear();
    y.resize(m, 0.0);
    let yptr = SendSlice(y.as_mut_ptr());
    par::parallel_for(m, 512, |lo, hi| {
        let p = yptr;
        for i in lo..hi {
            // SAFETY: disjoint index ranges per chunk.
            unsafe { *p.0.add(i) = dot(a.row(i), x) };
        }
    });
    Ok(())
}

/// `y = A^T x` with A: (n, m), x: (n,) -> y: (m,).
pub fn gemv_t(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    ensure_shape!(
        a.rows() == x.len(),
        "gemm::gemv_t",
        "a^T is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        if xi != 0.0 {
            for (yv, av) in y.iter_mut().zip(a.row(i)) {
                *yv += xi * av;
            }
        }
    }
    Ok(y)
}

/// Outer-product accumulate: `C += alpha * x y^T`.
pub fn ger(c: &mut Mat, alpha: f64, x: &[f64], y: &[f64]) -> Result<()> {
    ensure_shape!(
        c.rows() == x.len() && c.cols() == y.len(),
        "gemm::ger",
        "c is {:?}, x has {}, y has {}",
        c.shape(),
        x.len(),
        y.len()
    );
    for (i, &xi) in x.iter().enumerate() {
        let axi = alpha * xi;
        if axi != 0.0 {
            for (cv, yv) in c.row_mut(i).iter_mut().zip(y) {
                *cv += axi * yv;
            }
        }
    }
    Ok(())
}

/// Fused LU-panel column step (a "scaled GER"): for every row `i` in
/// `[k+1, n)` of the row-major buffer `base` (leading dimension `ld`),
/// divide the multiplier through by the pivot and apply the rank-1 panel
/// update in one pass over the row:
///
/// ```text
/// f = base[i, k] / pivot;   base[i, k] = f;
/// base[i, k+1..pe] -= f * base[k, k+1..pe]
/// ```
///
/// This is the inner kernel of the packed parallel LU panel factorization
/// (`solve`'s panel phase): rows are processed in MR-high blocks so the
/// pivot-row segment is loaded once per block, and the update loop runs NR
/// wide — the same 4×8 register-tile shape as [`micro_kernel_4x8`], which
/// the autovectorizer lowers to two 256-bit FMAs per row. The multiplier
/// uses a **division** by the pivot (not a reciprocal multiply) and each
/// element sees exactly the ops of the scalar reference in the same order,
/// so the factored panel is bitwise identical to `lu_decompose_naive`'s —
/// downstream pivot decisions can never diverge between the paths.
/// Parallel over rows above `min_par_rows` (`usize::MAX` pins the serial
/// reference path; chunk boundaries cannot change the result — rows are
/// independent).
///
/// # Safety
/// `base` must cover `n` rows of stride `ld >= pe`; rows `[k+1, n)` of
/// columns `[k, pe)` are written (each row by exactly one chunk), row `k`
/// is read-only, and no other thread may touch any of them for the
/// duration of the call.
pub(crate) unsafe fn ger_panel(
    base: SendSlice,
    ld: usize,
    k: usize,
    pe: usize,
    n: usize,
    pivot: f64,
    min_par_rows: usize,
) {
    if k + 1 >= n {
        return;
    }
    let rows = n - (k + 1);
    let width = pe - (k + 1);
    par::parallel_for(rows, min_par_rows, |lo, hi| {
        // SAFETY: row k is read-only in this phase; rows [k+1+lo, k+1+hi)
        // belong to this chunk alone.
        let prow = unsafe { std::slice::from_raw_parts(base.0.add(k * ld + k + 1), width) };
        let mut i = k + 1 + lo;
        let end = k + 1 + hi;
        while i < end {
            let bh = MR.min(end - i);
            // multipliers for the MR-row block (division: bitwise parity
            // with the scalar reference)
            let mut f = [0.0f64; MR];
            for (r, fr) in f.iter_mut().enumerate().take(bh) {
                // SAFETY: column k of row i+r is owned by this chunk.
                unsafe {
                    let p = base.0.add((i + r) * ld + k);
                    *fr = *p / pivot;
                    *p = *fr;
                }
            }
            for (r, &fr) in f.iter().enumerate().take(bh) {
                if fr == 0.0 {
                    continue;
                }
                // SAFETY: the row segment is owned by this chunk and
                // disjoint from `prow` (row k < i + r).
                let crow = unsafe {
                    std::slice::from_raw_parts_mut(base.0.add((i + r) * ld + k + 1), width)
                };
                // NR-wide main loop + remainder, mirroring the micro-kernel
                let mut cchunks = crow.chunks_exact_mut(NR);
                let mut pchunks = prow.chunks_exact(NR);
                for (cv8, pv8) in (&mut cchunks).zip(&mut pchunks) {
                    for (cv, pv) in cv8.iter_mut().zip(pv8) {
                        *cv -= fr * pv;
                    }
                }
                for (cv, pv) in cchunks.into_remainder().iter_mut().zip(pchunks.remainder()) {
                    *cv -= fr * pv;
                }
            }
            i += bh;
        }
    });
}

/// Raw-pointer Send wrapper (disjoint writes guaranteed by the callers).
#[derive(Clone, Copy)]
pub(crate) struct SendSlice(pub(crate) *mut f64);
unsafe impl Send for SendSlice {}
unsafe impl Sync for SendSlice {}

#[cfg(test)]
#[allow(clippy::needless_range_loop)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (7, 3, 5), (65, 130, 33), (128, 64, 256)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-9, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_path_matches_naive() {
        // shapes over the packed-path thresholds, including non-multiples
        // of MR/NR/KC that exercise zero-padded edge tiles
        for &(m, k, n) in &[(192, 128, 96), (193, 130, 97), (68, 300, 105)] {
            assert!(
                dispatch::use_packed(m, n, k),
                "({m},{k},{n}) must exercise the packed engine"
            );
            let a = randm(m, k, 3);
            let b = randm(k, n, 4);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-8, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_alpha_beta_accumulate() {
        let (m, k, n) = (160, 140, 112);
        let a = randm(m, k, 5);
        let b = randm(k, n, 6);
        let mut c = randm(m, n, 7);
        let c0 = c.clone();
        gemm_into(-1.5, &a, &b, 2.0, &mut c).unwrap();
        let want = naive(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let expect = 2.0 * c0[(i, j)] - 1.5 * want[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_nt_matches() {
        let a = randm(33, 21, 3);
        let b = randm(47, 21, 4);
        let got = matmul_nt(&a, &b).unwrap();
        let want = naive(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matmul_nt_packed_matches_dots() {
        // over the crossover: the packed transpose-aware B path against the
        // row-dot kernel and the naive reference, edge tiles included
        for &(m, k, n) in &[(96, 192, 120), (131, 67, 250)] {
            assert!(dispatch::use_packed(m, n, k), "({m},{k},{n})");
            let a = randm(m, k, 8);
            let b = randm(n, k, 9);
            let got = matmul_nt(&a, &b).unwrap();
            let mut dots = Mat::default();
            matmul_nt_dots_into(&a, &b, &mut dots).unwrap();
            assert!(got.max_abs_diff(&dots) < 1e-9, "({m},{k},{n}) packed vs dots");
            let want = naive(&a, &b.transpose());
            assert!(got.max_abs_diff(&want) < 1e-8, "({m},{k},{n}) vs naive");
        }
    }

    #[test]
    fn matmul_tn_matches() {
        let a = randm(21, 33, 5);
        let b = randm(21, 13, 6);
        let got = matmul_tn(&a, &b).unwrap();
        let want = naive(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gemm_tn_acc_packed_matches_naive() {
        // over the crossover: the transpose-aware A packing path
        for &(k, m, n) in &[(150, 120, 130), (260, 70, 131)] {
            assert!(dispatch::use_packed(m, n, k), "({k},{m},{n})");
            let a = randm(k, m, 10);
            let b = randm(k, n, 11);
            let mut c = randm(m, n, 12);
            let c0 = c.clone();
            gemm_tn_acc(1.5, &a, &b, &mut c).unwrap();
            let want = naive(&a.transpose(), &b);
            for i in 0..m {
                for j in 0..n {
                    let expect = c0[(i, j)] + 1.5 * want[(i, j)];
                    assert!((c[(i, j)] - expect).abs() < 1e-8, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let a = randm(10, 8, 7);
        let b = randm(8, 6, 8);
        let mut c = randm(10, 6, 9);
        let c0 = c.clone();
        gemm_into(2.0, &a, &b, 0.5, &mut c).unwrap();
        let mut want = naive(&a, &b);
        want.scale(2.0);
        let mut c0s = c0;
        c0s.scale(0.5);
        want.axpy(1.0, &c0s).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn syrk_matches() {
        let a = randm(37, 12, 10);
        let got = syrk(&a).unwrap();
        let want = naive(&a, &a.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn syrk_into_alpha_beta_and_edges() {
        // sizes straddling the 4×4 tile boundaries
        for &(m, k) in &[(1, 1), (4, 4), (5, 3), (37, 12), (64, 21), (130, 7)] {
            let a = randm(m, k, 11);
            let mut c = Mat::default();
            syrk_into(1.0, &a, 0.0, &mut c).unwrap();
            let want = naive(&a, &a.transpose());
            assert!(c.max_abs_diff(&want) < 1e-9, "({m},{k})");
            // exact symmetry by construction (mirrored, not recomputed)
            for i in 0..m {
                for j in 0..i {
                    assert_eq!(c[(i, j)], c[(j, i)], "({m},{k}) at ({i},{j})");
                }
            }
        }
        // alpha/beta accumulate form
        let a = randm(23, 9, 12);
        let mut c = syrk(&randm(23, 5, 13)).unwrap();
        let c0 = c.clone();
        syrk_into(0.5, &a, 2.0, &mut c).unwrap();
        let want = naive(&a, &a.transpose());
        for i in 0..23 {
            for j in 0..23 {
                let expect = 2.0 * c0[(i, j)] + 0.5 * want[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        // beta != 0 with a mismatched shape must error
        let mut bad = Mat::zeros(5, 5);
        assert!(syrk_into(1.0, &a, 1.0, &mut bad).is_err());
    }

    #[test]
    fn syrk_macro_path_matches_tiled() {
        // over the crossover: the packed lower-only macro-kernel against
        // the 4×4 dot-tile path, across edge-tile shapes
        for &(m, k) in &[(160, 90), (201, 55), (97, 260)] {
            assert!(dispatch::use_packed(m, m, k), "({m},{k})");
            let a = randm(m, k, 14);
            let mut c = Mat::default();
            syrk_into(1.0, &a, 0.0, &mut c).unwrap();
            let mut want = Mat::default();
            syrk_tiled_into(1.0, &a, 0.0, &mut want).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-9, "({m},{k})");
            for i in 0..m {
                for j in 0..i {
                    assert_eq!(c[(i, j)], c[(j, i)], "({m},{k}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn syrk_t_matches_explicit_transpose() {
        // both sides of the dispatch: small (rank-1 sweep) and packed
        for &(k, m) in &[(9, 6), (40, 25), (180, 140)] {
            let a = randm(k, m, 15);
            let mut c = Mat::default();
            syrk_t_into(1.0, &a, 0.0, &mut c).unwrap();
            let want = naive(&a.transpose(), &a);
            assert!(c.max_abs_diff(&want) < 1e-8, "({k},{m})");
            for i in 0..m {
                for j in 0..i {
                    assert_eq!(c[(i, j)], c[(j, i)], "({k},{m}) at ({i},{j})");
                }
            }
        }
        // alpha/beta accumulate form
        let a = randm(12, 8, 16);
        let mut c = syrk(&randm(8, 5, 17)).unwrap();
        let c0 = c.clone();
        syrk_t_into(0.5, &a, 2.0, &mut c).unwrap();
        let want = naive(&a.transpose(), &a);
        for i in 0..8 {
            for j in 0..8 {
                let expect = 2.0 * c0[(i, j)] + 0.5 * want[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        let mut bad = Mat::zeros(3, 3);
        assert!(syrk_t_into(1.0, &a, 1.0, &mut bad).is_err());
    }

    #[test]
    fn trsm_lower_matches_substitution() {
        // sizes below, at, and over the TRSM block width, wide and narrow
        // RHS (narrow = trailing updates stay on the axpy fallback, wide at
        // n=256 = packed trailing)
        for &(n, nrhs, seed) in &[(5, 3, 20), (64, 40, 21), (130, 7, 22), (256, 256, 23)] {
            let spd = {
                let g = randm(n, n, seed);
                let mut s = syrk(&g).unwrap();
                s.scale(1.0 / n as f64);
                s.add_diag(1.0).unwrap();
                s
            };
            let l = crate::linalg::solve::cholesky(&spd).unwrap();
            let b0 = randm(n, nrhs, seed + 100);
            // forward: L X = B against per-column forward substitution
            let mut x = b0.clone();
            trsm_lower_into(&l, false, &mut x).unwrap();
            let mut want = Mat::zeros(n, nrhs);
            let mut col = vec![0.0; n];
            for j in 0..nrhs {
                for i in 0..n {
                    col[i] = b0[(i, j)];
                }
                crate::linalg::solve::forward_sub(&l, &mut col).unwrap();
                for i in 0..n {
                    want[(i, j)] = col[i];
                }
            }
            assert!(x.max_abs_diff(&want) < 1e-9, "forward n={n} nrhs={nrhs}");
            // backward: L^T X = B against per-column backward substitution
            let mut xt = b0.clone();
            trsm_lower_t_into(&l, false, &mut xt).unwrap();
            let mut want_t = Mat::zeros(n, nrhs);
            for j in 0..nrhs {
                for i in 0..n {
                    col[i] = b0[(i, j)];
                }
                crate::linalg::solve::backward_sub_t(&l, &mut col).unwrap();
                for i in 0..n {
                    want_t[(i, j)] = col[i];
                }
            }
            assert!(xt.max_abs_diff(&want_t) < 1e-9, "backward n={n} nrhs={nrhs}");
            // residual check: L X == B
            let rec = matmul(&l, &x).unwrap();
            assert!(rec.max_abs_diff(&b0) < 1e-8, "residual n={n} nrhs={nrhs}");
        }
    }

    #[test]
    fn trsm_right_solves_panel() {
        // X L^T = B row solves (the Cholesky panel shape)
        let n = 48;
        let rows = 70;
        let spd = {
            let g = randm(n, n, 30);
            let mut s = syrk(&g).unwrap();
            s.scale(1.0 / n as f64);
            s.add_diag(1.0).unwrap();
            s
        };
        let l = crate::linalg::solve::cholesky(&spd).unwrap();
        let b0 = randm(rows, n, 31);
        let mut x = b0.clone();
        trsm_right_into(&mut x, &l, false).unwrap();
        // X L^T == B
        let rec = matmul_nt(&x, &l).unwrap();
        assert!(rec.max_abs_diff(&b0) < 1e-8);
    }

    #[test]
    fn trsm_unit_diagonal() {
        // unit-lower solve (the LU panel case): diagonal never read
        let n = 90;
        let mut l = Mat::eye(n);
        let mut rng = Rng::new(32);
        for i in 0..n {
            l[(i, i)] = 1.0;
            for j in 0..i {
                l[(i, j)] = 0.3 * rng.gaussian();
            }
        }
        let b0 = randm(n, 33, 33);
        let mut x = b0.clone();
        trsm_lower_into(&l, true, &mut x).unwrap();
        let rec = matmul(&l, &x).unwrap();
        assert!(rec.max_abs_diff(&b0) < 1e-9);
        // poisoned diagonal must not matter for the unit solve
        let mut lp = l.clone();
        for i in 0..n {
            lp[(i, i)] = f64::NAN;
        }
        let mut xp = b0.clone();
        trsm_lower_into(&lp, true, &mut xp).unwrap();
        assert!(xp.max_abs_diff(&x) < 1e-12);
    }

    #[test]
    fn trsm_shape_errors() {
        let l = Mat::zeros(3, 3);
        let mut b = Mat::zeros(4, 2);
        assert!(trsm_lower_into(&l, false, &mut b).is_err());
        assert!(trsm_lower_t_into(&l, false, &mut b).is_err());
        let mut br = Mat::zeros(2, 4);
        assert!(trsm_right_into(&mut br, &l, false).is_err());
    }

    #[test]
    fn gemv_matches() {
        let a = randm(23, 17, 11);
        let mut rng = Rng::new(12);
        let x = rng.gaussian_vec(17);
        let y = gemv(&a, &x).unwrap();
        for i in 0..23 {
            let want = dot(a.row(i), &x);
            assert!((y[i] - want).abs() < 1e-10);
        }
        let xt = rng.gaussian_vec(23);
        let yt = gemv_t(&a, &xt).unwrap();
        let want = gemv(&a.transpose(), &xt).unwrap();
        for (g, w) in yt.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn ger_accumulates() {
        let mut c = Mat::zeros(3, 4);
        let x = [1.0, 2.0, 3.0];
        let y = [1.0, 0.0, -1.0, 2.0];
        ger(&mut c, 2.0, &x, &y).unwrap();
        assert_eq!(c[(2, 3)], 12.0);
        assert_eq!(c[(1, 2)], -4.0);
    }

    #[test]
    fn shape_errors() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(matmul(&a, &b).is_err());
        assert!(gemv(&a, &[0.0; 2]).is_err());
    }

    #[test]
    fn empty_dims() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 4);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), (0, 4));
        let e = syrk(&Mat::zeros(0, 3)).unwrap();
        assert_eq!(e.shape(), (0, 0));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let a = randm(12, 9, 20);
        let b = randm(9, 7, 21);
        let bt = randm(14, 9, 22);
        let mut c = Mat::default();
        matmul_into(&a, &b, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
        // reuse the same scratch for a different shape
        matmul_nt_into(&a, &bt, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &bt.transpose())) < 1e-9);
        let mut y = Vec::new();
        let mut rng = Rng::new(23);
        let x = rng.gaussian_vec(9);
        gemv_into(&a, &x, &mut y).unwrap();
        assert_eq!(y, gemv(&a, &x).unwrap());
    }

    #[test]
    fn nt_acc_block_updates_leading_block() {
        let a = randm(5, 3, 24);
        let b = randm(4, 3, 25);
        let mut c = Mat::from_fn(8, 8, |_, _| 1.0);
        gemm_nt_acc_block(2.0, &a, &b, &mut c).unwrap();
        let want = naive(&a, &b.transpose());
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i < 5 && j < 4 { 1.0 + 2.0 * want[(i, j)] } else { 1.0 };
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        assert!(gemm_nt_acc_block(1.0, &randm(9, 3, 1), &b, &mut c).is_err());
    }

    #[test]
    fn nt_acc_block_packed_leading_block() {
        // the packed arm with ldc > n: a large leading block inside a
        // larger C — the in-place bordered-grow shape
        let (m, k, n) = (140, 120, 96);
        assert!(dispatch::use_packed(m, n, k));
        let a = randm(m, k, 26);
        let b = randm(n, k, 27);
        let mut c = Mat::from_fn(150, 150, |_, _| 1.0);
        gemm_nt_acc_block(2.0, &a, &b, &mut c).unwrap();
        let want = naive(&a, &b.transpose());
        for i in 0..150 {
            for j in 0..150 {
                let expect =
                    if i < m && j < n { 1.0 + 2.0 * want[(i, j)] } else { 1.0 };
                assert!((c[(i, j)] - expect).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn tn_acc_matches_naive() {
        let a = randm(6, 4, 26);
        let b = randm(6, 5, 27);
        let mut c = Mat::from_fn(4, 5, |_, _| 0.5);
        gemm_tn_acc(3.0, &a, &b, &mut c).unwrap();
        let mut want = naive(&a.transpose(), &b);
        want.scale(3.0);
        for i in 0..4 {
            for j in 0..5 {
                assert!((c[(i, j)] - 0.5 - want[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn tuning_parse_overrides_and_defaults() {
        use dispatch::tune::{parse, Tuning};
        // full table: every key lands
        let t = parse(
            "# comment\n[dispatch]\npacked_min_flops = 4096 # inline\npacked_min_k=8\n\
             [lu_panel]\npivot_par_rows = 256\nger_par_rows = 32\n",
        );
        assert_eq!(t.packed_min_flops, 4096);
        assert_eq!(t.packed_min_k, 8);
        assert_eq!(t.lu_pivot_par_rows, 256);
        assert_eq!(t.lu_ger_par_rows, 32);
        // empty / comment-only text: pure defaults
        assert_eq!(parse(""), Tuning::defaults());
        assert_eq!(parse("# nothing here\n"), Tuning::defaults());
        // garbage values, zero thresholds, unknown keys and sections:
        // defaults survive untouched
        let g = parse(
            "[dispatch]\npacked_min_k = banana\npacked_min_flops = 0\nfuture_key = 7\n\
             [unknown_section]\npivot_par_rows = 3\nnot a kv line\n",
        );
        assert_eq!(g, Tuning::defaults());
        // keys outside any section are ignored, not misattributed
        let s = parse("packed_min_k = 2\n[dispatch]\npacked_min_k = 16\n");
        assert_eq!(s.packed_min_k, 16);
    }

    #[test]
    fn tuning_table_drives_use_packed() {
        // the live table must carry the crossover `use_packed` applies:
        // shapes exactly at the table's thresholds flip the decision
        let t = dispatch::tune::table();
        assert!(t.packed_min_k >= 1 && t.packed_min_flops >= 1);
        // deep enough and voluminous enough: packed
        let k = t.packed_min_k.max(32);
        let mn = (t.packed_min_flops / k).max(1);
        let side = (mn as f64).sqrt().ceil() as usize + MR + NR;
        assert!(dispatch::use_packed(side, side, k));
        // one below the k gate: never packed
        assert!(!dispatch::use_packed(side, side, t.packed_min_k - 1));
        // source is always a non-empty marker or path
        assert!(!dispatch::tune::source().is_empty());
    }

    #[test]
    fn ger_panel_matches_scalar_reference() {
        // the fused scale+rank-1 panel step must be bitwise identical to
        // the scalar loop, across widths straddling the NR unroll and
        // heights straddling the MR blocks
        for &(n, k, pe, seed) in &[
            (37, 3, 20, 40u64),
            (64, 0, 64, 41),
            (130, 7, 8, 42), // width 0: scaling only
            (41, 11, 41, 43),
        ] {
            let a0 = randm(n, pe.max(12), seed);
            let ld = a0.cols();
            let pivot = a0[(k, k)];
            // scalar reference
            let mut want = a0.clone();
            for i in k + 1..n {
                let f = want[(i, k)] / pivot;
                want[(i, k)] = f;
                if f != 0.0 {
                    for c in k + 1..pe {
                        let v = want[(k, c)];
                        want[(i, c)] -= f * v;
                    }
                }
            }
            // fused kernel, forced inline (serial) and dispatched paths
            for min_par in [usize::MAX, 1] {
                let mut got = a0.clone();
                // SAFETY: exclusive borrow of `got`; row k is never written.
                unsafe {
                    ger_panel(
                        SendSlice(got.as_mut_slice().as_mut_ptr()),
                        ld,
                        k,
                        pe,
                        n,
                        pivot,
                        min_par,
                    );
                }
                assert!(
                    got == want,
                    "(n={n}, k={k}, pe={pe}, min_par={min_par}) not bitwise identical"
                );
            }
        }
    }
}
