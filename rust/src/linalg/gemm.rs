//! Blocked, multi-threaded dense matrix products.
//!
//! Two engines share the row-parallel dispatch (rows of C are distributed
//! across the [`crate::par`] worker pool):
//!
//! * a **packed GEMM** for large products — A and B are repacked into
//!   contiguous MR×kc / kc×NR micro-panels (zero-padded at the edges) and
//!   multiplied by an explicitly unrolled 4×8 register-tile micro-kernel.
//!   The 32 accumulators fill exactly the 16-ymm AVX2 register budget, and
//!   the portable `f64` array form lowers to two 256-bit FMAs per row on
//!   any autovectorizing backend. Blocking is MC×KC×NC (A panel resident in
//!   L2, B panel shared across the row sweep, C streamed);
//! * an **axpy kernel** for small/skinny products (the rank-|H| update
//!   algebra: k ≤ a few dozen), where packing overhead would dominate and
//!   streaming B rows is already cache-resident.
//!
//! [`syrk_into`] computes symmetric rank-k products (`C = αAAᵀ + βC`) at
//! half the flops by filling only the lower triangle (4×4 register-tiled
//! row dots) and mirroring. Packing buffers are thread-local and reused, so
//! steady-state calls perform no heap allocation on any path (measured
//! before/after numbers in EXPERIMENTS.md §Perf).
//!
//! This is the native fallback for the AOT GEMM artifacts and the engine
//! used by all maintained-inverse updates (J up to 2024 in the paper's
//! configs).

use crate::ensure_shape;
use crate::error::Result;
use crate::linalg::matrix::{dot, Mat};
use crate::par;
use std::cell::RefCell;

/// Micro-tile rows (A panel height).
const MR: usize = 4;
/// Micro-tile columns (B panel width); MR×NR accumulators = 16 ymm.
const NR: usize = 8;
/// Cache-block sizes for the packed GEMM (tuned on this container; see
/// EXPERIMENTS.md §Perf). MC is a multiple of MR, NC a multiple of NR.
const MC: usize = 64; // rows of A per packed panel
const KC: usize = 256; // depth per panel
const NC: usize = 256; // cols of B per packed panel
const MIN_PAR_ROWS: usize = 16;
/// Below this flop volume (or depth) the axpy kernel wins: packing costs
/// O(mk + kn) writes that only amortize over a large k sweep.
const PACKED_MIN_FLOPS: usize = 1 << 21;
const PACKED_MIN_K: usize = 32;

thread_local! {
    /// Per-thread packed-A panel (MC×KC), reused across calls.
    static PACK_A: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    /// Per-thread packed-B panel (KC×NC), reused across calls.
    static PACK_B: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// `C = A * B` (new allocation).
pub fn matmul(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B` written into a caller-provided matrix (reshaped as needed;
/// allocation-free once `c`'s capacity is warm).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows(),
        "gemm::matmul",
        "a is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    c.resize_scratch(a.rows(), b.cols());
    gemm_into(1.0, a, b, 0.0, c)
}

/// `C = A * B^T` (new allocation).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    matmul_nt_into(a, b, &mut c)?;
    Ok(c)
}

/// `C = A * B^T` written into a caller-provided matrix (reshaped as
/// needed; allocation-free once `c`'s capacity is warm).
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols(),
        "gemm::matmul_nt",
        "a is {:?}, b^T is {:?}",
        a.shape(),
        b.shape()
    );
    // B^T in row-major == rows of B are columns of B^T: inner product of
    // rows, which is the cache-friendly case — no packing needed.
    let m = a.rows();
    let n = b.rows();
    c.resize_scratch(m, n);
    let a_ref = &a;
    let b_ref = &b;
    let cols = n;
    let data = c.as_mut_slice();
    let dptr = SendSlice(data.as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        let p = dptr;
        for i in lo..hi {
            let ai = a_ref.row(i);
            for j in 0..n {
                // SAFETY: disjoint row ranges per chunk.
                unsafe { *p.0.add(i * cols + j) = dot(ai, b_ref.row(j)) };
            }
        }
    });
    Ok(())
}

/// `C[0..A.rows, 0..B.rows] += alpha * A B^T` — accumulate into the leading
/// block of a (possibly larger) `C`. This is the in-place bordered-grow's
/// top-left rank-|C| correction: the maintained inverse has already been
/// restrided to its grown shape and the update lands directly in it.
pub fn gemm_nt_acc_block(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.cols() && c.rows() >= a.rows() && c.cols() >= b.rows(),
        "gemm::gemm_nt_acc_block",
        "a {:?}, b^T {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let n = b.rows();
    let c_cols = c.cols();
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(a.rows(), MIN_PAR_ROWS, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            let ai = a.row(i);
            // SAFETY: disjoint C rows per chunk.
            let crow = unsafe { std::slice::from_raw_parts_mut(p.0.add(i * c_cols), n) };
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += alpha * dot(ai, b.row(j));
            }
        }
    });
    Ok(())
}

/// `C += alpha * A^T B` with A: (k, m), B: (k, n), C: (m, n). Serial —
/// used for the small Schur blocks of the bordered updates.
pub fn gemm_tn_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.rows() == b.rows() && c.rows() == a.cols() && c.cols() == b.cols(),
        "gemm::gemm_tn_acc",
        "a^T {:?}, b {:?}, c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    for k in 0..a.rows() {
        for i in 0..a.cols() {
            let f = alpha * a[(k, i)];
            if f != 0.0 {
                let base = k * b.cols();
                let brow = &b.as_slice()[base..base + b.cols()];
                for (cv, bv) in c.row_mut(i).iter_mut().zip(brow) {
                    *cv += f * bv;
                }
            }
        }
    }
    Ok(())
}

/// `C = A^T * B` (new allocation), A: (k, m), B: (k, n) -> C: (m, n).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Result<Mat> {
    ensure_shape!(
        a.rows() == b.rows(),
        "gemm::matmul_tn",
        "a^T is {:?}, b is {:?}",
        a.shape(),
        b.shape()
    );
    let at = a.transpose();
    matmul(&at, b)
}

/// General `C = alpha * A * B + beta * C`, blocked and parallel over C rows.
/// Large products take the packed 4×8 micro-kernel path; small/skinny ones
/// (the update algebra) the streaming axpy path — see the module docs.
pub fn gemm_into(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols(),
        "gemm::gemm_into",
        "a {:?} * b {:?} -> c {:?}",
        a.shape(),
        b.shape(),
        c.shape()
    );
    let (m, k) = a.shape();
    let n = b.cols();
    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return Ok(());
    }
    let packed = k >= PACKED_MIN_K
        && m >= MR
        && n >= NR
        && m.saturating_mul(n).saturating_mul(k) >= PACKED_MIN_FLOPS;
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    if packed {
        gemm_packed(alpha, a, b, cptr, m, n);
    } else {
        par::parallel_for(m, MIN_PAR_ROWS, |row_lo, row_hi| {
            gemm_axpy_rows(alpha, a, b, cptr, n, row_lo, row_hi);
        });
    }
    Ok(())
}

/// Streaming axpy kernel: `C[rows] += alpha * A[rows] * B`, KC/MC panel
/// loop over B rows. Wins for small k where packing cannot amortize.
fn gemm_axpy_rows(alpha: f64, a: &Mat, b: &Mat, cptr: SendSlice, n: usize, row_lo: usize, row_hi: usize) {
    let k = a.cols();
    for kb in (0..k).step_by(KC) {
        let k_hi = (kb + KC).min(k);
        for ib in (row_lo..row_hi).step_by(MC) {
            let i_hi = (ib + MC).min(row_hi);
            for i in ib..i_hi {
                let arow = a.row(i);
                // SAFETY: each thread owns disjoint C rows.
                let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
                for kk in kb..k_hi {
                    let aik = alpha * arow[kk];
                    if aik != 0.0 {
                        let brow = b.row(kk);
                        // axpy: crow += aik * brow  (vectorizes)
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Packed engine: `C += alpha * A * B`. The caller packs each KC×NC B
/// panel **once** into its thread-local buffer and shares it (read-only)
/// across a row-parallel sweep — one dispatch per panel is cheap on the
/// persistent pool, and it avoids multiplying the packing bandwidth by the
/// lane count. Each lane packs only its own MC×KC A blocks.
fn gemm_packed(alpha: f64, a: &Mat, b: &Mat, cptr: SendSlice, m: usize, n: usize) {
    let k = a.cols();
    PACK_B.with(|pb| {
        let mut bpack = pb.borrow_mut();
        if bpack.len() < NC * KC {
            bpack.resize(NC * KC, 0.0);
        }
        for kb in (0..k).step_by(KC) {
            let kc = KC.min(k - kb);
            for nb in (0..n).step_by(NC) {
                let nc = NC.min(n - nb);
                pack_b(b, kb, kc, nb, nc, &mut bpack[..]);
                let bshared: &[f64] = &bpack;
                par::parallel_for(m, MIN_PAR_ROWS, |row_lo, row_hi| {
                    PACK_A.with(|pa| {
                        let mut apack = pa.borrow_mut();
                        if apack.len() < MC * KC {
                            apack.resize(MC * KC, 0.0);
                        }
                        let mut ib = row_lo;
                        while ib < row_hi {
                            let mc = MC.min(row_hi - ib);
                            pack_a(a, ib, mc, kb, kc, &mut apack[..]);
                            macro_kernel(
                                alpha, &apack[..], bshared, mc, nc, kc, cptr, n, ib, nb,
                            );
                            ib += MC;
                        }
                    });
                });
            }
        }
    });
}

/// Pack `A[ib..ib+mc, kb..kb+kc]` into MR-row micro-panels, k-major within
/// a panel (`panel[kk*MR + r]`), zero-padding partial row panels so the
/// micro-kernel never branches on height.
fn pack_a(a: &Mat, ib: usize, mc: usize, kb: usize, kc: usize, apack: &mut [f64]) {
    let mut p = 0;
    while p < mc {
        let pr = MR.min(mc - p);
        let panel = &mut apack[(p / MR) * MR * kc..][..MR * kc];
        if pr < MR {
            panel.fill(0.0);
        }
        for r in 0..pr {
            let arow = &a.row(ib + p + r)[kb..kb + kc];
            for (kk, &v) in arow.iter().enumerate() {
                panel[kk * MR + r] = v;
            }
        }
        p += MR;
    }
}

/// Pack `B[kb..kb+kc, nb..nb+nc]` into NR-column micro-panels, k-major
/// within a panel (`panel[kk*NR + j]`), zero-padding partial column panels.
fn pack_b(b: &Mat, kb: usize, kc: usize, nb: usize, nc: usize, bpack: &mut [f64]) {
    let mut q = 0;
    while q < nc {
        let pn = NR.min(nc - q);
        let panel = &mut bpack[(q / NR) * NR * kc..][..NR * kc];
        if pn < NR {
            panel.fill(0.0);
        }
        for kk in 0..kc {
            let brow = &b.row(kb + kk)[nb + q..nb + q + pn];
            panel[kk * NR..kk * NR + pn].copy_from_slice(brow);
        }
        q += NR;
    }
}

/// The register-tile micro-kernel: a full MR×NR rank-kc product from packed
/// panels. 32 f64 accumulators (exactly the AVX2 ymm budget); the j loop
/// lowers to two 256-bit FMAs per row.
#[inline(always)]
fn micro_kernel_4x8(apanel: &[f64], bpanel: &[f64], kc: usize) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for (a4, b8) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)).take(kc) {
        for r in 0..MR {
            let ar = a4[r];
            for j in 0..NR {
                acc[r][j] += ar * b8[j];
            }
        }
    }
    acc
}

/// Sweep the packed panels with the micro-kernel and accumulate
/// `alpha * acc` into C (partial edge tiles write only their live cells).
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    alpha: f64,
    apack: &[f64],
    bpack: &[f64],
    mc: usize,
    nc: usize,
    kc: usize,
    cptr: SendSlice,
    ldc: usize,
    ib: usize,
    nb: usize,
) {
    let mut p = 0;
    while p < mc {
        let pr = MR.min(mc - p);
        let apanel = &apack[(p / MR) * MR * kc..][..MR * kc];
        let mut q = 0;
        while q < nc {
            let pn = NR.min(nc - q);
            let bpanel = &bpack[(q / NR) * NR * kc..][..NR * kc];
            let acc = micro_kernel_4x8(apanel, bpanel, kc);
            for (r, acc_row) in acc.iter().enumerate().take(pr) {
                // SAFETY: row ib+p+r lies inside this thread's exclusive
                // row range.
                let crow = unsafe {
                    std::slice::from_raw_parts_mut(cptr.0.add((ib + p + r) * ldc + nb + q), pn)
                };
                for (cv, av) in crow.iter_mut().zip(&acc_row[..pn]) {
                    *cv += alpha * av;
                }
            }
            q += NR;
        }
        p += MR;
    }
}

/// Symmetric rank-k update `C = alpha * A * A^T + beta * C` (C symmetric,
/// fully mirrored on return) at **half the flops** of the general product:
/// only the lower triangle is computed, with a 4×4 register-tiled row-dot
/// kernel, then mirrored in a second parallel pass.
///
/// With `beta == 0` the output is reshaped (`resize_scratch`) so warm
/// buffers are reused allocation-free; with `beta != 0` the shape must
/// already match.
pub fn syrk_into(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) -> Result<()> {
    let m = a.rows();
    if beta == 0.0 {
        c.resize_scratch(m, m);
        c.as_mut_slice().fill(0.0);
    } else {
        ensure_shape!(
            c.rows() == m && c.cols() == m,
            "gemm::syrk_into",
            "a {:?} -> c {:?} with beta {beta}",
            a.shape(),
            c.shape()
        );
        if beta != 1.0 {
            c.scale(beta);
        }
    }
    if m == 0 || a.cols() == 0 || alpha == 0.0 {
        // C = beta*C already applied; mirror not needed (input symmetric or
        // freshly zeroed)
        return Ok(());
    }
    let cptr = SendSlice(c.as_mut_slice().as_mut_ptr());
    par::parallel_for(m, MIN_PAR_ROWS, |lo, hi| {
        syrk_lower_rows(alpha, a, cptr, m, lo, hi);
    });
    // mirror lower -> upper: pass 2 writes only the strict upper triangle
    // and reads only the strict lower, written in the completed pass 1
    par::parallel_for(m, 256, |lo, hi| {
        let p = cptr;
        for i in lo..hi {
            for j in i + 1..m {
                // SAFETY: disjoint (i, j>i) writes; reads are from pass 1.
                unsafe { *p.0.add(i * m + j) = *p.0.add(j * m + i) };
            }
        }
    });
    Ok(())
}

/// Lower-triangle accumulation for rows `[lo, hi)`: 4×4 blocks of row dots
/// sharing operand loads across the tile.
fn syrk_lower_rows(alpha: f64, a: &Mat, cptr: SendSlice, m: usize, lo: usize, hi: usize) {
    const BR: usize = 4;
    let mut i0 = lo;
    while i0 < hi {
        let ir = BR.min(hi - i0);
        let mut j0 = 0;
        while j0 < i0 + ir {
            let jr = BR.min(i0 + ir - j0);
            let acc = syrk_dot_block(a, i0, ir, j0, jr);
            for (r, acc_row) in acc.iter().enumerate().take(ir) {
                let i = i0 + r;
                for (s, acc_v) in acc_row.iter().enumerate().take(jr) {
                    let j = j0 + s;
                    if j <= i {
                        // SAFETY: row i belongs to this thread's range.
                        unsafe {
                            *cptr.0.add(i * m + j) += alpha * acc_v;
                        }
                    }
                }
            }
            j0 += BR;
        }
        i0 += BR;
    }
}

/// 4×4 block of row dot products `A[i0+r] · A[j0+s]` (edge blocks duplicate
/// the last live row; callers ignore the dead lanes).
#[inline(always)]
fn syrk_dot_block(a: &Mat, i0: usize, ir: usize, j0: usize, jr: usize) -> [[f64; 4]; 4] {
    let k = a.cols();
    let ai: [&[f64]; 4] = std::array::from_fn(|r| &a.row(i0 + r.min(ir - 1))[..k]);
    let aj: [&[f64]; 4] = std::array::from_fn(|s| &a.row(j0 + s.min(jr - 1))[..k]);
    let mut acc = [[0.0f64; 4]; 4];
    for kk in 0..k {
        let av: [f64; 4] = std::array::from_fn(|r| ai[r][kk]);
        let bv: [f64; 4] = std::array::from_fn(|s| aj[s][kk]);
        for r in 0..4 {
            for s in 0..4 {
                acc[r][s] += av[r] * bv[s];
            }
        }
    }
    acc
}

/// Symmetric rank-N update: `C = A * A^T` (new allocation, fully mirrored).
pub fn syrk(a: &Mat) -> Result<Mat> {
    let mut c = Mat::default();
    syrk_into(1.0, a, 0.0, &mut c)?;
    Ok(c)
}

/// Matrix-vector product `y = A x`.
pub fn gemv(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    let mut y = Vec::new();
    gemv_into(a, x, &mut y)?;
    Ok(y)
}

/// `y = A x` written into a caller-provided buffer (resized; no allocation
/// once its capacity is warm).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut Vec<f64>) -> Result<()> {
    ensure_shape!(
        a.cols() == x.len(),
        "gemm::gemv",
        "a is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let m = a.rows();
    y.clear();
    y.resize(m, 0.0);
    let yptr = SendSlice(y.as_mut_ptr());
    par::parallel_for(m, 512, |lo, hi| {
        let p = yptr;
        for i in lo..hi {
            // SAFETY: disjoint index ranges per chunk.
            unsafe { *p.0.add(i) = dot(a.row(i), x) };
        }
    });
    Ok(())
}

/// `y = A^T x` with A: (n, m), x: (n,) -> y: (m,).
pub fn gemv_t(a: &Mat, x: &[f64]) -> Result<Vec<f64>> {
    ensure_shape!(
        a.rows() == x.len(),
        "gemm::gemv_t",
        "a^T is {:?}, x has {}",
        a.shape(),
        x.len()
    );
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        if xi != 0.0 {
            for (yv, av) in y.iter_mut().zip(a.row(i)) {
                *yv += xi * av;
            }
        }
    }
    Ok(y)
}

/// Outer-product accumulate: `C += alpha * x y^T`.
pub fn ger(c: &mut Mat, alpha: f64, x: &[f64], y: &[f64]) -> Result<()> {
    ensure_shape!(
        c.rows() == x.len() && c.cols() == y.len(),
        "gemm::ger",
        "c is {:?}, x has {}, y has {}",
        c.shape(),
        x.len(),
        y.len()
    );
    for (i, &xi) in x.iter().enumerate() {
        let axi = alpha * xi;
        if axi != 0.0 {
            for (cv, yv) in c.row_mut(i).iter_mut().zip(y) {
                *cv += axi * yv;
            }
        }
    }
    Ok(())
}

/// Raw-pointer Send wrapper (disjoint writes guaranteed by the callers).
#[derive(Clone, Copy)]
struct SendSlice(*mut f64);
unsafe impl Send for SendSlice {}
unsafe impl Sync for SendSlice {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (7, 3, 5), (65, 130, 33), (128, 64, 256)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-9, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_path_matches_naive() {
        // shapes over the packed-path thresholds, including non-multiples
        // of MR/NR/KC that exercise zero-padded edge tiles
        for &(m, k, n) in &[(192, 128, 96), (193, 130, 97), (68, 300, 105)] {
            assert!(
                k >= PACKED_MIN_K && m * n * k >= PACKED_MIN_FLOPS,
                "({m},{k},{n}) must exercise the packed engine"
            );
            let a = randm(m, k, 3);
            let b = randm(k, n, 4);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-8, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_alpha_beta_accumulate() {
        let (m, k, n) = (160, 140, 112);
        let a = randm(m, k, 5);
        let b = randm(k, n, 6);
        let mut c = randm(m, n, 7);
        let c0 = c.clone();
        gemm_into(-1.5, &a, &b, 2.0, &mut c).unwrap();
        let want = naive(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let expect = 2.0 * c0[(i, j)] - 1.5 * want[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_nt_matches() {
        let a = randm(33, 21, 3);
        let b = randm(47, 21, 4);
        let got = matmul_nt(&a, &b).unwrap();
        let want = naive(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matmul_tn_matches() {
        let a = randm(21, 33, 5);
        let b = randm(21, 13, 6);
        let got = matmul_tn(&a, &b).unwrap();
        let want = naive(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gemm_alpha_beta() {
        let a = randm(10, 8, 7);
        let b = randm(8, 6, 8);
        let mut c = randm(10, 6, 9);
        let c0 = c.clone();
        gemm_into(2.0, &a, &b, 0.5, &mut c).unwrap();
        let mut want = naive(&a, &b);
        want.scale(2.0);
        let mut c0s = c0;
        c0s.scale(0.5);
        want.axpy(1.0, &c0s).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn syrk_matches() {
        let a = randm(37, 12, 10);
        let got = syrk(&a).unwrap();
        let want = naive(&a, &a.transpose());
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn syrk_into_alpha_beta_and_edges() {
        // sizes straddling the 4×4 tile boundaries
        for &(m, k) in &[(1, 1), (4, 4), (5, 3), (37, 12), (64, 21), (130, 7)] {
            let a = randm(m, k, 11);
            let mut c = Mat::default();
            syrk_into(1.0, &a, 0.0, &mut c).unwrap();
            let want = naive(&a, &a.transpose());
            assert!(c.max_abs_diff(&want) < 1e-9, "({m},{k})");
            // exact symmetry by construction (mirrored, not recomputed)
            for i in 0..m {
                for j in 0..i {
                    assert_eq!(c[(i, j)], c[(j, i)], "({m},{k}) at ({i},{j})");
                }
            }
        }
        // alpha/beta accumulate form
        let a = randm(23, 9, 12);
        let mut c = syrk(&randm(23, 5, 13)).unwrap();
        let c0 = c.clone();
        syrk_into(0.5, &a, 2.0, &mut c).unwrap();
        let want = naive(&a, &a.transpose());
        for i in 0..23 {
            for j in 0..23 {
                let expect = 2.0 * c0[(i, j)] + 0.5 * want[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        // beta != 0 with a mismatched shape must error
        let mut bad = Mat::zeros(5, 5);
        assert!(syrk_into(1.0, &a, 1.0, &mut bad).is_err());
    }

    #[test]
    fn gemv_matches() {
        let a = randm(23, 17, 11);
        let mut rng = Rng::new(12);
        let x = rng.gaussian_vec(17);
        let y = gemv(&a, &x).unwrap();
        for i in 0..23 {
            let want = dot(a.row(i), &x);
            assert!((y[i] - want).abs() < 1e-10);
        }
        let xt = rng.gaussian_vec(23);
        let yt = gemv_t(&a, &xt).unwrap();
        let want = gemv(&a.transpose(), &xt).unwrap();
        for (g, w) in yt.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn ger_accumulates() {
        let mut c = Mat::zeros(3, 4);
        let x = [1.0, 2.0, 3.0];
        let y = [1.0, 0.0, -1.0, 2.0];
        ger(&mut c, 2.0, &x, &y).unwrap();
        assert_eq!(c[(2, 3)], 12.0);
        assert_eq!(c[(1, 2)], -4.0);
    }

    #[test]
    fn shape_errors() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(matmul(&a, &b).is_err());
        assert!(gemv(&a, &[0.0; 2]).is_err());
    }

    #[test]
    fn empty_dims() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 4);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), (0, 4));
        let e = syrk(&Mat::zeros(0, 3)).unwrap();
        assert_eq!(e.shape(), (0, 0));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let a = randm(12, 9, 20);
        let b = randm(9, 7, 21);
        let bt = randm(14, 9, 22);
        let mut c = Mat::default();
        matmul_into(&a, &b, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
        // reuse the same scratch for a different shape
        matmul_nt_into(&a, &bt, &mut c).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &bt.transpose())) < 1e-9);
        let mut y = Vec::new();
        let mut rng = Rng::new(23);
        let x = rng.gaussian_vec(9);
        gemv_into(&a, &x, &mut y).unwrap();
        assert_eq!(y, gemv(&a, &x).unwrap());
    }

    #[test]
    fn nt_acc_block_updates_leading_block() {
        let a = randm(5, 3, 24);
        let b = randm(4, 3, 25);
        let mut c = Mat::from_fn(8, 8, |_, _| 1.0);
        gemm_nt_acc_block(2.0, &a, &b, &mut c).unwrap();
        let want = naive(&a, &b.transpose());
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i < 5 && j < 4 { 1.0 + 2.0 * want[(i, j)] } else { 1.0 };
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        assert!(gemm_nt_acc_block(1.0, &randm(9, 3, 1), &b, &mut c).is_err());
    }

    #[test]
    fn tn_acc_matches_naive() {
        let a = randm(6, 4, 26);
        let b = randm(6, 5, 27);
        let mut c = Mat::from_fn(4, 5, |_, _| 0.5);
        gemm_tn_acc(3.0, &a, &b, &mut c).unwrap();
        let mut want = naive(&a.transpose(), &b);
        want.scale(3.0);
        for i in 0..4 {
            for j in 0..5 {
                assert!((c[(i, j)] - 0.5 - want[(i, j)]).abs() < 1e-9);
            }
        }
    }
}
