//! Factorizations and solves: Cholesky (SPD), LU with partial pivoting,
//! triangular solves, inverses, log-determinant.
//!
//! The nonincremental baselines call [`spd_inverse`]/[`solve_spd`] on every
//! retrain (the O(N^3)/O(J^3) cost the paper's incremental rules avoid);
//! the incremental engines call them once at bootstrap.

use crate::ensure_shape;
use crate::error::{Error, Result};
use crate::linalg::matrix::{dot, Mat};

/// Cholesky factorization `A = L L^T` (lower).  Fails if a pivot is not
/// strictly positive (A not SPD up to roundoff).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let mut l = Mat::default();
    cholesky_into(a, &mut l)?;
    Ok(l)
}

/// [`cholesky`] writing into a caller-provided factor buffer (reshaped and
/// zeroed; allocation-free once its capacity is warm).
pub fn cholesky_into(a: &Mat, l: &mut Mat) -> Result<()> {
    ensure_shape!(a.is_square(), "solve::cholesky", "not square: {:?}", a.shape());
    let n = a.rows();
    l.resize_scratch(n, n);
    l.as_mut_slice().fill(0.0);
    for i in 0..n {
        for j in 0..=i {
            let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = a[(i, i)] - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(Error::numerical(
                        "cholesky",
                        format!("non-positive pivot {d:.3e} at row {i}"),
                    ));
                }
                l[(i, j)] = d.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(())
}

/// Solve `L x = b` (L lower-triangular) in place.
pub fn forward_sub(l: &Mat, b: &mut [f64]) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.len(),
        "solve::forward_sub",
        "l {:?}, b {}",
        l.shape(),
        b.len()
    );
    for i in 0..b.len() {
        let s = dot(&l.row(i)[..i], &b[..i]);
        b[i] = (b[i] - s) / l[(i, i)];
    }
    Ok(())
}

/// Solve `L^T x = b` (L lower-triangular, solving with its transpose) in place.
pub fn backward_sub_t(l: &Mat, b: &mut [f64]) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.len(),
        "solve::backward_sub_t",
        "l {:?}, b {}",
        l.shape(),
        b.len()
    );
    let n = b.len();
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * b[k];
        }
        b[i] = s / l[(i, i)];
    }
    Ok(())
}

/// Solve SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let mut x = b.to_vec();
    forward_sub(&l, &mut x)?;
    backward_sub_t(&l, &mut x)?;
    Ok(x)
}

/// SPD inverse via Cholesky: solves A X = I column by column.
pub fn spd_inverse(a: &Mat) -> Result<Mat> {
    let mut inv = Mat::default();
    spd_inverse_into(a, &mut inv, &mut Mat::default(), &mut Vec::new())?;
    Ok(inv)
}

/// [`spd_inverse`] writing into caller-provided output and scratch buffers
/// (`l` holds the Cholesky factor, `col` one solve column). Allocation-free
/// once the buffers' capacities are warm.
pub fn spd_inverse_into(
    a: &Mat,
    out: &mut Mat,
    l: &mut Mat,
    col: &mut Vec<f64>,
) -> Result<()> {
    let n = a.rows();
    cholesky_into(a, l)?;
    out.resize_scratch(n, n);
    col.clear();
    col.resize(n, 0.0);
    for j in 0..n {
        col.fill(0.0);
        col[j] = 1.0;
        forward_sub(l, col)?;
        backward_sub_t(l, col)?;
        for i in 0..n {
            out[(i, j)] = col[i];
        }
    }
    // exact-arithmetic symmetry, enforce against roundoff drift
    out.symmetrize();
    Ok(())
}

/// log(det(A)) for SPD A (via Cholesky).
pub fn spd_logdet(a: &Mat) -> Result<f64> {
    let l = cholesky(a)?;
    Ok(2.0 * (0..a.rows()).map(|i| l[(i, i)].ln()).sum::<f64>())
}

/// LU decomposition with partial pivoting: returns (LU packed, perm, sign).
pub struct Lu {
    /// Packed L (unit diagonal, below) and U (on/above diagonal).
    pub lu: Mat,
    /// Row permutation: row i of LU corresponds to row perm[i] of A.
    pub perm: Vec<usize>,
    /// Permutation sign (+1/-1), for determinants.
    pub sign: f64,
}

/// Factor a general square matrix.
pub fn lu_decompose(a: &Mat) -> Result<Lu> {
    ensure_shape!(a.is_square(), "solve::lu", "not square: {:?}", a.shape());
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut best = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 || !best.is_finite() {
            return Err(Error::numerical("lu", format!("singular at column {k}")));
        }
        if p != k {
            // swap rows k and p
            for c in 0..n {
                let t = lu[(k, c)];
                lu[(k, c)] = lu[(p, c)];
                lu[(p, c)] = t;
            }
            perm.swap(k, p);
            sign = -sign;
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / pivot;
            lu[(i, k)] = f;
            if f != 0.0 {
                // row_i -= f * row_k for columns k+1..n
                let (rk, ri) = {
                    // split borrows: copy row k segment
                    let rk: Vec<f64> = lu.row(k)[k + 1..].to_vec();
                    (rk, lu.row_mut(i))
                };
                for (c, rkv) in rk.iter().enumerate() {
                    ri[k + 1 + c] -= f * rkv;
                }
            }
        }
    }
    Ok(Lu { lu, perm, sign })
}

impl Lu {
    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.lu.rows();
        ensure_shape!(b.len() == n, "solve::lu_solve", "b has {}, need {}", b.len(), n);
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // forward (unit lower)
        for i in 0..n {
            let s = dot(&self.lu.row(i)[..i], &x[..i]);
            x[i] -= s;
        }
        // backward (upper)
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        self.sign * (0..self.lu.rows()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

/// General inverse via LU.
pub fn inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let lu = lu_decompose(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let col = lu.solve(&e)?;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Ok(inv)
}

/// Solve a small dense system `A x = B` for matrix RHS (used for the H x H
/// Woodbury core, H ~ 6).
pub fn solve_mat(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut lu = a.clone();
    let mut x = b.clone();
    lu_solve_mat_in_place(&mut lu, &mut x)?;
    Ok(x)
}

/// Solve `A X = B` fully in place: `a` is destroyed (overwritten by its LU
/// factors) and `b` is overwritten with the solution. Partial pivoting with
/// the row swaps applied to both sides as they happen, so no permutation
/// vector is needed — the whole solve performs zero heap allocations. This
/// is the workhorse of the in-place Woodbury/Schur updates.
pub fn lu_solve_mat_in_place(a: &mut Mat, b: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.is_square() && a.rows() == b.rows(),
        "solve::lu_solve_mat_in_place",
        "a {:?}, b {:?}",
        a.shape(),
        b.shape()
    );
    let n = a.rows();
    let bc = b.cols();
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut best = a[(k, k)].abs();
        for i in (k + 1)..n {
            let v = a[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 || !best.is_finite() {
            return Err(Error::numerical(
                "lu_solve_mat_in_place",
                format!("singular at column {k}"),
            ));
        }
        if p != k {
            let ad = a.as_mut_slice();
            for c in 0..n {
                ad.swap(k * n + c, p * n + c);
            }
            let bd = b.as_mut_slice();
            for c in 0..bc {
                bd.swap(k * bc + c, p * bc + c);
            }
        }
        // eliminate below the pivot, applying the same row ops to B
        let pivot = a[(k, k)];
        for i in (k + 1)..n {
            let f = a[(i, k)] / pivot;
            a[(i, k)] = f;
            if f != 0.0 {
                for c in (k + 1)..n {
                    let v = a[(k, c)];
                    a[(i, c)] -= f * v;
                }
                for c in 0..bc {
                    let v = b[(k, c)];
                    b[(i, c)] -= f * v;
                }
            }
        }
    }
    // back substitution over rows of B (contiguous row operations)
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            let f = a[(i, k)];
            if f != 0.0 {
                for c in 0..bc {
                    let v = b[(k, c)];
                    b[(i, c)] -= f * v;
                }
            }
        }
        let d = a[(i, i)];
        for c in 0..bc {
            b[(i, c)] /= d;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk};
    use crate::util::prng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut s = syrk(&a).unwrap();
        s.scale(1.0 / n as f64);
        s.add_diag(1.0).unwrap();
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(20, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.transpose()).unwrap();
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_spd_works() {
        let a = spd(15, 2);
        let mut rng = Rng::new(3);
        let x_true = rng.gaussian_vec(15);
        let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
        let x = solve_spd(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn spd_inverse_identity() {
        let a = spd(25, 4);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(25)) < 1e-9);
        // symmetric
        assert!(inv.max_abs_diff(&inv.transpose()) < 1e-12);
    }

    #[test]
    fn logdet_matches_lu_det() {
        let a = spd(10, 5);
        let ld = spd_logdet(&a).unwrap();
        let lu = lu_decompose(&a).unwrap();
        assert!((ld - lu.det().ln()).abs() < 1e-9);
    }

    #[test]
    fn lu_solve_general() {
        let mut rng = Rng::new(6);
        let a = Mat::from_fn(12, 12, |_, _| rng.gaussian());
        let x_true = rng.gaussian_vec(12);
        let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
        let lu = lu_decompose(&a).unwrap();
        let x = lu.solve(&b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn lu_rejects_singular() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        // third row all zeros -> singular
        assert!(lu_decompose(&a).is_err());
    }

    #[test]
    fn inverse_general() {
        let mut rng = Rng::new(7);
        let a = Mat::from_fn(9, 9, |r, c| rng.gaussian() + if r == c { 3.0 } else { 0.0 });
        let inv = inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(9)) < 1e-9);
    }

    #[test]
    fn solve_mat_small_core() {
        let a = spd(6, 8);
        let mut rng = Rng::new(9);
        let b = Mat::from_fn(6, 4, |_, _| rng.gaussian());
        let x = solve_mat(&a, &b).unwrap();
        let rec = matmul(&a, &x).unwrap();
        assert!(rec.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn lu_solve_mat_in_place_matches_and_pivots() {
        // a general (non-SPD) system exercising the pivoting path
        let mut rng = Rng::new(10);
        let a = Mat::from_fn(7, 7, |_, _| rng.gaussian());
        let b = Mat::from_fn(7, 3, |_, _| rng.gaussian());
        let mut lu = a.clone();
        let mut x = b.clone();
        lu_solve_mat_in_place(&mut lu, &mut x).unwrap();
        let rec = matmul(&a, &x).unwrap();
        assert!(rec.max_abs_diff(&b) < 1e-8);
        // singular input rejected
        let mut sing = Mat::zeros(3, 3);
        sing[(0, 0)] = 1.0;
        sing[(1, 1)] = 1.0;
        let mut rhs = Mat::zeros(3, 1);
        assert!(lu_solve_mat_in_place(&mut sing, &mut rhs).is_err());
    }

    #[test]
    fn spd_inverse_into_reuses_buffers() {
        let a = spd(9, 11);
        let mut out = Mat::default();
        let mut l = Mat::default();
        let mut col = Vec::new();
        spd_inverse_into(&a, &mut out, &mut l, &mut col).unwrap();
        assert!(out.max_abs_diff(&spd_inverse(&a).unwrap()) < 1e-12);
        // second use with a different size reshapes the same buffers
        let b = spd(5, 12);
        spd_inverse_into(&b, &mut out, &mut l, &mut col).unwrap();
        let prod = matmul(&b, &out).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(5)) < 1e-9);
    }

    #[test]
    fn det_sign_permutation() {
        // [[0,1],[1,0]] has det -1
        let mut a = Mat::zeros(2, 2);
        a[(0, 1)] = 1.0;
        a[(1, 0)] = 1.0;
        let lu = lu_decompose(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }
}
