//! Factorizations and solves: blocked Cholesky (SPD), blocked LU with
//! partial pivoting, triangular solves, inverses, log-determinant.
//!
//! The nonincremental baselines call [`spd_inverse`]/[`solve_spd`] on every
//! retrain (the O(N^3)/O(J^3) cost the paper's incremental rules avoid);
//! the incremental engines call them once at bootstrap and on periodic
//! refactorization. Both factorizations are **right-looking blocked**
//! variants: a small in-cache diagonal factor, a panel solve through the
//! blocked TRSM family in [`crate::linalg::gemm`], and a trailing
//! SYRK/GEMM update that routes through the shape-adaptive packed
//! dispatch ([`crate::linalg::gemm::dispatch`]) — so bootstrap and
//! baseline costs scale with cores *and* run the packed 4×8 micro-kernel
//! above the crossover (before/after numbers in EXPERIMENTS.md §Perf).
//! Large SPD inverses are two blocked TRSMs against the identity instead
//! of per-column scalar substitution.
//!
//! Since PR 4 the **LU panel itself is packed and parallel** — the last
//! factorization phase that used to run as a serial scalar loop. Pivot
//! search reduces per-lane partial maxima through the persistent pool
//! (deterministically: stripe order decides ties, so the choice is bitwise
//! identical to the scalar scan), row swaps are applied lazily (panel
//! columns immediately, the outside columns in one batched parallel pass
//! per panel), and the panel's fused scale+rank-1 column updates run on
//! `gemm::ger_panel`'s 4×8 register tiles. The parallel thresholds come
//! from the startup tuning table ([`dispatch::tune`]). The scalar
//! reference implementations are kept as
//! [`cholesky_naive`]/[`lu_decompose_naive`] for tests and benches, and
//! [`lu_panel_factor`]/[`lu_panel_factor_scalar`] expose the panel pair
//! for the `core/lu_panel_packed` microbench and the panel property
//! tests.

use crate::ensure_shape;
use crate::error::{Error, Result};
use crate::linalg::gemm::{self, dispatch};
use crate::linalg::matrix::{dot, Mat};
use crate::par;

/// Panel width for the blocked factorizations: the NB×NB diagonal block and
/// an NB-wide panel row stay L1/L2-resident while the trailing update
/// streams.
const NB: usize = 64;
/// Below this size the blocked machinery is pure overhead (the Woodbury
/// cores are ~(|C|+|R|)² — a few dozen elements).
const MIN_BLOCKED: usize = 96;

/// Cholesky factorization `A = L L^T` (lower).  Fails if a pivot is not
/// strictly positive (A not SPD up to roundoff).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let mut l = Mat::default();
    cholesky_into(a, &mut l)?;
    Ok(l)
}

/// [`cholesky`] writing into a caller-provided factor buffer (reshaped;
/// allocation-free once its capacity is warm). Right-looking blocked: for
/// each NB panel, factor the diagonal block in cache, solve the
/// sub-diagonal panel rows in parallel, then apply the rank-NB trailing
/// SYRK update in parallel over rows.
pub fn cholesky_into(a: &Mat, l: &mut Mat) -> Result<()> {
    ensure_shape!(a.is_square(), "solve::cholesky", "not square: {:?}", a.shape());
    let n = a.rows();
    l.resize_scratch(n, n);
    // seed L with the lower triangle of A (zero strict upper): the blocked
    // sweep then updates in place
    for i in 0..n {
        let (ar, lr) = (a.row(i), l.row_mut(i));
        lr[..=i].copy_from_slice(&ar[..=i]);
        lr[i + 1..].fill(0.0);
    }
    if n < MIN_BLOCKED {
        return chol_diag_block(l, 0, n);
    }
    let mut kb = 0;
    while kb < n {
        let nb = NB.min(n - kb);
        chol_diag_block(l, kb, nb)?;
        let panel_end = kb + nb;
        if panel_end == n {
            break;
        }
        // panel solve: L21 L11^T = A21 (rows panel_end..n, cols kb..panel_end)
        // — a right-side TRSM against the freshly factored diagonal block.
        // All access goes through raw views: no `&Mat` may alias the buffer
        // while another lane writes it.
        {
            let base = l.as_mut_slice().as_mut_ptr();
            let rows = n - panel_end;
            // SAFETY: the written L21 rows [panel_end, n) and the read L11
            // rows [kb, panel_end) are disjoint row ranges of the buffer,
            // and each L21 row is owned by exactly one chunk.
            unsafe {
                let l11 = gemm::RawMat::from_raw(base, n, kb, kb);
                let b21 = gemm::SendSlice(base.add(panel_end * n + kb));
                gemm::trsm_right_raw(l11, nb, false, b21, n, rows);
            }
        }
        // trailing SYRK update: A22 -= L21 L21^T (lower triangle only).
        // Reads touch only panel columns [kb, panel_end) which this phase
        // never writes; above the dispatch crossover the update runs on the
        // packed lower-only macro-kernel, below it on the 2-row dot sweep.
        {
            let rows = n - panel_end;
            if dispatch::use_packed(rows, rows, nb) {
                let base = l.as_mut_slice().as_mut_ptr();
                // SAFETY: read columns [kb, panel_end) and written columns
                // [panel_end, n) are disjoint; the C block is rooted on the
                // diagonal, so `lower_only` clips to the global triangle.
                unsafe {
                    let l21 = gemm::RawMat::from_raw(base, n, panel_end, kb);
                    let c22 = gemm::SendSlice(base.add(panel_end * n + panel_end));
                    gemm::gemm_packed_raw(
                        -1.0, l21, false, l21, true, rows, rows, nb, c22, n, true,
                    );
                }
            } else {
                let lptr = gemm::SendSlice(l.as_mut_slice().as_mut_ptr());
                par::parallel_for(rows, 8, |lo, hi| {
                    trailing_syrk_rows(lptr, n, kb, panel_end, panel_end + lo, panel_end + hi);
                });
            }
        }
        kb = panel_end;
    }
    Ok(())
}

/// Unblocked Cholesky of the in-place diagonal block
/// `L[off..off+nb, off..off+nb]` (which already carries all trailing
/// updates from previous panels, so dots start at column `off`).
fn chol_diag_block(l: &mut Mat, off: usize, nb: usize) -> Result<()> {
    for i in off..off + nb {
        for j in off..=i {
            let s = dot(&l.row(i)[off..j], &l.row(j)[off..j]);
            let v = l[(i, j)] - s;
            if i == j {
                if v <= 0.0 || !v.is_finite() {
                    return Err(Error::numerical(
                        "cholesky",
                        format!("non-positive pivot {v:.3e} at row {i}"),
                    ));
                }
                l[(i, j)] = v.sqrt();
            } else {
                l[(i, j)] = v / l[(j, j)];
            }
        }
    }
    Ok(())
}

/// Rank-nb trailing update rows `[lo, hi)`:
/// `L[i][j] -= L[i][kb..pe] · L[j][kb..pe]` for `pe <= j <= i`, 2-row
/// blocked to share the `L[j]` panel loads. Raw-pointer access only — the
/// panel segments read here (columns `[kb, pe)`) are never written in this
/// phase, and writes target columns `>= pe` of exclusively-owned rows.
fn trailing_syrk_rows(lptr: gemm::SendSlice, n: usize, kb: usize, pe: usize, lo: usize, hi: usize) {
    let p = lptr;
    let nb = pe - kb;
    let mut i = lo;
    while i < hi {
        let pair = i + 1 < hi;
        // SAFETY: panel segments are read-only in this phase; the write
        // targets below never overlap them (column ranges are disjoint).
        let ri0 = unsafe { std::slice::from_raw_parts(p.0.add(i * n + kb), nb) };
        let ri1 = if pair {
            unsafe { std::slice::from_raw_parts(p.0.add((i + 1) * n + kb), nb) }
        } else {
            ri0
        };
        let top = if pair { i + 1 } else { i };
        let mut j = pe;
        while j <= top {
            let rj = unsafe { std::slice::from_raw_parts(p.0.add(j * n + kb), nb) };
            let mut s0 = 0.0;
            let mut s1 = 0.0;
            for ((&a0, &a1), &b) in ri0.iter().zip(ri1).zip(rj) {
                s0 += a0 * b;
                s1 += a1 * b;
            }
            // SAFETY: rows [lo, hi) are exclusively owned by this chunk.
            unsafe {
                if j <= i {
                    *p.0.add(i * n + j) -= s0;
                }
                if pair && j <= i + 1 {
                    *p.0.add((i + 1) * n + j) -= s1;
                }
            }
            j += 1;
        }
        i += 2;
    }
}

/// Scalar reference Cholesky (the pre-blocked implementation), kept for
/// property tests and the before/after benches.
pub fn cholesky_naive(a: &Mat) -> Result<Mat> {
    ensure_shape!(a.is_square(), "solve::cholesky_naive", "not square: {:?}", a.shape());
    let n = a.rows();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = a[(i, i)] - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(Error::numerical(
                        "cholesky",
                        format!("non-positive pivot {d:.3e} at row {i}"),
                    ));
                }
                l[(i, j)] = d.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve `L x = b` (L lower-triangular) in place.
pub fn forward_sub(l: &Mat, b: &mut [f64]) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.len(),
        "solve::forward_sub",
        "l {:?}, b {}",
        l.shape(),
        b.len()
    );
    for i in 0..b.len() {
        let s = dot(&l.row(i)[..i], &b[..i]);
        b[i] = (b[i] - s) / l[(i, i)];
    }
    Ok(())
}

/// Solve `L^T x = b` (L lower-triangular, solving with its transpose) in place.
pub fn backward_sub_t(l: &Mat, b: &mut [f64]) -> Result<()> {
    ensure_shape!(
        l.is_square() && l.rows() == b.len(),
        "solve::backward_sub_t",
        "l {:?}, b {}",
        l.shape(),
        b.len()
    );
    let n = b.len();
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * b[k];
        }
        b[i] = s / l[(i, i)];
    }
    Ok(())
}

/// Solve SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let mut x = b.to_vec();
    forward_sub(&l, &mut x)?;
    backward_sub_t(&l, &mut x)?;
    Ok(x)
}

/// SPD inverse via Cholesky: solves A X = I column by column.
pub fn spd_inverse(a: &Mat) -> Result<Mat> {
    let mut inv = Mat::default();
    spd_inverse_into(a, &mut inv, &mut Mat::default(), &mut Vec::new())?;
    Ok(inv)
}

/// [`spd_inverse`] writing into caller-provided output and scratch buffers
/// (`l` holds the Cholesky factor, `col` one solve column). Allocation-free
/// once the buffers' capacities are warm.
///
/// Small systems (the Woodbury update cores) solve unit columns serially
/// against the caller's scratch — zero heap traffic on the hot path. Large
/// inverses are BLAS-3: `L X = I` then `L^T A^-1 = X` as two blocked TRSMs
/// ([`gemm::trsm_lower_into`] / [`gemm::trsm_lower_t_into`]) whose trailing
/// rank-NB updates ride the packed dispatch, replacing the former
/// per-column scalar substitution. The final `symmetrize` absorbs roundoff
/// asymmetry exactly as before.
pub fn spd_inverse_into(
    a: &Mat,
    out: &mut Mat,
    l: &mut Mat,
    col: &mut Vec<f64>,
) -> Result<()> {
    let n = a.rows();
    cholesky_into(a, l)?;
    out.resize_scratch(n, n);
    if n < MIN_BLOCKED {
        // serial path: the caller's scratch column, zero heap traffic;
        // A^-1 is symmetric so each solution is stored as a row
        col.clear();
        col.resize(n, 0.0);
        for j in 0..n {
            col.fill(0.0);
            col[j] = 1.0;
            forward_sub(l, col)?;
            backward_sub_t(l, col)?;
            out.row_mut(j).copy_from_slice(col);
        }
    } else {
        out.as_mut_slice().fill(0.0);
        for j in 0..n {
            out[(j, j)] = 1.0;
        }
        // the factor is triangular with a strictly positive diagonal, so
        // the solves cannot fail past the (already satisfied) shape checks
        gemm::trsm_lower_into(l, false, out)?;
        gemm::trsm_lower_t_into(l, false, out)?;
    }
    // exact-arithmetic symmetry, enforce against roundoff drift
    out.symmetrize();
    Ok(())
}

/// log(det(A)) for SPD A (via Cholesky).
pub fn spd_logdet(a: &Mat) -> Result<f64> {
    let l = cholesky(a)?;
    Ok(2.0 * (0..a.rows()).map(|i| l[(i, i)].ln()).sum::<f64>())
}

/// LU decomposition with partial pivoting: returns (LU packed, perm, sign).
pub struct Lu {
    /// Packed L (unit diagonal, below) and U (on/above diagonal).
    pub lu: Mat,
    /// Row permutation: row i of LU corresponds to row perm[i] of A.
    pub perm: Vec<usize>,
    /// Permutation sign (+1/-1), for determinants.
    pub sign: f64,
}

impl Default for Lu {
    fn default() -> Self {
        Self { lu: Mat::default(), perm: Vec::new(), sign: 1.0 }
    }
}

/// One factored LU panel (see [`lu_panel_factor`]).
pub struct LuPanel {
    /// `ipiv[j]` = the row swapped into panel row `j` at panel column `j`.
    pub ipiv: Vec<usize>,
    /// Sign of the recorded row permutation (+1/-1).
    pub sign: f64,
}

/// Factor a general square matrix: right-looking blocked LU with partial
/// pivoting. See [`lu_decompose_into`] for the scheme.
pub fn lu_decompose(a: &Mat) -> Result<Lu> {
    let mut out = Lu::default();
    lu_decompose_into(a, &mut out)?;
    Ok(out)
}

/// [`lu_decompose`] writing into a caller-provided [`Lu`] (factor buffer
/// and permutation reshaped; allocation-free once their capacities are
/// warm — the panel machinery keeps its pivot scratch on the stack, which
/// is what `rust/tests/alloc_count.rs` measures).
///
/// Right-looking blocked with a **packed parallel panel**: per-lane
/// partial-maxima pivot search reduced deterministically through the pool,
/// lazy row swaps (panel columns during the panel, the outside columns in
/// one batched parallel pass per panel — the LAPACK `getf2`/`laswp`
/// split), and the panel's fused scale+rank-1 updates on
/// [`gemm::ger_panel`]'s 4×8 register tiles. The U12 triangular solve then
/// distributes over column stripes and the rank-NB trailing GEMM update
/// over rows, both through the packed [`dispatch`] above the crossover.
///
/// Parity with [`lu_decompose_naive`]: the panel machinery itself (pivot
/// scan, swaps, column updates) is bitwise identical, and so is the
/// axpy-path trailing update (same per-element subtraction order), so
/// below the packed crossover the whole factorization — permutation
/// included — matches naive bitwise. Above the crossover the packed
/// trailing GEMM accumulates in register tiles (different rounding order),
/// so later-panel values agree only to roundoff and a pivot near-tie could
/// in principle resolve differently; the blocked-vs-naive property tests
/// assert exact `perm` equality only on axpy-path sizes and tolerance
/// elsewhere.
pub fn lu_decompose_into(a: &Mat, out: &mut Lu) -> Result<()> {
    ensure_shape!(a.is_square(), "solve::lu", "not square: {:?}", a.shape());
    let n = a.rows();
    out.lu.resize_scratch(n, n);
    out.lu.as_mut_slice().copy_from_slice(a.as_slice());
    out.perm.clear();
    out.perm.extend(0..n);
    out.sign = 1.0;
    let lu = &mut out.lu;
    // panel pivot rows, stack-resident (NB is small and fixed)
    let mut ipiv = [0usize; NB];
    let mut kb = 0;
    while kb < n {
        let nb = NB.min(n - kb);
        let panel_end = kb + nb;
        // --- packed parallel panel factorization (lazy swaps) ---
        {
            let base = lu.as_mut_slice().as_mut_ptr();
            // SAFETY: `lu` is exclusively borrowed; the panel phase touches
            // rows [kb, n) of columns [kb, panel_end) only.
            unsafe { lu_panel_raw(base, n, n, kb, nb, &mut ipiv[..nb], true)? };
        }
        // --- propagate the panel's row swaps to the outside columns and
        // to perm/sign (same swap order as the scalar reference) ---
        apply_panel_swaps(lu, kb, nb, &ipiv[..nb], &mut out.perm, &mut out.sign);
        if panel_end == n {
            break;
        }
        // --- U12 = L11^{-1} A12: unit-lower TRSM on the in-place panel
        // (one TRSM_NB diagonal block; parallel over RHS column stripes) ---
        {
            let cols = n - panel_end;
            let base = lu.as_mut_slice().as_mut_ptr();
            // SAFETY: the read L11 multipliers (columns [kb, panel_end))
            // and the written U12 block (columns [panel_end, n) of rows
            // kb..panel_end) occupy disjoint column ranges; stripes own
            // disjoint columns.
            unsafe {
                let l11 = gemm::RawMat::from_raw(base, n, kb, kb);
                let b12 = gemm::SendSlice(base.add(kb * n + panel_end));
                gemm::trsm_lower_raw(l11, nb, true, b12, n, cols);
            }
        }
        // --- trailing GEMM update: A22 -= L21 * U12 — packed above the
        // dispatch crossover, axpy row sweep below ---
        {
            let rows = n - panel_end;
            let cols = n - panel_end;
            if dispatch::use_packed(rows, cols, nb) {
                let base = lu.as_mut_slice().as_mut_ptr();
                // SAFETY: L21 (columns < panel_end of the written rows) and
                // U12 (rows < panel_end of the written columns) are both
                // disjoint from the written A22 block; each A22 row is
                // owned by exactly one chunk.
                unsafe {
                    let l21 = gemm::RawMat::from_raw(base, n, panel_end, kb);
                    let u12 = gemm::RawMat::from_raw(base, n, kb, panel_end);
                    let c22 = gemm::SendSlice(base.add(panel_end * n + panel_end));
                    gemm::gemm_packed_raw(
                        -1.0, l21, false, u12, false, rows, cols, nb, c22, n, false,
                    );
                }
            } else {
                let luptr = gemm::SendSlice(lu.as_mut_slice().as_mut_ptr());
                par::parallel_for(rows, 8, |lo, hi| {
                    let p = luptr;
                    for i in panel_end + lo..panel_end + hi {
                        // SAFETY: row i is exclusively owned by this chunk;
                        // its multiplier segment (columns < panel_end) and
                        // the U12 panel rows read below are disjoint from
                        // the written tail and read-only in this phase.
                        let irow = unsafe {
                            std::slice::from_raw_parts_mut(
                                p.0.add(i * n + panel_end),
                                n - panel_end,
                            )
                        };
                        for k in kb..panel_end {
                            let f = unsafe { *p.0.add(i * n + k) };
                            if f != 0.0 {
                                let krow = unsafe {
                                    std::slice::from_raw_parts(
                                        p.0.add(k * n + panel_end),
                                        n - panel_end,
                                    )
                                };
                                for (iv, &kv) in irow.iter_mut().zip(krow) {
                                    *iv -= f * kv;
                                }
                            }
                        }
                    }
                });
            }
        }
        kb = panel_end;
    }
    Ok(())
}

/// Factor the leading `nb`-column panel of `a` (all rows) in place with
/// the **packed parallel** machinery of [`lu_decompose_into`]: per-lane
/// partial-maxima pivot search plus [`gemm::ger_panel`]'s fused
/// scale+rank-1 updates, with the parallel thresholds from
/// [`dispatch::tune`]. Row swaps are applied to the panel columns only —
/// the lazy-swap contract of the blocked sweep; columns of `a` past `nb`
/// (if any) are untouched. Public as the measured side of the
/// `core/lu_panel_packed` microbench and the panel property tests;
/// [`lu_panel_factor_scalar`] is the serial reference with identical
/// semantics (and bitwise-identical output).
pub fn lu_panel_factor(a: &mut Mat, nb: usize) -> Result<LuPanel> {
    lu_panel_factor_impl(a, nb, true)
}

/// Serial reference for [`lu_panel_factor`]: scalar pivot scan, inline
/// column updates, same lazy-swap semantics.
pub fn lu_panel_factor_scalar(a: &mut Mat, nb: usize) -> Result<LuPanel> {
    lu_panel_factor_impl(a, nb, false)
}

fn lu_panel_factor_impl(a: &mut Mat, nb: usize, parallel: bool) -> Result<LuPanel> {
    ensure_shape!(
        nb >= 1 && nb <= a.cols() && nb <= a.rows(),
        "solve::lu_panel",
        "panel width {nb} vs a {:?}",
        a.shape()
    );
    let (n, ld) = a.shape();
    let mut ipiv = vec![0usize; nb];
    // SAFETY: `a` is exclusively borrowed; the panel touches rows [0, n)
    // of columns [0, nb) only.
    unsafe { lu_panel_raw(a.as_mut_slice().as_mut_ptr(), ld, n, 0, nb, &mut ipiv, parallel)? };
    let mut sign = 1.0;
    for (j, &p) in ipiv.iter().enumerate() {
        if p != j {
            sign = -sign;
        }
    }
    Ok(LuPanel { ipiv, sign })
}

/// Factor the panel rows `[kb, n)` × columns `[kb, kb+nb)` of the
/// row-major buffer `base` (leading dimension `ld`) in place with partial
/// pivoting. `ipiv[j]` records the global row swapped into `kb + j`; the
/// swaps are applied **only to the panel's own columns** (lazy — the
/// caller propagates them to the outside columns afterwards, see
/// [`apply_panel_swaps`]). With `parallel`, the pivot search reduces
/// per-lane partial maxima over the persistent pool and the fused
/// scale+rank-1 column updates run on [`gemm::ger_panel`]; without it,
/// both stay serial. The two paths are bitwise identical — every element
/// sees the same operations in the same order, and tie-breaks in the
/// pivot reduction follow stripe order (= row order).
///
/// # Safety
/// `base` must cover `n` rows of stride `ld >= kb + nb`; rows `[kb, n)`
/// of columns `[kb, kb + nb)` must be exclusively owned by the caller for
/// the duration of the call.
unsafe fn lu_panel_raw(
    base: *mut f64,
    ld: usize,
    n: usize,
    kb: usize,
    nb: usize,
    ipiv: &mut [usize],
    parallel: bool,
) -> Result<()> {
    let t = dispatch::tune::table();
    debug_assert_eq!(ipiv.len(), nb);
    for (j, piv) in ipiv.iter_mut().enumerate() {
        let k = kb + j;
        let par_search = parallel && n - k >= t.lu_pivot_par_rows;
        let (best, p) = pivot_search(base, ld, k, n, par_search);
        if best == 0.0 || !best.is_finite() {
            return Err(Error::numerical("lu", format!("singular at column {k}")));
        }
        *piv = p;
        if p != k {
            // lazy swap: the panel's own columns only
            for c in kb..kb + nb {
                std::ptr::swap(base.add(k * ld + c), base.add(p * ld + c));
            }
        }
        let pivot = *base.add(k * ld + k);
        let min_par = if parallel { t.lu_ger_par_rows } else { usize::MAX };
        // fused multiplier scaling + rank-1 update of the remaining panel
        // columns (4×8 register tiles; parallel over rows when worthwhile)
        gemm::ger_panel(gemm::SendSlice(base), ld, k, kb + nb, n, pivot, min_par);
    }
    Ok(())
}

/// Partial-pivot search on column `k`, rows `[k, n)`: returns the maximum
/// |value| and the **first** row attaining it (the scalar scan's
/// tie-break). With `parallel`, the rows split into [`par::MAX_THREADS`]
/// ordered stripes whose per-lane partial maxima land in a stack array
/// (one writer per slot), then reduce serially in stripe order — which
/// lane ran which stripe can never change the winner, so the decision is
/// bitwise identical to the serial scan.
unsafe fn pivot_search(
    base: *const f64,
    ld: usize,
    k: usize,
    n: usize,
    parallel: bool,
) -> (f64, usize) {
    // A NaN pivot seed poisons the scalar scan's running maximum (every
    // later comparison is false), which the error path then reports —
    // return it directly so both paths agree on NaN input.
    let diag = (*base.add(k * ld + k)).abs();
    if diag.is_nan() {
        return (diag, k);
    }
    const SLOTS: usize = par::MAX_THREADS;
    let rows = n - k;
    if !parallel || rows < 2 * SLOTS {
        return pivot_scan(base, ld, k, k, n);
    }
    let span = rows.div_ceil(SLOTS);
    let mut part = [(f64::NEG_INFINITY, usize::MAX); SLOTS];
    let pptr = par::SendPtr(part.as_mut_ptr());
    let bptr = par::SendPtr(base as *mut f64);
    par::parallel_for(SLOTS, 1, |lo, hi| {
        for s in lo..hi {
            let r0 = k + s * span;
            let r1 = (r0 + span).min(n);
            if r0 >= r1 {
                continue;
            }
            // SAFETY: slot s has exactly one writer; the scan only reads
            // the caller-owned column.
            unsafe { *pptr.0.add(s) = pivot_scan(bptr.0, ld, k, r0, r1) };
        }
    });
    // ordered reduction: strictly-greater keeps the lowest-index maximum,
    // exactly like the serial scan
    let mut best = (f64::NEG_INFINITY, k);
    for &(v, at) in part.iter() {
        if v > best.0 {
            best = (v, at);
        }
    }
    best
}

/// Serial max-|value| scan of column `k` over rows `[r0, r1)` (first-max
/// tie-break, matching the scalar reference).
unsafe fn pivot_scan(base: *const f64, ld: usize, k: usize, r0: usize, r1: usize) -> (f64, usize) {
    let mut best = f64::NEG_INFINITY;
    let mut at = r0;
    for i in r0..r1 {
        let v = (*base.add(i * ld + k)).abs();
        if v > best {
            best = v;
            at = i;
        }
    }
    (best, at)
}

/// Propagate a factored panel's row swaps (recorded in `ipiv`) to the
/// columns **outside** the panel — the already-factored L block `[0, kb)`
/// and the trailing block `[kb+nb, n)` — in one batched pass, parallel
/// over column stripes. Each stripe applies every swap in panel order, so
/// the result equals the scalar reference's immediate full-row swaps.
/// Updates `perm` and `sign` in the same order.
fn apply_panel_swaps(
    lu: &mut Mat,
    kb: usize,
    nb: usize,
    ipiv: &[usize],
    perm: &mut [usize],
    sign: &mut f64,
) {
    let n = lu.rows();
    for (j, &p) in ipiv.iter().enumerate() {
        let k = kb + j;
        if p != k {
            perm.swap(k, p);
            *sign = -*sign;
        }
    }
    let right = n - (kb + nb);
    let outside = kb + right;
    if outside == 0 {
        return;
    }
    let base = gemm::SendSlice(lu.as_mut_slice().as_mut_ptr());
    par::parallel_for(outside, 512, |lo, hi| {
        // the stripe [lo, hi) of the concatenated outside columns: left
        // block [0, kb), then right block [kb+nb, n)
        let (l0, l1) = (lo.min(kb), hi.min(kb));
        let (r0, r1) = (
            kb + nb + lo.saturating_sub(kb),
            kb + nb + hi.saturating_sub(kb),
        );
        for (j, &p) in ipiv.iter().enumerate() {
            let k = kb + j;
            if p == k {
                continue;
            }
            // SAFETY: rows k != p; the stripe's columns belong to this
            // chunk alone, and swaps within a column apply in panel order.
            unsafe {
                for c in l0..l1 {
                    std::ptr::swap(base.0.add(k * n + c), base.0.add(p * n + c));
                }
                for c in r0..r1 {
                    std::ptr::swap(base.0.add(k * n + c), base.0.add(p * n + c));
                }
            }
        }
    });
}

/// Scalar reference LU (the pre-blocked implementation), kept for property
/// tests and the before/after benches.
pub fn lu_decompose_naive(a: &Mat) -> Result<Lu> {
    ensure_shape!(a.is_square(), "solve::lu_naive", "not square: {:?}", a.shape());
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    for k in 0..n {
        let mut p = k;
        let mut best = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 || !best.is_finite() {
            return Err(Error::numerical("lu", format!("singular at column {k}")));
        }
        if p != k {
            let d = lu.as_mut_slice();
            for c in 0..n {
                d.swap(k * n + c, p * n + c);
            }
            perm.swap(k, p);
            sign = -sign;
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / pivot;
            lu[(i, k)] = f;
            if f != 0.0 {
                for c in (k + 1)..n {
                    let v = lu[(k, c)];
                    lu[(i, c)] -= f * v;
                }
            }
        }
    }
    Ok(Lu { lu, perm, sign })
}

impl Lu {
    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.lu.rows();
        ensure_shape!(b.len() == n, "solve::lu_solve", "b has {}, need {}", b.len(), n);
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // forward (unit lower)
        for i in 0..n {
            let s = dot(&self.lu.row(i)[..i], &x[..i]);
            x[i] -= s;
        }
        // backward (upper)
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        self.sign * (0..self.lu.rows()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

/// General inverse via LU.
pub fn inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let lu = lu_decompose(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let col = lu.solve(&e)?;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Ok(inv)
}

/// Solve a small dense system `A x = B` for matrix RHS (used for the H x H
/// Woodbury core, H ~ 6).
pub fn solve_mat(a: &Mat, b: &Mat) -> Result<Mat> {
    let mut lu = a.clone();
    let mut x = b.clone();
    lu_solve_mat_in_place(&mut lu, &mut x)?;
    Ok(x)
}

/// Solve `A X = B` fully in place: `a` is destroyed (overwritten by its LU
/// factors) and `b` is overwritten with the solution. Partial pivoting with
/// the row swaps applied to both sides as they happen, so no permutation
/// vector is needed — the whole solve performs zero heap allocations. This
/// is the workhorse of the in-place Woodbury/Schur updates; the systems it
/// sees are the (|C|+|R|)-sized update cores, far below the blocked-LU
/// crossover, so it stays deliberately scalar.
pub fn lu_solve_mat_in_place(a: &mut Mat, b: &mut Mat) -> Result<()> {
    ensure_shape!(
        a.is_square() && a.rows() == b.rows(),
        "solve::lu_solve_mat_in_place",
        "a {:?}, b {:?}",
        a.shape(),
        b.shape()
    );
    let n = a.rows();
    let bc = b.cols();
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut best = a[(k, k)].abs();
        for i in (k + 1)..n {
            let v = a[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 || !best.is_finite() {
            return Err(Error::numerical(
                "lu_solve_mat_in_place",
                format!("singular at column {k}"),
            ));
        }
        if p != k {
            let ad = a.as_mut_slice();
            for c in 0..n {
                ad.swap(k * n + c, p * n + c);
            }
            let bd = b.as_mut_slice();
            for c in 0..bc {
                bd.swap(k * bc + c, p * bc + c);
            }
        }
        // eliminate below the pivot, applying the same row ops to B
        let pivot = a[(k, k)];
        for i in (k + 1)..n {
            let f = a[(i, k)] / pivot;
            a[(i, k)] = f;
            if f != 0.0 {
                for c in (k + 1)..n {
                    let v = a[(k, c)];
                    a[(i, c)] -= f * v;
                }
                for c in 0..bc {
                    let v = b[(k, c)];
                    b[(i, c)] -= f * v;
                }
            }
        }
    }
    // back substitution over rows of B (contiguous row operations)
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            let f = a[(i, k)];
            if f != 0.0 {
                for c in 0..bc {
                    let v = b[(k, c)];
                    b[(i, c)] -= f * v;
                }
            }
        }
        let d = a[(i, i)];
        for c in 0..bc {
            b[(i, c)] /= d;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk};
    use crate::util::prng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut s = syrk(&a).unwrap();
        s.scale(1.0 / n as f64);
        s.add_diag(1.0).unwrap();
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(20, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.transpose()).unwrap();
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn blocked_cholesky_matches_naive_across_panel_edges() {
        // sizes below, at, and straddling the NB panel boundary, plus one
        // with several panels and a partial tail
        for &(n, seed) in &[(95, 2), (96, 3), (97, 4), (128, 5), (200, 6), (257, 7)] {
            let a = spd(n, seed);
            let got = cholesky(&a).unwrap();
            let want = cholesky_naive(&a).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-10,
                "n={n}: blocked vs naive diff {}",
                got.max_abs_diff(&want)
            );
            let rec = matmul(&got, &got.transpose()).unwrap();
            assert!(rec.max_abs_diff(&a) < 1e-8, "n={n} reconstruction");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
        assert!(cholesky_naive(&a).is_err());
        // blocked path must reject too (indefinite leaks into a later panel)
        let mut big = spd(150, 8);
        big[(120, 120)] = -50.0;
        assert!(cholesky(&big).is_err());
    }

    #[test]
    fn solve_spd_works() {
        let a = spd(15, 2);
        let mut rng = Rng::new(3);
        let x_true = rng.gaussian_vec(15);
        let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
        let x = solve_spd(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn spd_inverse_identity() {
        let a = spd(25, 4);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(25)) < 1e-9);
        // symmetric
        assert!(inv.max_abs_diff(&inv.transpose()) < 1e-12);
    }

    #[test]
    fn spd_inverse_parallel_path_matches() {
        // size over MIN_BLOCKED so the row-parallel solves run when the
        // pool is active (inline when MIKRR_THREADS=1 — same code result)
        let a = spd(140, 9);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(140)) < 1e-8);
        assert!(inv.max_abs_diff(&inv.transpose()) < 1e-12);
    }

    #[test]
    fn logdet_matches_lu_det() {
        let a = spd(10, 5);
        let ld = spd_logdet(&a).unwrap();
        let lu = lu_decompose(&a).unwrap();
        assert!((ld - lu.det().ln()).abs() < 1e-9);
    }

    #[test]
    fn lu_solve_general() {
        let mut rng = Rng::new(6);
        let a = Mat::from_fn(12, 12, |_, _| rng.gaussian());
        let x_true = rng.gaussian_vec(12);
        let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
        let lu = lu_decompose(&a).unwrap();
        let x = lu.solve(&b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn blocked_lu_matches_naive_across_panel_edges() {
        for &(n, seed) in &[(63, 10), (64, 11), (65, 12), (130, 13), (200, 14)] {
            let mut rng = Rng::new(seed);
            let a = Mat::from_fn(n, n, |r, c| {
                rng.gaussian() + if r == c { 2.0 } else { 0.0 }
            });
            let got = lu_decompose(&a).unwrap();
            let want = lu_decompose_naive(&a).unwrap();
            assert_eq!(got.perm, want.perm, "n={n} permutations diverge");
            assert_eq!(got.sign, want.sign, "n={n}");
            assert!(
                got.lu.max_abs_diff(&want.lu) < 1e-9,
                "n={n}: blocked vs naive LU diff {}",
                got.lu.max_abs_diff(&want.lu)
            );
            // and the factorization actually solves
            let x_true = rng.gaussian_vec(n);
            let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
            let x = got.solve(&b).unwrap();
            for (g, w) in x.iter().zip(&x_true) {
                assert!((g - w).abs() < 1e-6, "n={n}");
            }
        }
    }

    #[test]
    fn lu_decompose_into_reuses_buffers_and_matches_naive() {
        let mut out = Lu::default();
        let mut rng = Rng::new(60);
        // shrinking and growing sizes reshape the same buffers; the packed
        // parallel panel must keep pivoting bitwise-identical to naive
        for &n in &[90usize, 40, 150, 64] {
            let a = Mat::from_fn(n, n, |r, c| {
                rng.gaussian() + if r == c { 2.5 } else { 0.0 }
            });
            lu_decompose_into(&a, &mut out).unwrap();
            let want = lu_decompose_naive(&a).unwrap();
            assert_eq!(out.perm, want.perm, "n={n}: pivoting diverged");
            assert_eq!(out.sign, want.sign, "n={n}");
            assert!(
                out.lu.max_abs_diff(&want.lu) < 1e-9,
                "n={n}: into vs naive diff {}",
                out.lu.max_abs_diff(&want.lu)
            );
        }
    }

    #[test]
    fn lu_panel_factor_solves_square_panel() {
        // a full-width panel (nb = n) is a complete LU factorization with
        // lazy semantics: applying ipiv to b then L/U solves must recover x
        let n = 48;
        let mut rng = Rng::new(61);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut f = a.clone();
        let panel = lu_panel_factor(&mut f, n).unwrap();
        // rebuild the Lu form: ipiv (applied in order) -> perm
        let mut perm: Vec<usize> = (0..n).collect();
        for (j, &p) in panel.ipiv.iter().enumerate() {
            perm.swap(j, p);
        }
        let lu = Lu { lu: f, perm, sign: panel.sign };
        let x_true = rng.gaussian_vec(n);
        let b = crate::linalg::gemm::gemv(&a, &x_true).unwrap();
        let x = lu.solve(&b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-7);
        }
        // the scalar reference produces the identical factorization
        let mut fs = a.clone();
        let ps = lu_panel_factor_scalar(&mut fs, n).unwrap();
        assert_eq!(ps.ipiv, panel.ipiv);
        assert_eq!(ps.sign, panel.sign);
        assert!(lu.lu == fs, "packed and scalar panels must be bitwise identical");
        // shape errors
        let mut bad = Mat::zeros(3, 3);
        assert!(lu_panel_factor(&mut bad, 4).is_err());
        assert!(lu_panel_factor(&mut bad, 0).is_err());
    }

    #[test]
    fn lu_rejects_singular() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        // third row all zeros -> singular
        assert!(lu_decompose(&a).is_err());
        assert!(lu_decompose_naive(&a).is_err());
        // blocked path: rank deficiency appearing after the first panel
        let mut big = Mat::eye(100);
        for c in 0..100 {
            big[(80, c)] = big[(79, c)];
        }
        assert!(lu_decompose(&big).is_err());
    }

    #[test]
    fn inverse_general() {
        let mut rng = Rng::new(7);
        let a = Mat::from_fn(9, 9, |r, c| rng.gaussian() + if r == c { 3.0 } else { 0.0 });
        let inv = inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(9)) < 1e-9);
    }

    #[test]
    fn solve_mat_small_core() {
        let a = spd(6, 8);
        let mut rng = Rng::new(9);
        let b = Mat::from_fn(6, 4, |_, _| rng.gaussian());
        let x = solve_mat(&a, &b).unwrap();
        let rec = matmul(&a, &x).unwrap();
        assert!(rec.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn lu_solve_mat_in_place_matches_and_pivots() {
        // a general (non-SPD) system exercising the pivoting path
        let mut rng = Rng::new(10);
        let a = Mat::from_fn(7, 7, |_, _| rng.gaussian());
        let b = Mat::from_fn(7, 3, |_, _| rng.gaussian());
        let mut lu = a.clone();
        let mut x = b.clone();
        lu_solve_mat_in_place(&mut lu, &mut x).unwrap();
        let rec = matmul(&a, &x).unwrap();
        assert!(rec.max_abs_diff(&b) < 1e-8);
        // singular input rejected
        let mut sing = Mat::zeros(3, 3);
        sing[(0, 0)] = 1.0;
        sing[(1, 1)] = 1.0;
        let mut rhs = Mat::zeros(3, 1);
        assert!(lu_solve_mat_in_place(&mut sing, &mut rhs).is_err());
    }

    #[test]
    fn spd_inverse_into_reuses_buffers() {
        let a = spd(9, 11);
        let mut out = Mat::default();
        let mut l = Mat::default();
        let mut col = Vec::new();
        spd_inverse_into(&a, &mut out, &mut l, &mut col).unwrap();
        assert!(out.max_abs_diff(&spd_inverse(&a).unwrap()) < 1e-12);
        // second use with a different size reshapes the same buffers
        let b = spd(5, 12);
        spd_inverse_into(&b, &mut out, &mut l, &mut col).unwrap();
        let prod = matmul(&b, &out).unwrap();
        assert!(prod.max_abs_diff(&Mat::eye(5)) < 1e-9);
    }

    #[test]
    fn det_sign_permutation() {
        // [[0,1],[1,0]] has det -1
        let mut a = Mat::zeros(2, 2);
        a[(0, 1)] = 1.0;
        a[(1, 0)] = 1.0;
        let lu = lu_decompose(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }
}
