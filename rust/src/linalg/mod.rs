//! Dense linear-algebra substrate (from scratch; f64, row-major).
//!
//! The paper's update rules are rank-k corrections of maintained inverses;
//! everything they need lives here:
//!
//! * [`matrix`] — the `Mat` container and views;
//! * [`gemm`] — blocked, multi-threaded BLAS-3 behind one shape-adaptive
//!   packed dispatch ([`gemm::dispatch`]): NN/NT/TN multiply, SYRK (both
//!   sides), blocked TRSM, GEMV;
//! * [`solve`] — Cholesky and LU factorizations, triangular solves, SPD and
//!   general inverses;
//! * [`woodbury`] — the paper's eq. (13)–(15) batched up/down-dates and the
//!   eq. (22)/(27)–(30) bordered grow/shrink rules for empirical space.

pub mod gemm;
pub mod matrix;
pub mod solve;
pub mod sparse;
pub mod woodbury;

pub use matrix::Mat;
pub use sparse::SparseMat;
