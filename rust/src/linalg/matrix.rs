//! `Mat`: a dense, row-major, f64 matrix with the small API surface the
//! incremental-KRR engines need. Deliberately simple — contiguous `Vec<f64>`
//! storage, explicit shapes, panics only in `debug_assert`s; fallible ops
//! return [`crate::error::Result`].
//!
//! Beyond the basic container, `Mat` carries a `Vec`-style reserved capacity
//! so the maintained-inverse engines can resize without reallocating:
//! [`Mat::grow_inplace`] / [`Mat::shrink_inplace`] restride the buffer for
//! row/col append and truncation, [`Mat::compact`] gathers an index-set
//! submatrix forward into the same buffer, and [`Mat::resize_scratch`]
//! repurposes a matrix as an overwrite-target workspace. All of them are
//! allocation-free once the backing buffer has warmed up to the workload's
//! peak size (growth beyond capacity reserves with amortized doubling).

use crate::ensure_shape;
use crate::error::Result;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Default for Mat {
    fn default() -> Self {
        Self { rows: 0, cols: 0, data: Vec::new() }
    }
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat({}x{})", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for r in 0..rmax {
            write!(f, "  [")?;
            for c in 0..cmax {
                write!(f, "{:>10.4}", self[(r, c)])?;
            }
            writeln!(f, "{}]", if cmax < self.cols { " ..." } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  ...")?;
        }
        Ok(())
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Wrap an existing buffer (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        ensure_shape!(
            data.len() == rows * cols,
            "Mat::from_vec",
            "len {} != {}x{}",
            data.len(),
            rows,
            cols
        );
        Ok(Self { rows, cols, data })
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Self { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// True when square.
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw data slice (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow one row.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow one row.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy one column out.
    pub fn col(&self, c: usize) -> Vec<f64> {
        debug_assert!(c < self.cols);
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transposed copy (blocked for cache friendliness).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::default();
        self.transpose_into(&mut out);
        out
    }

    /// Copy of selected rows, in the given order.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Copy of selected columns, in the given order.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            for (j, &c) in idx.iter().enumerate() {
                out[(r, j)] = self[(r, c)];
            }
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Result<Mat> {
        ensure_shape!(
            self.rows == other.rows,
            "Mat::hcat",
            "rows {} != {}",
            self.rows,
            other.rows
        );
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        Ok(out)
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Result<Mat> {
        ensure_shape!(
            self.cols == other.cols,
            "Mat::vcat",
            "cols {} != {}",
            self.cols,
            other.cols
        );
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Append one row in place.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        ensure_shape!(
            row.len() == self.cols || self.rows == 0,
            "Mat::push_row",
            "row len {} != cols {}",
            row.len(),
            self.cols
        );
        if self.rows == 0 {
            self.cols = row.len();
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// Remove rows by index (any order; deduplicated), preserving the order
    /// of the remaining rows. Returns the removed rows as a new Mat in
    /// ascending original-index order.
    pub fn remove_rows(&mut self, idx: &[usize]) -> Result<Mat> {
        let mut sorted: Vec<usize> = idx.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if let Some(&max) = sorted.last() {
            ensure_shape!(
                max < self.rows,
                "Mat::remove_rows",
                "index {} >= rows {}",
                max,
                self.rows
            );
        }
        let removed = self.select_rows(&sorted);
        self.drop_rows_sorted(&sorted)?;
        Ok(removed)
    }

    /// Remove rows by a sorted, deduplicated index list, preserving the
    /// order of the remaining rows. The allocation-free core of
    /// [`Mat::remove_rows`]: compacts in place (one memmove per kept row
    /// after the first removal) and never touches the heap.
    pub fn drop_rows_sorted(&mut self, sorted: &[usize]) -> Result<()> {
        debug_assert!(sorted.windows(2).all(|w| w[0] < w[1]));
        if sorted.is_empty() {
            return Ok(());
        }
        ensure_shape!(
            sorted[sorted.len() - 1] < self.rows,
            "Mat::drop_rows_sorted",
            "index {} >= rows {}",
            sorted[sorted.len() - 1],
            self.rows
        );
        let keep_rows = self.rows - sorted.len();
        let cols = self.cols;
        let mut dst = sorted[0];
        let mut it = sorted.iter().peekable();
        for r in sorted[0]..self.rows {
            if it.peek() == Some(&&r) {
                it.next();
                continue;
            }
            if dst != r {
                self.data.copy_within(r * cols..(r + 1) * cols, dst * cols);
            }
            dst += 1;
        }
        self.data.truncate(keep_rows * cols);
        self.rows = keep_rows;
        Ok(())
    }

    /// Append all rows of `other` in place (an in-place [`Mat::vcat`]).
    /// Amortized allocation-free: reserves with doubling when the backing
    /// buffer is outgrown, so steady-state appends never reallocate.
    pub fn push_rows(&mut self, other: &Mat) -> Result<()> {
        ensure_shape!(
            other.cols == self.cols || self.rows == 0,
            "Mat::push_rows",
            "cols {} != {}",
            other.cols,
            self.cols
        );
        if self.rows == 0 {
            self.cols = other.cols;
        }
        self.reserve_total(self.data.len() + other.data.len());
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
        Ok(())
    }

    /// Reserved element capacity of the backing buffer.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Ensure the backing buffer can hold `total` elements without a
    /// further reallocation. Grows with amortized doubling (at least 2x the
    /// current capacity) so repeated small growths cost O(1) amortized.
    pub fn reserve_total(&mut self, total: usize) {
        if total > self.data.capacity() {
            let target = total.max(self.data.capacity() * 2);
            self.data.reserve_exact(target - self.data.len());
        }
    }

    /// Grow to `(new_rows, new_cols)` in place, keeping existing entries in
    /// their (row, col) positions and zero-filling the new cells. Restrides
    /// the row-major buffer without reallocating when capacity suffices;
    /// otherwise reserves with amortized doubling.
    pub fn grow_inplace(&mut self, new_rows: usize, new_cols: usize) -> Result<()> {
        ensure_shape!(
            new_rows >= self.rows && new_cols >= self.cols,
            "Mat::grow_inplace",
            "({}, {}) -> ({}, {}) shrinks",
            self.rows,
            self.cols,
            new_rows,
            new_cols
        );
        let (old_rows, old_cols) = (self.rows, self.cols);
        self.reserve_total(new_rows * new_cols);
        self.data.resize(new_rows * new_cols, 0.0);
        if new_cols > old_cols {
            // restride back-to-front: each row's destination only overlaps
            // sources of rows already moved
            for r in (1..old_rows).rev() {
                self.data
                    .copy_within(r * old_cols..(r + 1) * old_cols, r * new_cols);
            }
            // zero the exposed column tails (stale pre-restride bytes)
            for r in 0..old_rows {
                self.data[r * new_cols + old_cols..(r + 1) * new_cols].fill(0.0);
            }
        }
        self.rows = new_rows;
        self.cols = new_cols;
        Ok(())
    }

    /// Shrink to the leading `(new_rows, new_cols)` block in place (drops
    /// trailing rows/cols). Never allocates; capacity is retained for later
    /// regrowth.
    pub fn shrink_inplace(&mut self, new_rows: usize, new_cols: usize) -> Result<()> {
        ensure_shape!(
            new_rows <= self.rows && new_cols <= self.cols,
            "Mat::shrink_inplace",
            "({}, {}) -> ({}, {}) grows",
            self.rows,
            self.cols,
            new_rows,
            new_cols
        );
        let old_cols = self.cols;
        if new_cols < old_cols {
            // forward restride: each source range sits at or after its
            // destination, so earlier writes never clobber pending reads
            for r in 1..new_rows {
                self.data
                    .copy_within(r * old_cols..r * old_cols + new_cols, r * new_cols);
            }
        }
        self.data.truncate(new_rows * new_cols);
        self.rows = new_rows;
        self.cols = new_cols;
        Ok(())
    }

    /// Compact to the submatrix selected by sorted, strictly-increasing
    /// row/col index sets, in place and without allocating. Every source
    /// element sits at or after its destination in the row-major buffer, so
    /// a single forward gather pass is safe.
    pub fn compact(&mut self, keep_rows: &[usize], keep_cols: &[usize]) -> Result<()> {
        debug_assert!(keep_rows.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(keep_cols.windows(2).all(|w| w[0] < w[1]));
        ensure_shape!(
            keep_rows.last().is_none_or(|&r| r < self.rows)
                && keep_cols.last().is_none_or(|&c| c < self.cols),
            "Mat::compact",
            "keep sets exceed shape {:?}",
            self.shape()
        );
        let old_cols = self.cols;
        let mut dst = 0usize;
        for &r in keep_rows {
            let base = r * old_cols;
            for &c in keep_cols {
                self.data[dst] = self.data[base + c];
                dst += 1;
            }
        }
        self.data.truncate(dst);
        self.rows = keep_rows.len();
        self.cols = keep_cols.len();
        Ok(())
    }

    /// Reshape as an overwrite target: the logical shape becomes
    /// `(rows, cols)` and the contents are unspecified (callers must fully
    /// overwrite). Allocation-free once the buffer has warmed to the
    /// workload's peak size — this is how the update workspaces are reused.
    pub fn resize_scratch(&mut self, rows: usize, cols: usize) {
        self.reserve_total(rows * cols);
        self.data.resize(rows * cols, 0.0);
        self.rows = rows;
        self.cols = cols;
    }

    /// Transposed copy written into a caller-provided matrix (reshaped as
    /// needed; allocation-free with warm capacity).
    pub fn transpose_into(&self, out: &mut Mat) {
        out.resize_scratch(self.cols, self.rows);
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out[(c, r)] = self[(r, c)];
                    }
                }
            }
        }
    }

    /// Submatrix copy `[r0..r1, c0..c1)`.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        debug_assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.row_mut(r - r0)
                .copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) -> Result<()> {
        ensure_shape!(
            self.shape() == other.shape(),
            "Mat::axpy",
            "{:?} != {:?}",
            self.shape(),
            other.shape()
        );
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Symmetrize in place: `A = (A + A^T) / 2` (drift control for the
    /// maintained inverses, which are SPD in exact arithmetic).
    pub fn symmetrize(&mut self) {
        debug_assert!(self.is_square());
        let n = self.rows;
        for r in 0..n {
            for c in (r + 1)..n {
                let v = 0.5 * (self[(r, c)] + self[(c, r)]);
                self[(r, c)] = v;
                self[(c, r)] = v;
            }
        }
    }

    /// `A + alpha*I` (must be square).
    pub fn add_diag(&mut self, alpha: f64) -> Result<()> {
        ensure_shape!(self.is_square(), "Mat::add_diag", "not square: {:?}", self.shape());
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
        Ok(())
    }

    /// Row sums as a vector (`A e^T`).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.row_sums_into(&mut out);
        out
    }

    /// Row sums written into a caller-provided buffer (resized; no
    /// allocation once its capacity is warm).
    pub fn row_sums_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.rows).map(|r| self.row(r).iter().sum::<f64>()));
    }

    /// Column sums as a vector (`e A`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (o, v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Check all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for ILP; LLVM vectorizes this well.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x` over slices.
#[inline]
pub fn axpy_slice(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[allow(clippy::needless_range_loop)]
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_fn(3, 4, |r, c| (r * 10 + c) as f64);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn eye_and_diag() {
        let mut m = Mat::eye(3);
        m.add_diag(0.5).unwrap();
        assert_eq!(m[(0, 0)], 1.5);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(37, 53, |r, c| (r * 53 + c) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.transpose(), m);
        assert_eq!(t[(5, 7)], m[(7, 5)]);
    }

    #[test]
    fn hcat_vcat() {
        let a = Mat::from_fn(2, 2, |r, c| (r + c) as f64);
        let b = Mat::from_fn(2, 1, |_, _| 9.0);
        let h = a.hcat(&b).unwrap();
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h[(1, 2)], 9.0);
        let v = a.vcat(&a).unwrap();
        assert_eq!(v.shape(), (4, 2));
        assert!(a.hcat(&Mat::zeros(3, 1)).is_err());
    }

    #[test]
    fn push_remove_rows() {
        let mut m = Mat::zeros(0, 0);
        m.push_row(&[1.0, 2.0]).unwrap();
        m.push_row(&[3.0, 4.0]).unwrap();
        m.push_row(&[5.0, 6.0]).unwrap();
        assert_eq!(m.shape(), (3, 2));
        let removed = m.remove_rows(&[1]).unwrap();
        assert_eq!(removed.row(0), &[3.0, 4.0]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m.row(1), &[5.0, 6.0]);
        assert!(m.remove_rows(&[5]).is_err());
    }

    #[test]
    fn remove_rows_unsorted_dedup() {
        let mut m = Mat::from_fn(5, 1, |r, _| r as f64);
        let removed = m.remove_rows(&[3, 1, 3]).unwrap();
        assert_eq!(removed.col(0), vec![1.0, 3.0]);
        assert_eq!(m.col(0), vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn select_and_block() {
        let m = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0)[0], 8.0);
        assert_eq!(s.row(1)[0], 0.0);
        let b = m.block(1, 3, 2, 4);
        assert_eq!(b.shape(), (2, 2));
        assert_eq!(b[(0, 0)], 6.0);
        let c = m.select_cols(&[3, 1]);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(0, 1)], 1.0);
    }

    #[test]
    fn sums_and_norms() {
        let m = Mat::from_fn(2, 3, |_, _| 1.0);
        assert_eq!(m.row_sums(), vec![3.0, 3.0]);
        assert_eq!(m.col_sums(), vec![2.0, 2.0, 2.0]);
        assert!((m.fro_norm() - 6.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn symmetrize() {
        let mut m = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f64);
        m.symmetrize();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(m[(r, c)], m[(c, r)]);
            }
        }
    }

    #[test]
    fn dot_unrolled() {
        let a: Vec<f64> = (0..103).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..103).map(|i| (i * 2) as f64).collect();
        let want: f64 = (0..103).map(|i| (i * i * 2) as f64).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-9);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Mat::from_vec(2, 2, vec![0.0; 4]).is_ok());
    }

    #[test]
    fn grow_inplace_preserves_and_zero_fills() {
        let mut m = Mat::from_fn(3, 2, |r, c| (r * 2 + c + 1) as f64);
        m.grow_inplace(5, 4).unwrap();
        assert_eq!(m.shape(), (5, 4));
        for r in 0..3 {
            for c in 0..2 {
                assert_eq!(m[(r, c)], (r * 2 + c + 1) as f64);
            }
            for c in 2..4 {
                assert_eq!(m[(r, c)], 0.0, "tail ({r},{c})");
            }
        }
        for r in 3..5 {
            assert!(m.row(r).iter().all(|&v| v == 0.0));
        }
        assert!(m.grow_inplace(2, 2).is_err());
    }

    #[test]
    fn grow_inplace_within_capacity_does_not_realloc() {
        let mut m = Mat::zeros(2, 2);
        m.reserve_total(100);
        let cap = m.capacity();
        let ptr = m.as_slice().as_ptr();
        m.grow_inplace(6, 6).unwrap();
        assert_eq!(m.capacity(), cap);
        assert_eq!(m.as_slice().as_ptr(), ptr);
    }

    #[test]
    fn reserve_total_doubles() {
        let mut m = Mat::zeros(2, 2);
        let c0 = m.capacity();
        m.reserve_total(c0 + 1);
        assert!(m.capacity() >= 2 * c0);
    }

    #[test]
    fn shrink_inplace_keeps_leading_block() {
        let mut m = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        let cap = m.capacity();
        m.shrink_inplace(2, 3).unwrap();
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.capacity(), cap, "capacity retained for regrowth");
        assert!(m.shrink_inplace(3, 3).is_err());
    }

    #[test]
    fn grow_shrink_roundtrip_inplace() {
        let orig = Mat::from_fn(5, 5, |r, c| (r * 5 + c) as f64);
        let mut m = orig.clone();
        m.grow_inplace(8, 8).unwrap();
        m.shrink_inplace(5, 5).unwrap();
        assert_eq!(m, orig);
    }

    #[test]
    fn compact_gathers_index_sets() {
        let mut m = Mat::from_fn(5, 5, |r, c| (r * 10 + c) as f64);
        m.compact(&[0, 2, 4], &[1, 3]).unwrap();
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.row(0), &[1.0, 3.0]);
        assert_eq!(m.row(1), &[21.0, 23.0]);
        assert_eq!(m.row(2), &[41.0, 43.0]);
        assert!(m.compact(&[5], &[0]).is_err());
    }

    #[test]
    fn compact_to_empty() {
        let mut m = Mat::from_fn(3, 3, |_, _| 1.0);
        m.compact(&[], &[]).unwrap();
        assert_eq!(m.shape(), (0, 0));
    }

    #[test]
    fn drop_rows_sorted_matches_remove_rows() {
        let mut a = Mat::from_fn(6, 2, |r, _| r as f64);
        let mut b = a.clone();
        a.remove_rows(&[1, 4]).unwrap();
        b.drop_rows_sorted(&[1, 4]).unwrap();
        assert_eq!(a, b);
        assert!(b.drop_rows_sorted(&[9]).is_err());
    }

    #[test]
    fn push_rows_appends() {
        let mut m = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as f64);
        let extra = Mat::from_fn(2, 3, |r, c| (100 + r * 3 + c) as f64);
        m.push_rows(&extra).unwrap();
        assert_eq!(m.shape(), (4, 3));
        assert_eq!(m.row(2), extra.row(0));
        assert!(m.push_rows(&Mat::zeros(1, 2)).is_err());
        let mut empty = Mat::zeros(0, 0);
        empty.push_rows(&extra).unwrap();
        assert_eq!(empty.shape(), (2, 3));
    }

    #[test]
    fn resize_scratch_and_transpose_into_reuse() {
        let mut scratch = Mat::default();
        scratch.resize_scratch(4, 3);
        assert_eq!(scratch.shape(), (4, 3));
        let m = Mat::from_fn(7, 2, |r, c| (r * 2 + c) as f64);
        m.transpose_into(&mut scratch);
        assert_eq!(scratch.shape(), (2, 7));
        assert_eq!(scratch, m.transpose());
    }

    #[test]
    fn row_sums_into_reuses_buffer() {
        let m = Mat::from_fn(3, 2, |_, _| 2.0);
        let mut buf = Vec::with_capacity(8);
        m.row_sums_into(&mut buf);
        assert_eq!(buf, vec![4.0, 4.0, 4.0]);
        m.row_sums_into(&mut buf);
        assert_eq!(buf.len(), 3);
    }
}
