//! `Mat`: a dense, row-major, f64 matrix with the small API surface the
//! incremental-KRR engines need. Deliberately simple — contiguous `Vec<f64>`
//! storage, explicit shapes, panics only in `debug_assert`s; fallible ops
//! return [`crate::error::Result`].

use crate::ensure_shape;
use crate::error::Result;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat({}x{})", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for r in 0..rmax {
            write!(f, "  [")?;
            for c in 0..cmax {
                write!(f, "{:>10.4}", self[(r, c)])?;
            }
            writeln!(f, "{}]", if cmax < self.cols { " ..." } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  ...")?;
        }
        Ok(())
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Wrap an existing buffer (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        ensure_shape!(
            data.len() == rows * cols,
            "Mat::from_vec",
            "len {} != {}x{}",
            data.len(),
            rows,
            cols
        );
        Ok(Self { rows, cols, data })
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Self { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// True when square.
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw data slice (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow one row.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow one row.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy one column out.
    pub fn col(&self, c: usize) -> Vec<f64> {
        debug_assert!(c < self.cols);
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out[(c, r)] = self[(r, c)];
                    }
                }
            }
        }
        out
    }

    /// Copy of selected rows, in the given order.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Copy of selected columns, in the given order.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            for (j, &c) in idx.iter().enumerate() {
                out[(r, j)] = self[(r, c)];
            }
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Result<Mat> {
        ensure_shape!(
            self.rows == other.rows,
            "Mat::hcat",
            "rows {} != {}",
            self.rows,
            other.rows
        );
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        Ok(out)
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Result<Mat> {
        ensure_shape!(
            self.cols == other.cols,
            "Mat::vcat",
            "cols {} != {}",
            self.cols,
            other.cols
        );
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Append one row in place.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        ensure_shape!(
            row.len() == self.cols || self.rows == 0,
            "Mat::push_row",
            "row len {} != cols {}",
            row.len(),
            self.cols
        );
        if self.rows == 0 {
            self.cols = row.len();
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// Remove rows by index (any order; deduplicated), preserving the order
    /// of the remaining rows. Returns the removed rows as a new Mat in
    /// ascending original-index order.
    pub fn remove_rows(&mut self, idx: &[usize]) -> Result<Mat> {
        let mut sorted: Vec<usize> = idx.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if let Some(&max) = sorted.last() {
            ensure_shape!(
                max < self.rows,
                "Mat::remove_rows",
                "index {} >= rows {}",
                max,
                self.rows
            );
        }
        let removed = self.select_rows(&sorted);
        if sorted.is_empty() {
            return Ok(removed);
        }
        let keep_rows = self.rows - sorted.len();
        // in-place compaction: shift kept rows down over removed ones
        // (no allocation; one memmove per kept row after the first removal)
        let cols = self.cols;
        let mut dst = sorted[0];
        let mut it = sorted.iter().peekable();
        for r in sorted[0]..self.rows {
            if it.peek() == Some(&&r) {
                it.next();
                continue;
            }
            if dst != r {
                self.data.copy_within(r * cols..(r + 1) * cols, dst * cols);
            }
            dst += 1;
        }
        self.data.truncate(keep_rows * cols);
        self.rows = keep_rows;
        Ok(removed)
    }

    /// Submatrix copy `[r0..r1, c0..c1)`.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        debug_assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.row_mut(r - r0)
                .copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) -> Result<()> {
        ensure_shape!(
            self.shape() == other.shape(),
            "Mat::axpy",
            "{:?} != {:?}",
            self.shape(),
            other.shape()
        );
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Symmetrize in place: `A = (A + A^T) / 2` (drift control for the
    /// maintained inverses, which are SPD in exact arithmetic).
    pub fn symmetrize(&mut self) {
        debug_assert!(self.is_square());
        let n = self.rows;
        for r in 0..n {
            for c in (r + 1)..n {
                let v = 0.5 * (self[(r, c)] + self[(c, r)]);
                self[(r, c)] = v;
                self[(c, r)] = v;
            }
        }
    }

    /// `A + alpha*I` (must be square).
    pub fn add_diag(&mut self, alpha: f64) -> Result<()> {
        ensure_shape!(self.is_square(), "Mat::add_diag", "not square: {:?}", self.shape());
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
        Ok(())
    }

    /// Row sums as a vector (`A e^T`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|r| self.row(r).iter().sum()).collect()
    }

    /// Column sums as a vector (`e A`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (o, v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Check all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for ILP; LLVM vectorizes this well.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x` over slices.
#[inline]
pub fn axpy_slice(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[allow(clippy::needless_range_loop)]
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_fn(3, 4, |r, c| (r * 10 + c) as f64);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn eye_and_diag() {
        let mut m = Mat::eye(3);
        m.add_diag(0.5).unwrap();
        assert_eq!(m[(0, 0)], 1.5);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(37, 53, |r, c| (r * 53 + c) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.transpose(), m);
        assert_eq!(t[(5, 7)], m[(7, 5)]);
    }

    #[test]
    fn hcat_vcat() {
        let a = Mat::from_fn(2, 2, |r, c| (r + c) as f64);
        let b = Mat::from_fn(2, 1, |_, _| 9.0);
        let h = a.hcat(&b).unwrap();
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h[(1, 2)], 9.0);
        let v = a.vcat(&a).unwrap();
        assert_eq!(v.shape(), (4, 2));
        assert!(a.hcat(&Mat::zeros(3, 1)).is_err());
    }

    #[test]
    fn push_remove_rows() {
        let mut m = Mat::zeros(0, 0);
        m.push_row(&[1.0, 2.0]).unwrap();
        m.push_row(&[3.0, 4.0]).unwrap();
        m.push_row(&[5.0, 6.0]).unwrap();
        assert_eq!(m.shape(), (3, 2));
        let removed = m.remove_rows(&[1]).unwrap();
        assert_eq!(removed.row(0), &[3.0, 4.0]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m.row(1), &[5.0, 6.0]);
        assert!(m.remove_rows(&[5]).is_err());
    }

    #[test]
    fn remove_rows_unsorted_dedup() {
        let mut m = Mat::from_fn(5, 1, |r, _| r as f64);
        let removed = m.remove_rows(&[3, 1, 3]).unwrap();
        assert_eq!(removed.col(0), vec![1.0, 3.0]);
        assert_eq!(m.col(0), vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn select_and_block() {
        let m = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0)[0], 8.0);
        assert_eq!(s.row(1)[0], 0.0);
        let b = m.block(1, 3, 2, 4);
        assert_eq!(b.shape(), (2, 2));
        assert_eq!(b[(0, 0)], 6.0);
        let c = m.select_cols(&[3, 1]);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(0, 1)], 1.0);
    }

    #[test]
    fn sums_and_norms() {
        let m = Mat::from_fn(2, 3, |_, _| 1.0);
        assert_eq!(m.row_sums(), vec![3.0, 3.0]);
        assert_eq!(m.col_sums(), vec![2.0, 2.0, 2.0]);
        assert!((m.fro_norm() - 6.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn symmetrize() {
        let mut m = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f64);
        m.symmetrize();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(m[(r, c)], m[(c, r)]);
            }
        }
    }

    #[test]
    fn dot_unrolled() {
        let a: Vec<f64> = (0..103).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..103).map(|i| (i * 2) as f64).collect();
        let want: f64 = (0..103).map(|i| (i * i * 2) as f64).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-9);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Mat::from_vec(2, 2, vec![0.0; 4]).is_ok());
    }
}
