//! The paper's maintained-inverse update rules.
//!
//! * [`incdec`] — eq. (15): one-shot batched up/down-date of `S^-1` by
//!   `|C|` additions and `|R|` removals (rank-H Woodbury, H = |C| + |R|).
//! * [`bordered_grow`] — eq. (28): grow `Q^-1` by a block of new samples
//!   (block bordered-inverse / Schur complement).
//! * [`bordered_shrink`] — eq. (29): shrink `Q^-1` by removing samples.
//!
//! All three avoid the O(n^3) fresh inverse: `incdec` costs O(J^2 H + H^3),
//! grow costs O(N^2 |C|), shrink costs O(N^2 |R|).

use crate::ensure_shape;
use crate::error::{Error, Result};
use crate::linalg::gemm::{gemm_into, matmul, matmul_nt, matmul_tn};
use crate::linalg::matrix::Mat;
use crate::linalg::solve::solve_mat;

/// Reusable workspace for [`incdec_into`] so the hot path allocates nothing
/// after warm-up.
#[derive(Clone, Default)]
pub struct IncDecWork {
    t: Option<Mat>,
    w: Option<Mat>,
}

/// Batched incremental/decremental update (paper eq. 15):
///
/// `S'^-1 = S^-1 - S^-1 Φ_H (I + Φ_H' S^-1 Φ_H)^-1 Φ_H' S^-1`
///
/// with `Φ_H` of shape (J, H) and `signs[h] ∈ {+1, -1}` marking column h as
/// incremental (+) or decremental (−); `Φ_H'` is `diag(signs) Φ_H^T`.
/// Zero columns are exact no-ops (used by the AOT artifact to pad batches).
pub fn incdec(s_inv: &Mat, phi_h: &Mat, signs: &[f64]) -> Result<Mat> {
    let mut out = s_inv.clone();
    let mut work = IncDecWork::default();
    incdec_into(&mut out, phi_h, signs, &mut work)?;
    Ok(out)
}

/// In-place variant of [`incdec`]: updates `s_inv` directly.
pub fn incdec_into(
    s_inv: &mut Mat,
    phi_h: &Mat,
    signs: &[f64],
    work: &mut IncDecWork,
) -> Result<()> {
    let j = s_inv.rows();
    let h = phi_h.cols();
    ensure_shape!(
        s_inv.is_square() && phi_h.rows() == j && signs.len() == h,
        "woodbury::incdec",
        "s_inv {:?}, phi_h {:?}, signs {}",
        s_inv.shape(),
        phi_h.shape(),
        signs.len()
    );
    if h == 0 {
        return Ok(());
    }
    for &s in signs {
        if s != 1.0 && s != -1.0 {
            return Err(Error::InvalidUpdate(format!("sign {s} not in {{+1,-1}}")));
        }
    }
    // T = S^-1 Φ_H  (J, H) — computed as row-dots against Φ_H^T so the
    // inner loops run over contiguous length-J slices instead of length-H
    // strided columns (≈2x on the J=253/H=6 hot path; EXPERIMENTS.md §Perf).
    let phi_t = phi_h.transpose(); // (H, J)
    let t = matmul_nt(s_inv, &phi_t)?;
    // core = I + diag(s) Φ_H^T T                    (H, H)
    let pht_t = matmul_tn(phi_h, &t)?;
    let mut core = Mat::eye(h);
    for r in 0..h {
        for c in 0..h {
            core[(r, c)] += signs[r] * pht_t[(r, c)];
        }
    }
    // W = core^-1 diag(s) T^T                       (H, J)
    let mut st_t = t.transpose();
    for r in 0..h {
        let s = signs[r];
        if s != 1.0 {
            for v in st_t.row_mut(r) {
                *v *= s;
            }
        }
    }
    let w = solve_mat(&core, &st_t).map_err(|_| {
        Error::InvalidUpdate(format!(
            "Woodbury core singular: batch of {h} conflicts with current state \
             (removing samples not in the set, or |H| too large)"
        ))
    })?;
    // S'^-1 = S^-1 - T W   (rank-H correction — the L1 kernel's job on TPU)
    gemm_into(-1.0, &t, &w, 1.0, s_inv)?;
    // exact-arithmetic symmetric for symmetric batches; fight drift
    s_inv.symmetrize();
    work.t = Some(t);
    work.w = Some(w);
    Ok(())
}

/// Bordered grow (paper eq. 28): given `Q^-1` (N, N), the cross-kernel block
/// `eta` (N, C) and the new-block kernel `q_cc` (C, C) (already including
/// the ridge on its diagonal), return the (N+C, N+C) inverse of
/// `[[Q, eta], [eta^T, q_cc]]`.
pub fn bordered_grow(q_inv: &Mat, eta: &Mat, q_cc: &Mat) -> Result<Mat> {
    let n = q_inv.rows();
    let c = q_cc.rows();
    ensure_shape!(
        q_inv.is_square() && eta.rows() == n && eta.cols() == c && q_cc.is_square(),
        "woodbury::bordered_grow",
        "q_inv {:?}, eta {:?}, q_cc {:?}",
        q_inv.shape(),
        eta.shape(),
        q_cc.shape()
    );
    // G = -Q^-1 eta          (N, C)     [paper eq. 23, matrix version]
    let mut g = matmul(q_inv, eta)?;
    g.scale(-1.0);
    // Z = q_cc - eta^T Q^-1 eta = q_cc + eta^T G    (C, C)
    let mut z = q_cc.clone();
    let etg = matmul_tn(eta, &g)?;
    z.axpy(1.0, &etg)?;
    let z_inv = crate::linalg::solve::spd_inverse(&z).map_err(|_| {
        Error::InvalidUpdate("grow block Schur complement not SPD".to_string())
    })?;
    // assemble [[Q^-1 + G Z^-1 G^T, G Z^-1], [Z^-1 G^T, Z^-1]]
    let gz = matmul(&g, &z_inv)?; // (N, C)
    let mut out = Mat::zeros(n + c, n + c);
    // top-left
    let gzgt = crate::linalg::gemm::matmul_nt(&gz, &g)?; // G Z^-1 G^T
    for r in 0..n {
        let o = out.row_mut(r);
        let q = q_inv.row(r);
        let x = gzgt.row(r);
        for i in 0..n {
            o[i] = q[i] + x[i];
        }
        for i in 0..c {
            o[n + i] = gz[(r, i)];
        }
    }
    for r in 0..c {
        for i in 0..n {
            out[(n + r, i)] = gz[(i, r)];
        }
        for i in 0..c {
            out[(n + r, n + i)] = z_inv[(r, i)];
        }
    }
    Ok(out)
}

/// Bordered shrink (paper eq. 29): remove the samples at `remove_idx` from a
/// maintained `Q^-1`.  Works for any index set by block-partitioning `Q^-1`
/// into kept (Θ), cross (ξ_R) and removed (θ_R) parts:
///
/// `Q'^-1 = Θ − ξ_R θ_R^-1 ξ_R^T`
///
/// Cost O(N^2 |R|).  Per §III.B, when |R| approaches the residual size a
/// fresh inverse is cheaper — the [`crate::krr::advisor`] makes that call.
pub fn bordered_shrink(q_inv: &Mat, remove_idx: &[usize]) -> Result<Mat> {
    let n = q_inv.rows();
    let mut rem: Vec<usize> = remove_idx.to_vec();
    rem.sort_unstable();
    rem.dedup();
    ensure_shape!(
        q_inv.is_square() && rem.iter().all(|&i| i < n),
        "woodbury::bordered_shrink",
        "q_inv {:?}, remove {:?}",
        q_inv.shape(),
        remove_idx
    );
    if rem.len() == n {
        return Ok(Mat::zeros(0, 0));
    }
    if rem.is_empty() {
        return Ok(q_inv.clone());
    }
    let keep: Vec<usize> = (0..n).filter(|i| !rem.contains(i)).collect();
    let theta = sub_matrix(q_inv, &keep, &keep);
    let xi = sub_matrix(q_inv, &keep, &rem); // (K, R)
    let theta_r = sub_matrix(q_inv, &rem, &rem); // (R, R)
    // W = theta_r^-1 xi^T  -> correction = xi W
    let w = solve_mat(&theta_r, &xi.transpose()).map_err(|_| {
        Error::InvalidUpdate("shrink block theta_R singular".to_string())
    })?;
    let mut out = theta;
    gemm_into(-1.0, &xi, &w, 1.0, &mut out)?;
    out.symmetrize();
    Ok(out)
}

/// Copy a general submatrix by row/col index lists.
pub fn sub_matrix(a: &Mat, rows: &[usize], cols: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), cols.len());
    for (i, &r) in rows.iter().enumerate() {
        let arow = a.row(r);
        let orow = out.row_mut(i);
        for (j, &c) in cols.iter().enumerate() {
            orow[j] = arow[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul_nt, syrk};
    use crate::linalg::solve::spd_inverse;
    use crate::util::prng::Rng;

    fn spd(n: usize, seed: u64, jitter: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut s = syrk(&a).unwrap();
        s.scale(1.0 / n as f64);
        s.add_diag(jitter).unwrap();
        s
    }

    #[test]
    fn incdec_matches_fresh_inverse() {
        let j = 30;
        let s = spd(j, 1, 30.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(2);
        let phi_h = Mat::from_fn(j, 6, |_, _| 0.3 * rng.gaussian());
        let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
        let got = incdec(&s_inv, &phi_h, &signs).unwrap();
        // fresh: S' = S + sum signs * phi phi^T
        let mut s_new = s.clone();
        for h in 0..6 {
            let col = phi_h.col(h);
            crate::linalg::gemm::ger(&mut s_new, signs[h], &col, &col).unwrap();
        }
        let want = spd_inverse(&s_new).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "diff={}", got.max_abs_diff(&want));
    }

    #[test]
    fn incdec_pure_incremental_and_decremental() {
        let j = 20;
        let s = spd(j, 3, 25.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(4);
        let phi = Mat::from_fn(j, 3, |_, _| 0.2 * rng.gaussian());
        // inc then dec with the same columns must round-trip
        let up = incdec(&s_inv, &phi, &[1.0; 3]).unwrap();
        let down = incdec(&up, &phi, &[-1.0; 3]).unwrap();
        assert!(down.max_abs_diff(&s_inv) < 1e-8);
    }

    #[test]
    fn incdec_empty_batch_noop() {
        let s_inv = spd_inverse(&spd(8, 5, 10.0)).unwrap();
        let got = incdec(&s_inv, &Mat::zeros(8, 0), &[]).unwrap();
        assert!(got.max_abs_diff(&s_inv) < 1e-15);
    }

    #[test]
    fn incdec_zero_columns_are_noop() {
        let j = 12;
        let s_inv = spd_inverse(&spd(j, 6, 12.0)).unwrap();
        let mut rng = Rng::new(7);
        let phi2 = Mat::from_fn(j, 2, |_, _| 0.2 * rng.gaussian());
        let phi6 = phi2.hcat(&Mat::zeros(j, 4)).unwrap();
        let a = incdec(&s_inv, &phi2, &[1.0, -1.0]).unwrap();
        let b = incdec(&s_inv, &phi6, &[1.0, -1.0, 1.0, 1.0, 1.0, 1.0]).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn incdec_rejects_bad_signs() {
        let s_inv = Mat::eye(4);
        let phi = Mat::zeros(4, 1);
        assert!(incdec(&s_inv, &phi, &[0.5]).is_err());
    }

    #[test]
    fn bordered_grow_matches_fresh() {
        let n = 15;
        let c = 4;
        let mut rng = Rng::new(8);
        // full SPD (N+C) matrix, then treat leading N as current
        let full = spd(n + c, 9, 20.0);
        let q = full.block(0, n, 0, n);
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let q_inv = spd_inverse(&q).unwrap();
        let got = bordered_grow(&q_inv, &eta, &qcc).unwrap();
        let want = spd_inverse(&full).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "diff={}", got.max_abs_diff(&want));
        let _ = &mut rng;
    }

    #[test]
    fn bordered_shrink_matches_fresh() {
        let n = 18;
        let full = spd(n, 10, 15.0);
        let full_inv = spd_inverse(&full).unwrap();
        let rem = vec![2usize, 7, 11];
        let got = bordered_shrink(&full_inv, &rem).unwrap();
        let keep: Vec<usize> = (0..n).filter(|i| !rem.contains(i)).collect();
        let sub = sub_matrix(&full, &keep, &keep);
        let want = spd_inverse(&sub).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn grow_then_shrink_roundtrip() {
        let n = 12;
        let c = 3;
        let full = spd(n + c, 11, 18.0);
        let q = full.block(0, n, 0, n);
        let q_inv = spd_inverse(&q).unwrap();
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let grown = bordered_grow(&q_inv, &eta, &qcc).unwrap();
        let rem: Vec<usize> = (n..n + c).collect();
        let back = bordered_shrink(&grown, &rem).unwrap();
        assert!(back.max_abs_diff(&q_inv) < 1e-8);
    }

    #[test]
    fn shrink_all_and_none() {
        let q_inv = spd_inverse(&spd(5, 12, 8.0)).unwrap();
        assert_eq!(bordered_shrink(&q_inv, &[]).unwrap().shape(), (5, 5));
        assert_eq!(
            bordered_shrink(&q_inv, &[0, 1, 2, 3, 4]).unwrap().shape(),
            (0, 0)
        );
    }

    #[test]
    fn incdec_large_batch_still_correct() {
        // |H| > J is mathematically fine (just not efficient) — check math.
        let j = 6;
        let s = spd(j, 13, 40.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(14);
        let phi = Mat::from_fn(j, 10, |_, _| 0.1 * rng.gaussian());
        let signs = [1.0; 10];
        let got = incdec(&s_inv, &phi, &signs).unwrap();
        let mut s_new = s.clone();
        let ppt = matmul_nt(&phi, &phi).unwrap();
        s_new.axpy(1.0, &ppt).unwrap();
        let want = spd_inverse(&s_new).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8);
    }
}
