//! The paper's maintained-inverse update rules, as an in-place engine.
//!
//! * [`incdec_into`] — eq. (15): one batched up/down-date of `S^-1` by
//!   `|C|` additions and `|R|` removals (rank-H Woodbury, H = |C| + |R|),
//!   written directly into the maintained buffer.
//! * [`bordered_grow_into`] — eq. (28): grow `Q^-1` by a block of new
//!   samples (block bordered-inverse / Schur complement), restriding the
//!   existing buffer in place.
//! * [`bordered_shrink_into`] — eq. (29): shrink `Q^-1` by removing any
//!   index set, compacting the existing buffer in place.
//!
//! All three avoid the O(n^3) fresh inverse: `incdec` costs O(J^2 H + H^3),
//! grow costs O(N^2 |C|), shrink costs O(N^2 |R|).
//!
//! Every product below goes through the shape-adaptive dispatch in
//! [`crate::linalg::gemm::dispatch`]: the typical small-|H| rounds (k =
//! |C| + |R| ≤ a few dozen) stay on the streaming axpy/row-dot kernels by
//! design, while a large batch against a large maintained inverse (e.g. a
//! wide grow block at J = 2024) crosses into the packed 4×8 micro-kernel
//! automatically — no per-call-site tuning.
//!
//! # Workspace contract
//!
//! The `_into` variants take a workspace ([`IncDecWork`] / [`BorderWork`])
//! holding every intermediate the update needs. The buffers are resized
//! logically on each call but keep their backing storage, so a workspace
//! reused across rounds stops allocating as soon as it has seen the
//! workload's peak shapes — typically after the first round. The first
//! call (and any call that grows past the previous peak |H|, N, or |C|)
//! does allocate; "allocates nothing" holds only for a *warm* workspace in
//! steady state, as asserted by `tests/alloc_count.rs`. The convenience
//! wrappers ([`incdec`], [`bordered_grow`], [`bordered_shrink`]) construct
//! a fresh workspace and output copy per call and are for tests and
//! one-shot use — never for the hot path.

use crate::ensure_shape;
use crate::error::{Error, Result};
use crate::linalg::gemm::{
    gemm_into, gemm_nt_acc_block, gemm_tn_acc, matmul_into, matmul_nt_into,
};
use crate::linalg::matrix::Mat;
use crate::linalg::solve::{lu_solve_mat_in_place, spd_inverse_into};

/// Reusable workspace for [`incdec_into`]: pre-sized `Φ_H^T`, `T`, `W` and
/// core buffers (see the module-level workspace contract).
#[derive(Clone, Default)]
pub struct IncDecWork {
    /// Φ_H^T (H, J).
    phi_t: Mat,
    /// T = S^-1 Φ_H (J, H).
    t: Mat,
    /// diag(s) T^T, overwritten with W = core^-1 diag(s) T^T (H, J).
    w: Mat,
    /// I + diag(s) Φ_H^T T (H, H); destroyed by the in-place LU solve.
    core: Mat,
}

/// Batched incremental/decremental update (paper eq. 15):
///
/// `S'^-1 = S^-1 - S^-1 Φ_H (I + Φ_H' S^-1 Φ_H)^-1 Φ_H' S^-1`
///
/// with `Φ_H` of shape (J, H) and `signs[h] ∈ {+1, -1}` marking column h as
/// incremental (+) or decremental (−); `Φ_H'` is `diag(signs) Φ_H^T`.
/// Zero columns are exact no-ops (used by the AOT artifact to pad batches).
///
/// Convenience wrapper: copies `s_inv` and builds a cold workspace. The hot
/// path is [`incdec_into`] with a reused workspace.
pub fn incdec(s_inv: &Mat, phi_h: &Mat, signs: &[f64]) -> Result<Mat> {
    let mut out = s_inv.clone();
    let mut work = IncDecWork::default();
    incdec_into(&mut out, phi_h, signs, &mut work)?;
    Ok(out)
}

/// In-place variant of [`incdec`]: updates `s_inv` directly, drawing every
/// intermediate from `work` (zero heap allocations once `work` is warm).
/// On error `s_inv` is left unmodified.
pub fn incdec_into(
    s_inv: &mut Mat,
    phi_h: &Mat,
    signs: &[f64],
    work: &mut IncDecWork,
) -> Result<()> {
    let j = s_inv.rows();
    let h = phi_h.cols();
    ensure_shape!(
        s_inv.is_square() && phi_h.rows() == j && signs.len() == h,
        "woodbury::incdec",
        "s_inv {:?}, phi_h {:?}, signs {}",
        s_inv.shape(),
        phi_h.shape(),
        signs.len()
    );
    if h == 0 {
        return Ok(());
    }
    for &s in signs {
        if s != 1.0 && s != -1.0 {
            return Err(Error::InvalidUpdate(format!("sign {s} not in {{+1,-1}}")));
        }
    }
    // T = S^-1 Φ_H  (J, H) — computed as row-dots against Φ_H^T so the
    // inner loops run over contiguous length-J slices instead of length-H
    // strided columns (≈2x on the J=253/H=6 hot path; EXPERIMENTS.md §Perf).
    // For |H| past the dispatch crossover the same call rides the packed
    // NT engine instead.
    phi_h.transpose_into(&mut work.phi_t); // (H, J)
    matmul_nt_into(s_inv, &work.phi_t, &mut work.t)?;
    // core = I + diag(s) Φ_H^T T                    (H, H)
    matmul_into(&work.phi_t, &work.t, &mut work.core)?;
    for r in 0..h {
        let s = signs[r];
        if s != 1.0 {
            for v in work.core.row_mut(r) {
                *v *= s;
            }
        }
        work.core[(r, r)] += 1.0;
    }
    // W = core^-1 diag(s) T^T                       (H, J)
    work.t.transpose_into(&mut work.w);
    for r in 0..h {
        let s = signs[r];
        if s != 1.0 {
            for v in work.w.row_mut(r) {
                *v *= s;
            }
        }
    }
    lu_solve_mat_in_place(&mut work.core, &mut work.w).map_err(|_| {
        Error::InvalidUpdate(format!(
            "Woodbury core singular: batch of {h} conflicts with current state \
             (removing samples not in the set, or |H| too large)"
        ))
    })?;
    // S'^-1 = S^-1 - T W   (rank-H correction — the L1 kernel's job on TPU)
    gemm_into(-1.0, &work.t, &work.w, 1.0, s_inv)?;
    // exact-arithmetic symmetric for symmetric batches; fight drift
    s_inv.symmetrize();
    Ok(())
}

/// Reusable workspace for [`bordered_grow_into`] / [`bordered_shrink_into`]
/// (see the module-level workspace contract). One `BorderWork` serves both
/// directions, so an engine alternating grow and shrink carries a single
/// workspace.
#[derive(Clone, Default)]
pub struct BorderWork {
    /// Grow: G = -Q^-1 η (N, C).
    g: Mat,
    /// Grow: Schur complement Z = q_cc - η^T Q^-1 η (C, C).
    z: Mat,
    /// Grow: Z^-1 (C, C).
    z_inv: Mat,
    /// Grow: G Z^-1 (N, C).
    gz: Mat,
    /// Cholesky factor scratch for the Z inverse.
    l: Mat,
    /// Column scratch for the Z inverse.
    col: Vec<f64>,
    /// Shrink: sorted, deduplicated removal set.
    rem: Vec<usize>,
    /// Shrink: complement (kept) index set.
    keep: Vec<usize>,
    /// Shrink: ξ_R = Q^-1[keep, rem] (K, R).
    xi: Mat,
    /// Shrink: ξ_R^T, overwritten with W = θ_R^-1 ξ_R^T (R, K).
    w: Mat,
    /// Shrink: θ_R = Q^-1[rem, rem] (R, R); destroyed by the LU solve.
    theta_r: Mat,
}

/// Bordered grow (paper eq. 28): given `Q^-1` (N, N), the cross-kernel block
/// `eta` (N, C) and the new-block kernel `q_cc` (C, C) (already including
/// the ridge on its diagonal), return the (N+C, N+C) inverse of
/// `[[Q, eta], [eta^T, q_cc]]`.
///
/// Convenience wrapper over [`bordered_grow_into`] (copies the input and
/// builds a cold workspace).
pub fn bordered_grow(q_inv: &Mat, eta: &Mat, q_cc: &Mat) -> Result<Mat> {
    let mut out = q_inv.clone();
    bordered_grow_into(&mut out, eta, q_cc, &mut BorderWork::default())?;
    Ok(out)
}

/// In-place bordered grow: restrides `q_inv`'s buffer to (N+C, N+C) —
/// without reallocating when its reserved capacity suffices — and writes
/// the rank-|C| top-left correction plus the new borders directly into it.
/// Zero heap allocations once `q_inv`'s capacity and `work` are warm.
pub fn bordered_grow_into(
    q_inv: &mut Mat,
    eta: &Mat,
    q_cc: &Mat,
    work: &mut BorderWork,
) -> Result<()> {
    let n = q_inv.rows();
    let c = q_cc.rows();
    ensure_shape!(
        q_inv.is_square() && eta.rows() == n && eta.cols() == c && q_cc.is_square(),
        "woodbury::bordered_grow",
        "q_inv {:?}, eta {:?}, q_cc {:?}",
        q_inv.shape(),
        eta.shape(),
        q_cc.shape()
    );
    if c == 0 {
        return Ok(());
    }
    // G = -Q^-1 eta          (N, C)     [paper eq. 23, matrix version]
    // (small |C| streams on the axpy kernel; wide grow blocks at large N
    // cross into the packed engine — gemm::dispatch decides)
    matmul_into(q_inv, eta, &mut work.g)?;
    work.g.scale(-1.0);
    // Z = q_cc - eta^T Q^-1 eta = q_cc + eta^T G    (C, C)
    work.z.resize_scratch(c, c);
    work.z.as_mut_slice().copy_from_slice(q_cc.as_slice());
    gemm_tn_acc(1.0, eta, &work.g, &mut work.z)?;
    spd_inverse_into(&work.z, &mut work.z_inv, &mut work.l, &mut work.col).map_err(
        |_| Error::InvalidUpdate("grow block Schur complement not SPD".to_string()),
    )?;
    matmul_into(&work.g, &work.z_inv, &mut work.gz)?; // G Z^-1 (N, C)
    // restride the maintained buffer; existing entries stay in the top-left
    q_inv.grow_inplace(n + c, n + c)?;
    // top-left += G Z^-1 G^T (rank-|C| correction, straight into the block)
    gemm_nt_acc_block(1.0, &work.gz, &work.g, q_inv)?;
    // borders: [.., G Z^-1; Z^-1 G^T, Z^-1]
    for r in 0..n {
        let row = q_inv.row_mut(r);
        row[n..n + c].copy_from_slice(work.gz.row(r));
    }
    for r in 0..c {
        for i in 0..n {
            q_inv[(n + r, i)] = work.gz[(i, r)];
        }
        let row = q_inv.row_mut(n + r);
        row[n..n + c].copy_from_slice(work.z_inv.row(r));
    }
    // exact-arithmetic symmetric; fight drift like the other updates
    q_inv.symmetrize();
    Ok(())
}

/// Bordered shrink (paper eq. 29): remove the samples at `remove_idx` from a
/// maintained `Q^-1`.  Works for any index set by block-partitioning `Q^-1`
/// into kept (Θ), cross (ξ_R) and removed (θ_R) parts:
///
/// `Q'^-1 = Θ − ξ_R θ_R^-1 ξ_R^T`
///
/// Cost O(N^2 |R|).  Per §III.B, when |R| approaches the residual size a
/// fresh inverse is cheaper — the [`crate::krr::advisor`] makes that call.
///
/// Convenience wrapper over [`bordered_shrink_into`] (copies the input and
/// builds a cold workspace).
pub fn bordered_shrink(q_inv: &Mat, remove_idx: &[usize]) -> Result<Mat> {
    let mut out = q_inv.clone();
    bordered_shrink_into(&mut out, remove_idx, &mut BorderWork::default())?;
    Ok(out)
}

/// In-place bordered shrink: gathers the ξ_R/θ_R blocks into the
/// workspace, compacts `q_inv` to the kept index set inside its own buffer
/// (a forward gather — no reallocation, capacity retained for regrowth),
/// then applies the rank-|R| correction directly. Zero heap allocations
/// once `work` is warm.
pub fn bordered_shrink_into(
    q_inv: &mut Mat,
    remove_idx: &[usize],
    work: &mut BorderWork,
) -> Result<()> {
    let n = q_inv.rows();
    work.rem.clear();
    work.rem.extend_from_slice(remove_idx);
    work.rem.sort_unstable();
    work.rem.dedup();
    ensure_shape!(
        q_inv.is_square() && work.rem.last().is_none_or(|&i| i < n),
        "woodbury::bordered_shrink",
        "q_inv {:?}, remove {:?}",
        q_inv.shape(),
        remove_idx
    );
    let r = work.rem.len();
    if r == n {
        return q_inv.shrink_inplace(0, 0);
    }
    if r == 0 {
        return Ok(());
    }
    work.keep.clear();
    {
        // complement of the sorted removal set, by a single merge sweep
        let mut next = 0usize;
        for i in 0..n {
            if next < r && work.rem[next] == i {
                next += 1;
            } else {
                work.keep.push(i);
            }
        }
    }
    // gather the cross and removed blocks BEFORE compacting the buffer
    sub_matrix_into(q_inv, &work.keep, &work.rem, &mut work.xi); // (K, R)
    sub_matrix_into(q_inv, &work.rem, &work.rem, &mut work.theta_r); // (R, R)
    work.xi.transpose_into(&mut work.w); // ξ_R^T (R, K)
    // W = θ_R^-1 ξ_R^T (in place; θ_R destroyed)
    lu_solve_mat_in_place(&mut work.theta_r, &mut work.w).map_err(|_| {
        Error::InvalidUpdate("shrink block theta_R singular".to_string())
    })?;
    // compact to Θ inside the same buffer, then apply the correction
    q_inv.compact(&work.keep, &work.keep)?;
    gemm_into(-1.0, &work.xi, &work.w, 1.0, q_inv)?;
    q_inv.symmetrize();
    Ok(())
}

/// Copy a general submatrix by row/col index lists.
pub fn sub_matrix(a: &Mat, rows: &[usize], cols: &[usize]) -> Mat {
    let mut out = Mat::default();
    sub_matrix_into(a, rows, cols, &mut out);
    out
}

/// [`sub_matrix`] written into a caller-provided matrix (reshaped as
/// needed; allocation-free with warm capacity).
pub fn sub_matrix_into(a: &Mat, rows: &[usize], cols: &[usize], out: &mut Mat) {
    out.resize_scratch(rows.len(), cols.len());
    for (i, &r) in rows.iter().enumerate() {
        let arow = a.row(r);
        let orow = out.row_mut(i);
        for (j, &c) in cols.iter().enumerate() {
            orow[j] = arow[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul_nt, syrk};
    use crate::linalg::solve::spd_inverse;
    use crate::util::prng::Rng;

    fn spd(n: usize, seed: u64, jitter: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut s = syrk(&a).unwrap();
        s.scale(1.0 / n as f64);
        s.add_diag(jitter).unwrap();
        s
    }

    #[test]
    fn incdec_matches_fresh_inverse() {
        let j = 30;
        let s = spd(j, 1, 30.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(2);
        let phi_h = Mat::from_fn(j, 6, |_, _| 0.3 * rng.gaussian());
        let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
        let got = incdec(&s_inv, &phi_h, &signs).unwrap();
        // fresh: S' = S + sum signs * phi phi^T
        let mut s_new = s.clone();
        for h in 0..6 {
            let col = phi_h.col(h);
            crate::linalg::gemm::ger(&mut s_new, signs[h], &col, &col).unwrap();
        }
        let want = spd_inverse(&s_new).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "diff={}", got.max_abs_diff(&want));
    }

    #[test]
    fn incdec_pure_incremental_and_decremental() {
        let j = 20;
        let s = spd(j, 3, 25.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(4);
        let phi = Mat::from_fn(j, 3, |_, _| 0.2 * rng.gaussian());
        // inc then dec with the same columns must round-trip
        let up = incdec(&s_inv, &phi, &[1.0; 3]).unwrap();
        let down = incdec(&up, &phi, &[-1.0; 3]).unwrap();
        assert!(down.max_abs_diff(&s_inv) < 1e-8);
    }

    #[test]
    fn incdec_empty_batch_noop() {
        let s_inv = spd_inverse(&spd(8, 5, 10.0)).unwrap();
        let got = incdec(&s_inv, &Mat::zeros(8, 0), &[]).unwrap();
        assert!(got.max_abs_diff(&s_inv) < 1e-15);
    }

    #[test]
    fn incdec_zero_columns_are_noop() {
        let j = 12;
        let s_inv = spd_inverse(&spd(j, 6, 12.0)).unwrap();
        let mut rng = Rng::new(7);
        let phi2 = Mat::from_fn(j, 2, |_, _| 0.2 * rng.gaussian());
        let phi6 = phi2.hcat(&Mat::zeros(j, 4)).unwrap();
        let a = incdec(&s_inv, &phi2, &[1.0, -1.0]).unwrap();
        let b = incdec(&s_inv, &phi6, &[1.0, -1.0, 1.0, 1.0, 1.0, 1.0]).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn incdec_rejects_bad_signs() {
        let s_inv = Mat::eye(4);
        let phi = Mat::zeros(4, 1);
        assert!(incdec(&s_inv, &phi, &[0.5]).is_err());
    }

    #[test]
    fn bordered_grow_matches_fresh() {
        let n = 15;
        let c = 4;
        let mut rng = Rng::new(8);
        // full SPD (N+C) matrix, then treat leading N as current
        let full = spd(n + c, 9, 20.0);
        let q = full.block(0, n, 0, n);
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let q_inv = spd_inverse(&q).unwrap();
        let got = bordered_grow(&q_inv, &eta, &qcc).unwrap();
        let want = spd_inverse(&full).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "diff={}", got.max_abs_diff(&want));
        let _ = &mut rng;
    }

    #[test]
    fn bordered_shrink_matches_fresh() {
        let n = 18;
        let full = spd(n, 10, 15.0);
        let full_inv = spd_inverse(&full).unwrap();
        let rem = vec![2usize, 7, 11];
        let got = bordered_shrink(&full_inv, &rem).unwrap();
        let keep: Vec<usize> = (0..n).filter(|i| !rem.contains(i)).collect();
        let sub = sub_matrix(&full, &keep, &keep);
        let want = spd_inverse(&sub).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn grow_then_shrink_roundtrip() {
        let n = 12;
        let c = 3;
        let full = spd(n + c, 11, 18.0);
        let q = full.block(0, n, 0, n);
        let q_inv = spd_inverse(&q).unwrap();
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let grown = bordered_grow(&q_inv, &eta, &qcc).unwrap();
        let rem: Vec<usize> = (n..n + c).collect();
        let back = bordered_shrink(&grown, &rem).unwrap();
        assert!(back.max_abs_diff(&q_inv) < 1e-8);
    }

    #[test]
    fn shrink_all_and_none() {
        let q_inv = spd_inverse(&spd(5, 12, 8.0)).unwrap();
        assert_eq!(bordered_shrink(&q_inv, &[]).unwrap().shape(), (5, 5));
        assert_eq!(
            bordered_shrink(&q_inv, &[0, 1, 2, 3, 4]).unwrap().shape(),
            (0, 0)
        );
    }

    #[test]
    fn incdec_into_reused_workspace_matches_oneshot() {
        let j = 24;
        let s = spd(j, 20, 25.0);
        let mut live = spd_inverse(&s).unwrap();
        let mut reference = live.clone();
        let mut work = IncDecWork::default();
        let mut rng = Rng::new(21);
        for round in 0..6 {
            let h = 2 + round % 3;
            let phi = Mat::from_fn(j, h, |_, _| 0.2 * rng.gaussian());
            let mut signs = vec![1.0; h];
            if h > 1 {
                signs[h - 1] = -1.0;
            }
            incdec_into(&mut live, &phi, &signs, &mut work).unwrap();
            reference = incdec(&reference, &phi, &signs).unwrap();
            assert!(live.max_abs_diff(&reference) < 1e-12, "round {round}");
        }
    }

    #[test]
    fn bordered_grow_into_reuses_buffer() {
        let n = 12;
        let c = 3;
        let full = spd(n + c, 22, 18.0);
        let q = full.block(0, n, 0, n);
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let mut live = spd_inverse(&q).unwrap();
        live.reserve_total((n + c) * (n + c));
        let ptr = live.as_slice().as_ptr();
        let mut work = BorderWork::default();
        bordered_grow_into(&mut live, &eta, &qcc, &mut work).unwrap();
        assert_eq!(live.as_slice().as_ptr(), ptr, "no reallocation");
        let want = spd_inverse(&full).unwrap();
        assert!(live.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn bordered_shrink_into_compacts_in_buffer() {
        let n = 14;
        let full = spd(n, 23, 14.0);
        let mut live = spd_inverse(&full).unwrap();
        let cap = live.capacity();
        let ptr = live.as_slice().as_ptr();
        let mut work = BorderWork::default();
        bordered_shrink_into(&mut live, &[1, 6, 9], &mut work).unwrap();
        assert_eq!(live.shape(), (n - 3, n - 3));
        assert_eq!(live.capacity(), cap, "capacity retained");
        assert_eq!(live.as_slice().as_ptr(), ptr, "no reallocation");
        let want = bordered_shrink(&spd_inverse(&full).unwrap(), &[1, 6, 9]).unwrap();
        assert!(live.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn grow_shrink_alternating_shares_workspace() {
        // one BorderWork serving both directions across rounds
        let n = 10;
        let full = spd(n + 2, 24, 16.0);
        let q = full.block(0, n, 0, n);
        let mut live = spd_inverse(&q).unwrap();
        let mut work = BorderWork::default();
        let eta = full.block(0, n, n, n + 2);
        let qcc = full.block(n, n + 2, n, n + 2);
        bordered_grow_into(&mut live, &eta, &qcc, &mut work).unwrap();
        bordered_shrink_into(&mut live, &[n, n + 1], &mut work).unwrap();
        let want = spd_inverse(&q).unwrap();
        assert!(live.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn incdec_large_batch_still_correct() {
        // |H| > J is mathematically fine (just not efficient) — check math.
        let j = 6;
        let s = spd(j, 13, 40.0);
        let s_inv = spd_inverse(&s).unwrap();
        let mut rng = Rng::new(14);
        let phi = Mat::from_fn(j, 10, |_, _| 0.1 * rng.gaussian());
        let signs = [1.0; 10];
        let got = incdec(&s_inv, &phi, &signs).unwrap();
        let mut s_new = s.clone();
        let ppt = matmul_nt(&phi, &phi).unwrap();
        s_new.axpy(1.0, &ppt).unwrap();
        let want = spd_inverse(&s_new).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8);
    }
}
