//! Little-endian binary primitives and the CRC-framed section container
//! shared by the snapshot and WAL codecs.
//!
//! A *section* is the unit of corruption detection:
//!
//! ```text
//! [tag: u32][len: u64][payload: len bytes][crc32: u32]
//! ```
//!
//! with the CRC computed over `tag ‖ len ‖ payload`, so a flipped bit in
//! the header (a wrong tag, an inflated length) is as loud as one in the
//! payload. Decoding never panics: every truncation or mismatch surfaces
//! as [`Error::persist_corruption`], which [`crate::error::Error::is_transient`]
//! classifies as permanent — the recovery path's signal to fall back a
//! generation rather than retry.

use crate::error::{Error, Result};

use super::crc::{crc32, Crc32};

// ---- writer primitives (append-to-Vec) ----

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a little-endian `u32`.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64`.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append an `f64` as its little-endian IEEE-754 bit pattern (bit-exact
/// round trip, NaN payloads included).
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

// ---- reader cursor ----

/// Bounds-checked reader over a decoded byte buffer.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Context string used in corruption errors.
    ctx: &'static str,
}

impl<'a> Cursor<'a> {
    /// Cursor over `buf`, reporting failures against `ctx`.
    pub fn new(buf: &'a [u8], ctx: &'static str) -> Self {
        Self { buf, pos: 0, ctx }
    }

    /// Current byte offset.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True once every byte has been consumed.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Take `n` raw bytes.
    pub fn take_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::persist_corruption(
                self.ctx,
                format!(
                    "truncated: wanted {n} bytes at offset {}, have {}",
                    self.pos,
                    self.remaining()
                ),
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Take a `u8`.
    pub fn take_u8(&mut self) -> Result<u8> {
        Ok(self.take_bytes(1)?[0])
    }

    /// Take a little-endian `u32`.
    pub fn take_u32(&mut self) -> Result<u32> {
        let b = self.take_bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Take a little-endian `u64`.
    pub fn take_u64(&mut self) -> Result<u64> {
        let b = self.take_bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Take a `u64` and narrow it to `usize` (corruption if it does not
    /// fit — a hostile length must never drive an allocation).
    pub fn take_len(&mut self) -> Result<usize> {
        let v = self.take_u64()?;
        usize::try_from(v).map_err(|_| {
            Error::persist_corruption(self.ctx, format!("length {v} overflows usize"))
        })
    }

    /// Take an `f64` from its bit pattern.
    pub fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }
}

// ---- CRC-framed sections ----

/// Append one `[tag][len][payload][crc]` section.
pub fn write_section(out: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    put_u32(out, tag);
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
    let mut c = Crc32::new();
    c.update(&tag.to_le_bytes());
    c.update(&(payload.len() as u64).to_le_bytes());
    c.update(payload);
    put_u32(out, c.finish());
}

/// Read one section, verifying its CRC. Returns `(tag, payload)`.
pub fn read_section<'a>(cur: &mut Cursor<'a>, ctx: &'static str) -> Result<(u32, &'a [u8])> {
    let tag = cur.take_u32()?;
    let len = cur.take_len()?;
    // saturating: a hostile length near usize::MAX must not overflow the
    // bound check (debug builds would panic instead of returning Err)
    if cur.remaining() < len.saturating_add(4) {
        return Err(Error::persist_corruption(
            ctx,
            format!(
                "section {tag:#x} claims {len} bytes but only {} remain",
                cur.remaining()
            ),
        ));
    }
    let payload = cur.take_bytes(len)?;
    let stored = cur.take_u32()?;
    let mut c = Crc32::new();
    c.update(&tag.to_le_bytes());
    c.update(&(len as u64).to_le_bytes());
    c.update(payload);
    let computed = c.finish();
    if computed != stored {
        return Err(Error::persist_corruption(
            ctx,
            format!(
                "section {tag:#x} crc mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
        ));
    }
    Ok((tag, payload))
}

/// One-shot CRC over a frame (WAL records use raw `[len][payload][crc]`
/// framing; re-exported here so both codecs share one implementation).
pub fn frame_crc(payload: &[u8]) -> u32 {
    crc32(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 3);
        put_f64(&mut buf, -0.0);
        put_f64(&mut buf, f64::from_bits(0x7FF8_0000_0000_1234)); // NaN payload
        let mut cur = Cursor::new(&buf, "test");
        assert_eq!(cur.take_u8().unwrap(), 7);
        assert_eq!(cur.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(cur.take_u64().unwrap(), u64::MAX - 3);
        assert_eq!(cur.take_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(cur.take_f64().unwrap().to_bits(), 0x7FF8_0000_0000_1234);
        assert!(cur.is_empty());
        assert!(cur.take_u8().is_err(), "reading past the end is corruption");
    }

    #[test]
    fn section_round_trip_and_crc_rejection() {
        let mut buf = Vec::new();
        write_section(&mut buf, 3, b"hello sections");
        write_section(&mut buf, 9, b"");
        let mut cur = Cursor::new(&buf, "test");
        let (tag, payload) = read_section(&mut cur, "test").unwrap();
        assert_eq!((tag, payload), (3, b"hello sections".as_slice()));
        let (tag, payload) = read_section(&mut cur, "test").unwrap();
        assert_eq!((tag, payload.len()), (9, 0));
        assert!(cur.is_empty());
        // flip any byte -> corruption (header flips included)
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x40;
            let mut cur = Cursor::new(&bad, "test");
            let r = read_section(&mut cur, "test")
                .and_then(|_| read_section(&mut cur, "test"));
            assert!(r.is_err(), "flip at byte {i} slipped through");
            assert!(!r.unwrap_err().is_transient(), "corruption is permanent");
        }
    }

    #[test]
    fn truncated_section_rejected() {
        let mut buf = Vec::new();
        write_section(&mut buf, 1, &[0xAB; 32]);
        for cut in 0..buf.len() {
            let mut cur = Cursor::new(&buf[..cut], "test");
            assert!(read_section(&mut cur, "test").is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn hostile_length_is_rejected_not_allocated() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 1);
        put_u64(&mut buf, u64::MAX); // section claims 2^64 bytes
        let mut cur = Cursor::new(&buf, "test");
        assert!(read_section(&mut cur, "test").is_err());
    }
}
